"""Property-based chaos suite: ANY seeded fault plan recovers exactly.

The fuzzed form of the PR-8 acceptance criterion, built on the same
optional-hypothesis conftest stub as test_property_equivalence.py: for any
(pattern x steps_per_launch x fault classes x plan seed) drawn by
hypothesis, the resilient executor must reproduce the fault-free run bit
for bit — transport retries and launch replays exactly, and member
eviction exactly against the truncated-steps hetero-ensemble oracle
(survivors are never perturbed; the dead member's rows are precisely the
masked rows the act-schedule machinery produces for a member of the
frozen length).

Shapes stay small: every drawn case compiles its launch plan, and member
cases compile the oracle ensemble too.
"""
import dataclasses

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import GraphEnsemble, KernelSpec, TaskGraph, get_runtime
from repro.resilience import (
    FAULT_LAUNCH,
    FAULT_MEMBER,
    FAULT_STRAGGLER,
    FAULT_TRANSPORT,
    FaultPlan,
    run_resilient,
)

WIDTH = 8
#: one representative per plan kind: halo (stacked), stride + allgather
#: (stepwise) — the two resilient launch-plan builders
PATTERNS = ("stencil_1d", "tree", "all_to_all")
S_VALUES = (1, 4)
MEMBER_STEPS = ((13, 9), (10, 10), (7, 12))


def _graph(pattern: str, steps: int, seed: int) -> TaskGraph:
    return TaskGraph(steps=steps, width=WIDTH, payload=16, pattern=pattern,
                     radius=1, kernel=KernelSpec("compute_bound", 4),
                     seed=seed)


chaos_cases = st.tuples(
    st.sampled_from(PATTERNS),
    st.sampled_from(S_VALUES),
    st.sampled_from(MEMBER_STEPS),
    st.sampled_from([
        (FAULT_TRANSPORT,),
        (FAULT_LAUNCH,),
        (FAULT_TRANSPORT, FAULT_LAUNCH, FAULT_STRAGGLER),
    ]),
    st.integers(min_value=0, max_value=10),  # plan seed
)


@settings(max_examples=12, deadline=None, derandomize=True)
@given(chaos_cases)
def test_property_replayed_faults_recover_bit_identical(case):
    """Transport/launch/straggler plans never change a single bit."""
    pattern, s, member_steps, kinds, seed = case
    ens = GraphEnsemble(tuple(
        _graph(pattern, t, k) for k, t in enumerate(member_steps)))
    rt = get_runtime("pallas_step", steps_per_launch=s)
    want = [np.asarray(o) for o in rt.execute_ensemble(ens)]
    lp = rt.build_ensemble_launches(ens)
    plan = FaultPlan.random(seed, num_launches=lp.num_launches,
                            num_members=len(member_steps), rate=0.5,
                            kinds=kinds, straggler_delay_s=0.001)
    res = run_resilient(rt, ens, plan=plan)
    for k, (got, ref) in enumerate(zip(res.outputs, want)):
        assert np.array_equal(got, ref), (
            f"member {k} diverged under {plan.describe()} "
            f"({pattern}, S={s})")
    assert not res.evicted


@settings(max_examples=8, deadline=None, derandomize=True)
@given(st.tuples(
    st.sampled_from(PATTERNS),
    st.sampled_from(S_VALUES),
    st.integers(min_value=0, max_value=10),
))
def test_property_eviction_is_exactly_the_masked_rows(case):
    """Member-death plans: survivors bit-identical to the clean run, the
    evicted member bit-identical to a clean run truncated at the frozen
    step — i.e. the eviction's masked rows, nothing more or less."""
    pattern, s, seed = case
    members = (_graph(pattern, 13, 0), _graph(pattern, 9, 1))
    ens = GraphEnsemble(members)
    rt = get_runtime("pallas_step", steps_per_launch=s)
    want = [np.asarray(o) for o in rt.execute_ensemble(ens)]
    lp = rt.build_ensemble_launches(ens)
    plan = FaultPlan.random(seed, num_launches=lp.num_launches,
                            num_members=2, rate=0.5, kinds=(FAULT_MEMBER,))
    res = run_resilient(rt, ens, plan=plan)
    if not res.evicted:
        for got, ref in zip(res.outputs, want):
            assert np.array_equal(got, ref)
        return
    oracle_members = tuple(
        dataclasses.replace(g, steps=res.evicted[k])
        if k in res.evicted else g
        for k, g in enumerate(members))
    oracle = rt.execute_ensemble(GraphEnsemble(oracle_members))
    for k, (got, ref) in enumerate(zip(res.outputs, oracle)):
        assert np.array_equal(got, np.asarray(ref)), (
            f"member {k} (evicted={sorted(res.evicted)}) diverged under "
            f"{plan.describe()} ({pattern}, S={s})")
    for k in range(2):
        if k not in res.evicted:
            assert np.array_equal(res.outputs[k], want[k]), (
                f"survivor {k} perturbed by eviction")
