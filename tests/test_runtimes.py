"""Cross-backend equivalence — the system's core invariant.

Every runtime backend must produce identical final states for the same task
graph (DESIGN.md §2: the backends differ ONLY in scheduling/communication
strategy, never in dataflow). Single-device here; the multi-device versions
run in test_distributed.py subprocesses.
"""
import numpy as np
import pytest

from repro.core import TaskGraph, KernelSpec, available_runtimes, get_runtime
from repro.core.task_kernels import (
    apply_kernel,
    combine_all_to_all,
    combine_dependencies,
    initial_state,
)

PATTERNS = ["trivial", "no_comm", "stencil_1d", "stencil_1d_periodic", "dom",
            "tree", "fft", "all_to_all", "nearest", "spread",
            "random_nearest"]


def graph(pattern, **kw):
    base = dict(steps=6, width=16, payload=8,
                kernel=KernelSpec("compute_bound", 8), radius=2, seed=3)
    base.update(kw)
    return TaskGraph(pattern=pattern, **base)


def test_registry_contents():
    names = available_runtimes()
    for expected in ("fused", "serialized", "bsp", "bsp_scan", "overlap"):
        assert expected in names


@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("backend", ["serialized", "bsp", "bsp_scan",
                                     "overlap"])
def test_backend_matches_fused(pattern, backend):
    g = graph(pattern)
    rt = get_runtime(backend)
    ok, why = rt.supports(g)
    if not ok:
        pytest.skip(why)
    ref = get_runtime("fused").execute(g)
    out = rt.execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("kind", ["compute_bound", "memory_bound", "empty"])
def test_kernel_kinds_run(kind):
    g = graph("stencil_1d", kernel=KernelSpec(kind, 4, scratch=64))
    ref = get_runtime("fused").execute(g)
    out = get_runtime("bsp_scan").execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    assert np.isfinite(ref).all()


def test_single_step_graph():
    g = graph("stencil_1d", steps=1)
    ref = get_runtime("fused").execute(g)
    out = get_runtime("bsp").execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_large_iterations_stay_bounded():
    """Contraction-map FMA: no inf/nan at any grain size (task_kernels)."""
    g = graph("stencil_1d", kernel=KernelSpec("compute_bound", 1 << 14))
    out = get_runtime("fused").execute(g)
    assert np.isfinite(out).all()
    assert np.abs(out).max() < 10.0


def test_overlap_variants_match():
    """Fig-3-style build options must not change semantics."""
    g = graph("stencil_1d")
    ref = get_runtime("fused").execute(g)
    for opts in ({"overlap": False}, {"halo_via": "allgather"},
                 {"unroll": 2}):
        out = get_runtime("overlap", **opts).execute(g)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=str(opts))


def test_bsp_donate_toggle():
    g = graph("stencil_1d")
    a = get_runtime("bsp", donate=True).execute(g)
    b = get_runtime("bsp", donate=False).execute(g)
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_dispatch_accounting():
    g = graph("stencil_1d", steps=7)
    assert get_runtime("fused").dispatches_per_run(g) == 1
    assert get_runtime("bsp").dispatches_per_run(g) == 7
    assert get_runtime("bsp_scan").dispatches_per_run(g) == 1
    assert get_runtime("serialized").dispatches_per_run(g) == 7 * 16


def test_measure_returns_sane_sample():
    g = graph("stencil_1d", steps=4, kernel=KernelSpec("compute_bound", 32))
    rt = get_runtime("fused")
    sample, stats = rt.measure(g, reps=2, warmup=1)
    assert sample.wall_time > 0
    assert sample.total_flops == g.total_flops()
    assert stats.best <= stats.mean
    assert len(stats.walls) == 2


def test_unsupported_graph_raises():
    g = graph("fft")  # butterfly on 1 device is fine; force failure via width
    rt = get_runtime("bsp")
    bad = graph("stencil_1d", width=15)  # not divisible by devices=1? is ok
    # width 15 on 1 device divides; use radius > block instead
    g2 = TaskGraph(steps=3, width=4, pattern="nearest", radius=5,
                   kernel=KernelSpec("empty"))
    ok, why = rt.supports(g2)
    assert not ok and "radius" in why
    with pytest.raises(ValueError):
        rt.execute(g2)


# ------------------------------------------------- combine primitive units


def test_combine_dependencies_mean_semantics():
    import jax.numpy as jnp

    outputs = jnp.arange(4, dtype=jnp.float32)[:, None] * jnp.ones((1, 4))
    idx = jnp.array([[0, 1, 0], [2, 3, 0], [0, 0, 0], [1, 1, 1]], jnp.int32)
    mask = jnp.array([[1, 1, 0], [1, 1, 0], [1, 0, 0], [1, 1, 1]],
                     jnp.float32)
    got = combine_dependencies(outputs, idx, mask)
    np.testing.assert_allclose(np.asarray(got[0]), 0.5 * np.ones(4))
    np.testing.assert_allclose(np.asarray(got[1]), 2.5 * np.ones(4))
    np.testing.assert_allclose(np.asarray(got[2]), 0.0 * np.ones(4))
    np.testing.assert_allclose(np.asarray(got[3]), 1.0 * np.ones(4))


def test_combine_zero_deps_keeps_own_state():
    import jax.numpy as jnp

    outputs = jnp.arange(4, dtype=jnp.float32)[:, None] * jnp.ones((1, 2))
    idx = jnp.zeros((4, 1), jnp.int32)
    mask = jnp.zeros((4, 1), jnp.float32)
    got = combine_dependencies(outputs, idx, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(outputs))


def test_combine_all_to_all_is_global_mean():
    import jax.numpy as jnp

    outputs = jnp.arange(8, dtype=jnp.float32)[:, None] * jnp.ones((1, 3))
    got = np.asarray(combine_all_to_all(outputs))
    np.testing.assert_allclose(got, 3.5 * np.ones((8, 3)))
