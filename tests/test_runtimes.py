"""Cross-backend equivalence — the system's core invariant.

Every runtime backend must produce identical final states for the same task
graph (DESIGN.md §2: the backends differ ONLY in scheduling/communication
strategy, never in dataflow). Single-device here; the multi-device versions
run in test_distributed.py subprocesses.
"""
import numpy as np
import pytest

from repro.core import (
    GraphEnsemble,
    KernelSpec,
    TaskGraph,
    available_runtimes,
    get_runtime,
)
from repro.core import patterns as _patterns
from repro.core.task_kernels import (
    apply_kernel,
    combine_all_to_all,
    combine_dependencies,
    initial_state,
)

PATTERNS = ["trivial", "no_comm", "stencil_1d", "stencil_1d_periodic", "dom",
            "tree", "fft", "all_to_all", "nearest", "spread",
            "random_nearest"]


def graph(pattern, **kw):
    base = dict(steps=6, width=16, payload=8,
                kernel=KernelSpec("compute_bound", 8), radius=2, seed=3)
    base.update(kw)
    return TaskGraph(pattern=pattern, **base)


def test_registry_contents():
    names = available_runtimes()
    for expected in ("fused", "serialized", "bsp", "bsp_scan", "overlap",
                     "pallas_step"):
        assert expected in names


@pytest.mark.parametrize("pattern", PATTERNS)
@pytest.mark.parametrize("backend", ["serialized", "bsp", "bsp_scan",
                                     "overlap", "pallas_step"])
def test_backend_matches_fused(pattern, backend):
    g = graph(pattern)
    rt = get_runtime(backend)
    ok, why = rt.supports(g)
    if not ok:
        pytest.skip(why)
    ref = get_runtime("fused").execute(g)
    out = rt.execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("kind", ["compute_bound", "memory_bound", "empty"])
def test_kernel_kinds_run(kind):
    g = graph("stencil_1d", kernel=KernelSpec(kind, 4, scratch=64))
    ref = get_runtime("fused").execute(g)
    out = get_runtime("bsp_scan").execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    assert np.isfinite(ref).all()


def test_single_step_graph():
    g = graph("stencil_1d", steps=1)
    ref = get_runtime("fused").execute(g)
    out = get_runtime("bsp").execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-6)


def test_large_iterations_stay_bounded():
    """Contraction-map FMA: no inf/nan at any grain size (task_kernels)."""
    g = graph("stencil_1d", kernel=KernelSpec("compute_bound", 1 << 14))
    out = get_runtime("fused").execute(g)
    assert np.isfinite(out).all()
    assert np.abs(out).max() < 10.0


def test_overlap_variants_match():
    """Fig-3-style build options must not change semantics."""
    g = graph("stencil_1d")
    ref = get_runtime("fused").execute(g)
    for opts in ({"overlap": False}, {"halo_via": "allgather"},
                 {"unroll": 2}):
        out = get_runtime("overlap", **opts).execute(g)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=str(opts))


def test_bsp_donate_toggle():
    g = graph("stencil_1d")
    a = get_runtime("bsp", donate=True).execute(g)
    b = get_runtime("bsp", donate=False).execute(g)
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_dispatch_accounting():
    g = graph("stencil_1d", steps=7)
    assert get_runtime("fused").dispatches_per_run(g) == 1
    assert get_runtime("bsp").dispatches_per_run(g) == 7
    assert get_runtime("bsp_scan").dispatches_per_run(g) == 1
    # pallas_step reports actual KERNEL LAUNCHES (the overhead its METG
    # floor measures), not host dispatches: one t=0 body-only launch plus
    # ceil((T-1)/S) blocked combine launches. The (default) pipelined
    # schedule pays TWO launches per blocked iteration — boundary +
    # interior — and the accounting stays honest about it.
    assert get_runtime("pallas_step").dispatches_per_run(g) == 7
    assert get_runtime(
        "pallas_step", steps_per_launch=3).dispatches_per_run(g) == 5
    assert get_runtime("pallas_step", steps_per_launch=3,
                       pipeline=False).dispatches_per_run(g) == 3
    assert get_runtime("pallas_step", steps_per_launch=6,
                       pipeline=False).dispatches_per_run(g) == 2
    # depth clamps to the graph's T-1 combine steps (rest is masked tail)
    assert get_runtime("pallas_step", steps_per_launch=100,
                       pipeline=False).dispatches_per_run(g) == 2
    assert get_runtime(
        "pallas_step", steps_per_launch=100).dispatches_per_run(g) == 3
    assert get_runtime(
        "pallas_step").dispatches_per_run(graph("stencil_1d", steps=1)) == 1
    assert get_runtime("serialized").dispatches_per_run(g) == 7 * 16


# ------------------------------------------------ pallas_step (megakernel)


@pytest.mark.parametrize("pattern", list(_patterns.HALO_PATTERNS))
@pytest.mark.parametrize("K", [1, 4])
def test_pallas_step_halo_patterns_ensembles(pattern, K):
    """Acceptance: pallas_step runs every HALO_PATTERNS pattern and matches
    fused per ensemble member for K in {1, 4} (interpret mode)."""
    members = [
        TaskGraph(steps=5, width=16, payload=8, pattern=pattern, radius=2,
                  kernel=KernelSpec("compute_bound", 8), seed=k)
        for k in range(K)
    ]
    ens = GraphEnsemble(members)
    rt = get_runtime("pallas_step")
    ok, why = rt.supports_ensemble(ens)
    assert ok, why
    outs = rt.execute_ensemble(ens)
    for k, (g, out) in enumerate(zip(members, outs)):
        ref = get_runtime("fused").execute(g)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=f"{pattern} member {k}")


@pytest.mark.parametrize("combine", ["window", "gather", "onehot"])
def test_pallas_step_combine_modes_match_fused(combine):
    g = graph("nearest")
    ref = get_runtime("fused").execute(g)
    out = get_runtime("pallas_step", combine=combine).execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                               err_msg=combine)


def test_pallas_step_kernel_kinds():
    for kind in ("compute_bound", "memory_bound", "empty"):
        g = graph("stencil_1d", kernel=KernelSpec(kind, 4, scratch=64))
        ref = get_runtime("fused").execute(g)
        out = get_runtime("pallas_step").execute(g)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=kind)


HALO_LIKE = list(_patterns.HALO_PATTERNS) + ["random_nearest"]


@pytest.mark.parametrize("pattern", HALO_LIKE)
@pytest.mark.parametrize("S", [3, 8])
def test_pallas_step_blocked_matches_unblocked_and_fused(pattern, S):
    """Temporal blocking is a pure scheduling change: for every halo
    pattern, S steps per launch must be allclose to the S=1 path AND the
    fused oracle (T=7 with S=3 exercises the masked tail: 6 combine steps
    = 2 launches; with S=8 the whole run is one partially-masked launch)."""
    g = graph(pattern, steps=7)
    ref = get_runtime("fused").execute(g)
    s1 = get_runtime("pallas_step").execute(g)
    out = get_runtime("pallas_step", steps_per_launch=S).execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                               err_msg=f"{pattern} S={S} vs fused")
    np.testing.assert_allclose(out, s1, rtol=1e-5, atol=1e-6,
                               err_msg=f"{pattern} S={S} vs S=1")


@pytest.mark.parametrize("combine", ["window", "gather", "onehot"])
def test_pallas_step_blocked_combine_modes_match_fused(combine):
    g = graph("nearest", steps=8)
    ref = get_runtime("fused").execute(g)
    out = get_runtime("pallas_step", combine=combine,
                      steps_per_launch=4).execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                               err_msg=combine)


def test_pallas_step_blocked_kernel_kinds():
    for kind in ("compute_bound", "memory_bound", "empty"):
        g = graph("stencil_1d", kernel=KernelSpec(kind, 4, scratch=64))
        ref = get_runtime("fused").execute(g)
        out = get_runtime("pallas_step", steps_per_launch=3).execute(g)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=kind)


@pytest.mark.parametrize("S", [1, 3, 8])
def test_pallas_step_blocked_hetero_steps_ensemble(S):
    """Launch-granularity freezing: members with different T inside one
    blocked stacked ensemble each match running alone under fused (members
    end mid-launch, so the act mask must freeze them at inner-step
    granularity)."""
    members = [
        TaskGraph(steps=t, width=16, payload=8, pattern="stencil_1d",
                  kernel=KernelSpec("compute_bound", 8), seed=k)
        for k, t in enumerate((3, 6, 1, 5))
    ]
    ens = GraphEnsemble(members)
    assert ens.heterogeneous_steps
    outs = get_runtime("pallas_step", steps_per_launch=S).execute_ensemble(ens)
    for k, (g, out) in enumerate(zip(members, outs)):
        ref = get_runtime("fused").execute(g)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=f"S={S} member {k} T={g.steps}")


def test_pallas_step_blocked_mixed_spec_tuple_ensemble():
    """The mixed-spec (tuple) fallback blocks too: different kernels,
    patterns, and T per member, one shared launch cadence."""
    members = [
        TaskGraph(steps=5, width=16, payload=8, pattern="stencil_1d",
                  kernel=KernelSpec("compute_bound", 8), seed=0),
        TaskGraph(steps=3, width=16, payload=8, pattern="nearest", radius=2,
                  kernel=KernelSpec("compute_bound", 32), seed=1),
        TaskGraph(steps=7, width=16, payload=8, pattern="no_comm",
                  kernel=KernelSpec("memory_bound", 2, scratch=32), seed=2),
    ]
    ens = GraphEnsemble(members)
    rt = get_runtime("pallas_step", steps_per_launch=4)
    outs = rt.execute_ensemble(ens)
    for k, (g, out) in enumerate(zip(members, outs)):
        ref = get_runtime("fused").execute(g)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=f"member {k}")


def test_pallas_step_deep_halo_exceeding_width_wraps():
    """S*r far beyond W (depth wraps the ring repeatedly) stays exact."""
    g = graph("stencil_1d_periodic", steps=10, width=8)
    ref = get_runtime("fused").execute(g)
    out = get_runtime("pallas_step", steps_per_launch=8).execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


def test_pallas_step_auto_steps_per_launch():
    """'auto' resolves through kernels/schedule.py and stays exact."""
    g = graph("stencil_1d", steps=9)
    ref = get_runtime("fused").execute(g)
    rt = get_runtime("pallas_step", steps_per_launch="auto")
    out = rt.execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    # auto picks a deep schedule for this tiny shape -> few launches
    assert rt.dispatches_per_run(g) < g.steps


# ----------------------------- pallas_step pipelined deep-halo exchange


@pytest.mark.parametrize("pattern", HALO_LIKE)
@pytest.mark.parametrize("S", [1, 3, 8])
def test_pallas_step_pipeline_bit_identical_to_ablation(pattern, S):
    """The pipelined schedule is a pure dataflow reshuffle: for every halo
    pattern and S in {1, 3, 8}, pipeline=True must be BIT-identical to the
    pipeline=False ablation and allclose to fused. Width 48 keeps a
    nonempty interior at every depth (r=1 patterns at S=8: 48 > 16; r=2:
    48 > 32), so the pipelined path actually engages for S > 1."""
    g = graph(pattern, width=48, steps=10)
    ref = get_runtime("fused").execute(g)
    on = get_runtime("pallas_step", steps_per_launch=S).execute(g)
    off = get_runtime(
        "pallas_step", steps_per_launch=S, pipeline=False).execute(g)
    np.testing.assert_allclose(on, ref, rtol=1e-5, atol=1e-6,
                               err_msg=f"{pattern} S={S} vs fused")
    assert np.array_equal(on, off), f"{pattern} S={S}: pipeline changed bits"


def test_pallas_step_pipeline_halo_impls_bit_identical():
    """Both edge-exchange transports (fused single-collective vs
    per-direction ppermute) move exact row copies; outputs must not differ
    by a bit. Unknown impls fail loudly."""
    g = graph("stencil_1d", width=48, steps=10)
    a = get_runtime("pallas_step", steps_per_launch=4).execute(g)
    b = get_runtime("pallas_step", steps_per_launch=4,
                    halo_impl="ppermute").execute(g)
    assert np.array_equal(a, b)
    with pytest.raises(ValueError, match="halo async impl"):
        get_runtime("pallas_step", steps_per_launch=4,
                    halo_impl="smoke_signals").execute(g)


@pytest.mark.parametrize("S", [3, 4])
def test_pallas_step_pipeline_hetero_stacked_ensemble(S):
    """Pipelined stacked ensembles keep launch-granularity freezing exact:
    members with different T (ending mid-launch) each match fused, and the
    whole run is bit-identical to the serial-exchange ablation."""
    members = [
        TaskGraph(steps=t, width=48, payload=8, pattern="stencil_1d",
                  kernel=KernelSpec("compute_bound", 8), seed=k)
        for k, t in enumerate((3, 10, 6, 1))
    ]
    ens = GraphEnsemble(members)
    assert ens.heterogeneous_steps
    on = get_runtime(
        "pallas_step", steps_per_launch=S).execute_ensemble(ens)
    off = get_runtime("pallas_step", steps_per_launch=S,
                      pipeline=False).execute_ensemble(ens)
    for k, (g, a, b) in enumerate(zip(members, on, off)):
        ref = get_runtime("fused").execute(g)
        np.testing.assert_allclose(a, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=f"S={S} member {k} T={g.steps}")
        assert np.array_equal(a, b), f"S={S} member {k}: pipeline changed bits"


def test_pallas_step_pipeline_tuple_mixed_applicability():
    """The tuple path pipelines per member: a no_comm member (halo 0) and a
    wide-halo member share one cadence with a pipelined stencil member, and
    every member still matches fused."""
    members = [
        TaskGraph(steps=9, width=48, payload=8, pattern="stencil_1d",
                  kernel=KernelSpec("compute_bound", 8), seed=0),
        TaskGraph(steps=5, width=48, payload=8, pattern="no_comm",
                  kernel=KernelSpec("memory_bound", 2, scratch=32), seed=1),
        TaskGraph(steps=7, width=48, payload=8, pattern="nearest", radius=4,
                  kernel=KernelSpec("compute_bound", 32), seed=2),
    ]
    ens = GraphEnsemble(members)
    for pipe in (True, False):
        outs = get_runtime("pallas_step", steps_per_launch=4,
                           pipeline=pipe).execute_ensemble(ens)
        for k, (g, out) in enumerate(zip(members, outs)):
            ref = get_runtime("fused").execute(g)
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                       err_msg=f"pipe={pipe} member {k}")


def test_pallas_step_pipeline_auto_respects_profitability():
    """Under steps_per_launch='auto' the tuner's covering verdict binds:
    a block too small for the interior to cover the exchange runs the
    serial schedule (serial launch counts), while explicit S is an
    ablation choice and pipelines whenever structurally possible."""
    g = graph("stencil_1d", width=64, steps=9)
    auto = get_runtime("pallas_step", steps_per_launch="auto")
    S = auto._graph_steps_per_launch(g)
    assert 64 > 2 * S  # structurally pipelineable ...
    L = 1 + -(-(g.steps - 1) // S)
    assert auto.dispatches_per_run(g) == L  # ... but the tuner found no cover
    explicit = get_runtime("pallas_step", steps_per_launch=S)
    assert explicit.dispatches_per_run(g) == 1 + 2 * (L - 1)  # pipelines anyway


# ------------------------- pallas_step beyond halos: pattern -> plan


def test_pallas_step_plan_dispatch_and_rejection_message():
    """supports() is a pattern->plan dispatch: every paper pattern gets a
    plan at moderate widths, and the rejection (global pattern past the
    gather cap) names the plan kinds and the fused fallback."""
    rt = get_runtime("pallas_step")
    assert rt.plan_for(graph("stencil_1d"))[0] == "halo"
    assert rt.plan_for(graph("random_nearest"))[0] == "halo"
    assert rt.plan_for(graph("fft"))[0] == "stride"
    assert rt.plan_for(graph("tree"))[0] == "stride"
    assert rt.plan_for(graph("spread"))[0] == "allgather"
    assert rt.plan_for(graph("all_to_all"))[0] == "allgather"
    capped = get_runtime("pallas_step", gather_width_cap=64)
    ok, why = capped.supports(graph("spread", width=128))
    assert not ok
    for needle in ("halo", "stride", "allgather", "fused",
                   "gather_width_cap=64"):
        assert needle in why, why
    # butterfly keeps the (per-step) stride plan at ANY width
    ok, _ = capped.supports(graph("fft", width=128))
    assert ok
    # width-1 butterfly degenerates to a self-dependency: no stride plan
    # (its two-dep tables would be wrong) — the all-gather plan runs it
    g1 = graph("fft", width=1)
    assert rt.plan_for(g1)[0] == "allgather"
    out = rt.execute(g1)
    np.testing.assert_array_equal(out, get_runtime("fused").execute(g1))
    # "pair" is the stride plan's INTERNAL lowering, not a runtime option
    # — rejected up front (it would crash the halo operand layout deep in
    # the kernel otherwise), like any unknown mode
    for bad in ("pair", "smoke_signals"):
        with pytest.raises(ValueError, match="combine option"):
            get_runtime("pallas_step", combine=bad).execute(
                graph("stencil_1d"))


BUTTERFLY = list(_patterns.BUTTERFLY_PATTERNS)


@pytest.mark.parametrize("pattern", BUTTERFLY)
@pytest.mark.parametrize("S", [1, 3, 8])
def test_pallas_step_butterfly_bit_identical_to_fused(pattern, S):
    """Acceptance: fft/tree run BIT-identical to the fused oracle at every
    S (stride plan per-step; blocked requests route through the gathered
    plan's time-varying per-depth tables). Power-of-two widths make every
    butterfly combine weight exactly 0.5, so 0.5*a + 0.5*b must equal the
    oracle's (a + b) / 2 to the last bit. T=7 with S=3 exercises the
    masked tail; S=8 clamps to one fully-masked-tail launch."""
    g = graph(pattern, steps=7)
    ref = get_runtime("fused").execute(g)
    out = get_runtime("pallas_step", steps_per_launch=S).execute(g)
    assert np.array_equal(out, ref), f"{pattern} S={S}: bits differ"


@pytest.mark.parametrize("pattern", ["spread", "all_to_all"])
@pytest.mark.parametrize("S", [1, 4])
def test_pallas_step_global_patterns_match_fused(pattern, S):
    """The all-gather plan (spread's in-scan rotation, all_to_all's static
    global tables) matches fused at S in {1, 4}."""
    g = graph(pattern, steps=7)
    ref = get_runtime("fused").execute(g)
    out = get_runtime("pallas_step", steps_per_launch=S).execute(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                               err_msg=f"{pattern} S={S}")


@pytest.mark.parametrize("combine", ["window", "gather", "onehot"])
@pytest.mark.parametrize("pattern", ["fft", "spread"])
def test_pallas_step_nonhalo_combine_modes(pattern, combine):
    """Non-halo plans accept every combine option ("window" maps to the
    onehot lowering) in both the per-step and blocked schedules."""
    g = graph(pattern, steps=6)
    ref = get_runtime("fused").execute(g)
    for S in (1, 3):
        out = get_runtime("pallas_step", combine=combine,
                          steps_per_launch=S).execute(g)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=f"{pattern} {combine} S={S}")


def test_pallas_step_butterfly_dispatch_accounting():
    """Launch accounting mirrors the executed plan exactly: the stride
    plan is per-step BY CONSTRUCTION, so a butterfly run only drops below
    T launches when the blocked request actually re-routes through the
    all-gather plan (width under the cap)."""
    g = graph("fft", steps=7)  # W=16
    assert get_runtime("pallas_step").dispatches_per_run(g) == 7
    # blocked request -> gathered plan: 1 + ceil(6/3) launches
    assert get_runtime(
        "pallas_step", steps_per_launch=3).dispatches_per_run(g) == 3
    # width over the cap: per-step stride plan regardless of the request
    assert get_runtime("pallas_step", steps_per_launch=3,
                       gather_width_cap=8).dispatches_per_run(g) == 7
    # "auto" KEEPS the stride plan (the gathered pays-off model ranks
    # blocked gathers against per-step gathers, not against the cheaper
    # stride plan it would displace) — only an explicit depth re-routes
    auto = get_runtime("pallas_step", steps_per_launch="auto")
    assert auto.dispatches_per_run(g) == g.steps
    ref = get_runtime("fused").execute(g)
    assert np.array_equal(auto.execute(g), ref)


def test_pallas_step_gather_transports_bit_identical():
    """Both stride/gather transports (fused all-gather vs per-collective
    ppermute) move exact row copies; outputs must not differ by a bit."""
    for pattern in ("fft", "spread"):
        g = graph(pattern, steps=6)
        a = get_runtime("pallas_step").execute(g)
        b = get_runtime("pallas_step", halo_impl="ppermute").execute(g)
        assert np.array_equal(a, b), pattern


def test_pallas_step_mixed_plan_ensemble():
    """A tuple ensemble mixing all three plans (halo stencil, stride fft,
    allgather spread) with heterogeneous steps: one jitted scan, shared
    per-step cadence, every member matches running alone under fused."""
    base = dict(width=16, payload=8)
    members = [
        TaskGraph(steps=6, pattern="stencil_1d",
                  kernel=KernelSpec("compute_bound", 8), seed=0, **base),
        TaskGraph(steps=4, pattern="fft",
                  kernel=KernelSpec("compute_bound", 4), seed=1, **base),
        TaskGraph(steps=7, pattern="spread", fanout=3,
                  kernel=KernelSpec("compute_bound", 16), seed=2, **base),
        TaskGraph(steps=2, pattern="all_to_all",
                  kernel=KernelSpec("compute_bound", 8), seed=3, **base),
    ]
    ens = GraphEnsemble(members)
    for S in (1, 4):  # non-halo members pin the shared cadence to per-step
        rt = get_runtime("pallas_step", steps_per_launch=S)
        outs = rt.execute_ensemble(ens)
        for k, (g, out) in enumerate(zip(members, outs)):
            ref = get_runtime("fused").execute(g)
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                       err_msg=f"S={S} member {k}")
        # per-step cadence -> every member launches every lockstep step
        assert rt.ensemble_dispatches_per_run(ens) == len(members) * ens.steps


def test_measure_returns_sane_sample():
    g = graph("stencil_1d", steps=4, kernel=KernelSpec("compute_bound", 32))
    rt = get_runtime("fused")
    sample, stats = rt.measure(g, reps=2, warmup=1)
    assert sample.wall_time > 0
    assert sample.total_flops == g.total_flops()
    assert stats.best <= stats.mean
    assert len(stats.walls) == 2


def test_unsupported_graph_raises():
    g = graph("fft")  # butterfly on 1 device is fine; force failure via width
    rt = get_runtime("bsp")
    bad = graph("stencil_1d", width=15)  # not divisible by devices=1? is ok
    # width 15 on 1 device divides; use radius > block instead
    g2 = TaskGraph(steps=3, width=4, pattern="nearest", radius=5,
                   kernel=KernelSpec("empty"))
    ok, why = rt.supports(g2)
    assert not ok and "radius" in why
    with pytest.raises(ValueError):
        rt.execute(g2)


# ---------------------------------------------------------- graph ensembles


def mixed_ensemble(**kw):
    """Mixed patterns, grains, and seeds; stackable (uniform width/payload)."""
    base = dict(steps=6, width=16, payload=8, seed=0)
    base.update(kw)
    return GraphEnsemble([
        TaskGraph(pattern="stencil_1d",
                  kernel=KernelSpec("compute_bound", 8), **base),
        TaskGraph(pattern="nearest", radius=2,
                  kernel=KernelSpec("compute_bound", 32),
                  **{**base, "seed": base["seed"] + 1}),
        TaskGraph(pattern="fft",
                  kernel=KernelSpec("compute_bound", 4),
                  **{**base, "seed": base["seed"] + 2}),
    ])


@pytest.mark.parametrize("backend", ["fused", "serialized", "bsp",
                                     "bsp_scan", "overlap", "pallas_step"])
def test_ensemble_members_match_fused(backend):
    """Core invariant, ensemble edition: every backend's concurrent run must
    reproduce, per member, the state of running that member alone."""
    ens = mixed_ensemble()
    rt = get_runtime(backend)
    ok, why = rt.supports_ensemble(ens)
    if not ok:  # overlap refuses fft — swap in a halo-only ensemble for it
        ens = GraphEnsemble([g for g in ens
                             if rt.supports(g)[0]])
        assert len(ens) >= 2, why
    outs = rt.execute_ensemble(ens)
    for k, (g, out) in enumerate(zip(ens.members, outs)):
        ref = get_runtime("fused").execute(g)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=f"{backend} member {k}")


def test_ensemble_heterogeneous_shapes():
    """Non-stackable members (different width/payload) run via the
    tuple-carry fallback and still match per-member fused."""
    ens = GraphEnsemble([
        TaskGraph(steps=5, width=16, payload=8, pattern="stencil_1d", seed=1),
        TaskGraph(steps=5, width=8, payload=4, pattern="all_to_all", seed=2),
        TaskGraph(steps=5, width=32, payload=8, pattern="spread", fanout=3,
                  seed=3),
    ])
    assert not ens.stackable
    for backend in ("fused", "serialized", "bsp", "bsp_scan"):
        outs = get_runtime(backend).execute_ensemble(ens)
        for g, out in zip(ens.members, outs):
            ref = get_runtime("fused").execute(g)
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                       err_msg=backend)


def test_ensemble_validation():
    g = TaskGraph(steps=4, width=8)
    with pytest.raises(ValueError):
        GraphEnsemble([])
    with pytest.raises(ValueError):
        GraphEnsemble([g, TaskGraph(steps=4, width=4)]).dependency_arrays()


def test_ensemble_heterogeneous_steps_metadata():
    """Mismatched steps are allowed: lockstep T = max, members report own."""
    ens = GraphEnsemble([TaskGraph(steps=4, width=8),
                         TaskGraph(steps=7, width=8),
                         TaskGraph(steps=1, width=8)])
    assert ens.steps == 7
    assert ens.member_steps == (4, 7, 1)
    assert ens.heterogeneous_steps
    assert ens.num_tasks == (4 + 7 + 1) * 8
    assert not GraphEnsemble([TaskGraph(steps=4, width=8)]).heterogeneous_steps


@pytest.mark.parametrize("backend", ["fused", "serialized", "bsp",
                                     "bsp_scan", "overlap", "pallas_step"])
def test_ensemble_heterogeneous_steps_match_fused(backend):
    """Masked freezing: a member whose T is exhausted carries its final
    state unchanged, so member k of the lockstep run == running member k
    alone (its own T) under fused — for EVERY backend."""
    base = dict(width=16, payload=8)
    members = [
        TaskGraph(steps=3, pattern="stencil_1d",
                  kernel=KernelSpec("compute_bound", 8), seed=0, **base),
        TaskGraph(steps=6, pattern="nearest", radius=2,
                  kernel=KernelSpec("compute_bound", 32), seed=1, **base),
        TaskGraph(steps=4, pattern="fft",
                  kernel=KernelSpec("compute_bound", 4), seed=2, **base),
        TaskGraph(steps=1, pattern="dom",
                  kernel=KernelSpec("compute_bound", 8), seed=3, **base),
    ]
    ens = GraphEnsemble(members)
    rt = get_runtime(backend)
    ok, why = rt.supports_ensemble(ens)
    if not ok:  # overlap/pallas_step refuse fft — drop unsupported members
        ens = GraphEnsemble([g for g in members if rt.supports(g)[0]])
        assert len(ens) >= 3, why
        assert ens.heterogeneous_steps
    outs = rt.execute_ensemble(ens)
    for k, (g, out) in enumerate(zip(ens.members, outs)):
        ref = get_runtime("fused").execute(g)
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                   err_msg=f"{backend} member {k} T={g.steps}")


def test_ensemble_heterogeneous_steps_nonstackable():
    """Freezing also holds on the ragged-shape (tuple-carry) paths."""
    members = [
        TaskGraph(steps=5, width=16, payload=8, pattern="stencil_1d", seed=1),
        TaskGraph(steps=2, width=8, payload=4, pattern="all_to_all", seed=2),
        TaskGraph(steps=7, width=32, payload=8, pattern="spread", fanout=3,
                  seed=3),
    ]
    ens = GraphEnsemble(members)
    assert not ens.stackable and ens.heterogeneous_steps
    for backend in ("fused", "serialized", "bsp", "bsp_scan"):
        outs = get_runtime(backend).execute_ensemble(ens)
        for g, out in zip(members, outs):
            ref = get_runtime("fused").execute(g)
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                       err_msg=backend)


def test_ensemble_heterogeneous_steps_dispatch_accounting():
    """Frozen members must not be charged dispatches past their own T."""
    ens = GraphEnsemble([TaskGraph(steps=3, width=8),
                         TaskGraph(steps=7, width=8)])
    assert get_runtime("bsp").ensemble_dispatches_per_run(ens) == 3 + 7
    assert (get_runtime("serialized").ensemble_dispatches_per_run(ens)
            == (3 + 7) * 8)
    # stacked ensemble: ALL members share each launch -> lockstep launches
    # (1 body launch + ceil((Tmax-1)/S) combine launches), not 1; the
    # pipelined default splits each combine launch into boundary + interior
    assert get_runtime("pallas_step").ensemble_dispatches_per_run(ens) == 7
    assert get_runtime(
        "pallas_step", steps_per_launch=3,
        pipeline=False).ensemble_dispatches_per_run(ens) == 3
    assert get_runtime(
        "pallas_step", steps_per_launch=3).ensemble_dispatches_per_run(ens) == 5
    # mixed-spec (tuple) fallback launches each member every scan iteration
    mixed = GraphEnsemble([
        TaskGraph(steps=3, width=8),
        TaskGraph(steps=7, width=8, kernel=KernelSpec("compute_bound", 99)),
    ])
    assert get_runtime("pallas_step").ensemble_dispatches_per_run(mixed) == 14
    assert get_runtime(
        "pallas_step", steps_per_launch=3, pipeline=False
    ).ensemble_dispatches_per_run(mixed) == 6
    assert get_runtime(
        "pallas_step", steps_per_launch=3
    ).ensemble_dispatches_per_run(mixed) == 10


def test_ensemble_padded_dependency_arrays():
    ens = mixed_ensemble()
    idx, mask, periods = ens.dependency_arrays()
    K, Pmax, W, Dmax = idx.shape
    assert K == 3 and W == 16
    assert Pmax == max(g.period for g in ens.members)
    assert Dmax == max(g.max_deps for g in ens.members)
    assert list(periods) == [g.period for g in ens.members]
    # padded slices must reproduce each member's own arrays exactly
    for k, g in enumerate(ens.members):
        gi, gm = g.dependency_arrays()
        D = gi.shape[2]
        for s in range(Pmax):
            np.testing.assert_array_equal(idx[k, s, :, :D], gi[s % g.period])
            np.testing.assert_array_equal(mask[k, s, :, :D], gm[s % g.period])
            assert (mask[k, s, :, D:] == 0).all()


def test_ensemble_dispatch_accounting():
    ens = mixed_ensemble(steps=7)
    per_member_tasks = sum(g.num_tasks for g in ens.members)
    assert get_runtime("fused").ensemble_dispatches_per_run(ens) == 1
    assert get_runtime("bsp_scan").ensemble_dispatches_per_run(ens) == 1
    assert get_runtime("bsp").ensemble_dispatches_per_run(ens) == 7 * 3
    assert (get_runtime("serialized").ensemble_dispatches_per_run(ens)
            == per_member_tasks)


def test_ensemble_single_member_matches_single_graph():
    g = graph("stencil_1d")
    ens = GraphEnsemble([g])
    for backend in available_runtimes():
        out = get_runtime(backend).execute_ensemble(ens)[0]
        ref = get_runtime(backend).execute(g)
        np.testing.assert_allclose(out, ref, rtol=1e-6, err_msg=backend)


def test_measure_ensemble_aggregates():
    ens = mixed_ensemble(steps=4)
    sample, stats = get_runtime("fused").measure_ensemble(ens, reps=2,
                                                          warmup=1)
    assert sample.num_tasks == sum(g.num_tasks for g in ens.members)
    assert sample.total_flops == pytest.approx(
        sum(g.total_flops() for g in ens.members))
    assert sample.wall_time == stats.best > 0
    assert len(stats.walls) == 2


# ------------------------------------------------- combine primitive units


def test_combine_dependencies_mean_semantics():
    import jax.numpy as jnp

    outputs = jnp.arange(4, dtype=jnp.float32)[:, None] * jnp.ones((1, 4))
    idx = jnp.array([[0, 1, 0], [2, 3, 0], [0, 0, 0], [1, 1, 1]], jnp.int32)
    mask = jnp.array([[1, 1, 0], [1, 1, 0], [1, 0, 0], [1, 1, 1]],
                     jnp.float32)
    got = combine_dependencies(outputs, idx, mask)
    np.testing.assert_allclose(np.asarray(got[0]), 0.5 * np.ones(4))
    np.testing.assert_allclose(np.asarray(got[1]), 2.5 * np.ones(4))
    np.testing.assert_allclose(np.asarray(got[2]), 0.0 * np.ones(4))
    np.testing.assert_allclose(np.asarray(got[3]), 1.0 * np.ones(4))


def test_combine_zero_deps_keeps_own_state():
    import jax.numpy as jnp

    outputs = jnp.arange(4, dtype=jnp.float32)[:, None] * jnp.ones((1, 2))
    idx = jnp.zeros((4, 1), jnp.int32)
    mask = jnp.zeros((4, 1), jnp.float32)
    got = combine_dependencies(outputs, idx, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(outputs))


def test_combine_all_to_all_is_global_mean():
    import jax.numpy as jnp

    outputs = jnp.arange(8, dtype=jnp.float32)[:, None] * jnp.ones((1, 3))
    got = np.asarray(combine_all_to_all(outputs))
    np.testing.assert_allclose(got, 3.5 * np.ones((8, 3)))
