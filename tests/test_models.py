"""Per-arch reduced smoke tests + model component units.

Every assigned architecture instantiates its reduced() config and runs one
forward/train step on CPU asserting output shapes + no NaNs (assignment
requirement), plus a prefill->decode consistency check: decoding the next
token with a cache must match slicing a longer teacher-forced forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, cells, get_config
from repro.models.model import Model, build_model

ARCH_NAMES = sorted(ARCHS)


def make_batch(cfg, B=2, S=16, key=0):
    k = jax.random.PRNGKey(key)
    kt, ke, ki = jax.random.split(k, 3)
    toks = jax.random.randint(kt, (B, S + 1), 0, cfg.vocab, jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.embed_inputs:
        batch["embeds"] = 0.02 * jax.random.normal(ke, (B, S, cfg.d_model))
    if cfg.n_image_tokens:
        batch["image_embeds"] = 0.02 * jax.random.normal(
            ki, (B, cfg.n_image_tokens, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).reduced()
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


# ------------------------------------------------------------- smoke steps


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_shapes_and_finite(models, name):
    cfg, model, params = models(name)
    batch = make_batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all()
    # structural match params <-> grads
    assert jax.tree.structure(grads) == jax.tree.structure(params)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_hidden_shape(models, name):
    cfg, model, params = models(name)
    B, S = 2, 16
    batch = make_batch(cfg, B, S)
    hidden, caches, aux = model.forward(params, batch, mode="train")
    assert hidden.shape == (B, S, cfg.d_model)
    assert caches is None
    assert np.isfinite(np.asarray(hidden)).all()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_consistency(models, name):
    """Teacher-forced forward over S+1 tokens == prefill(S) + decode(1)."""
    cfg, model, params = models(name)
    B, S = 1, 12
    full = make_batch(cfg, B, S + 1, key=5)
    pre = {k: (v[:, :S] if k in ("tokens", "embeds") else v)
           for k, v in full.items() if k != "labels"}

    # ground truth: last-position logits of a full prefill over S+1 tokens
    full_nolabels = {k: v for k, v in full.items() if k != "labels"}
    logits_full, _ = model.prefill(params, full_nolabels)

    # prefill S, then decode token S
    logits_pre, caches = model.prefill(params, pre)
    caches = jax.tree.map(
        lambda x: x, caches)
    # grow caches to S+1 capacity
    grown = model.init_caches(B, S + 1)

    def fit(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        pads = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src.astype(dst.dtype), pads)

    caches = jax.tree.map(fit, grown, caches)
    tok = {"tokens": full["tokens"][:, S:S + 1]}
    if cfg.embed_inputs:
        tok = {"embeds": full["embeds"][:, S:S + 1]}
    lengths = jnp.full((B,), S, jnp.int32)
    logits_dec, _ = model.decode_step(params, tok, lengths, caches)

    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full),
        rtol=2e-2, atol=2e-2,
    )


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_cache_roundtrip_multi_token(models, name):
    """Decoding 3 tokens sequentially keeps shapes/finiteness stable."""
    cfg, model, params = models(name)
    B, S0 = 2, 8
    pre = {k: v for k, v in make_batch(cfg, B, S0, key=2).items()
           if k != "labels"}
    _, caches = model.prefill(params, pre)
    grown = model.init_caches(B, S0 + 4)

    def fit(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        pads = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src.astype(dst.dtype), pads)

    caches = jax.tree.map(fit, grown, caches)
    lengths = jnp.full((B,), S0, jnp.int32)
    tok = {"tokens": jnp.ones((B, 1), jnp.int32)}
    if cfg.embed_inputs:
        tok = {"embeds": jnp.full((B, 1, cfg.d_model), 0.01)}
    for _ in range(3):
        logits, caches = model.decode_step(params, tok, lengths, caches)
        assert logits.shape == (B, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()
        lengths = lengths + 1


def test_int8_kv_cache_decode_close_to_bf16():
    """kv_quant=True decode logits track the unquantized path (int8 error
    bounded by per-position scales)."""
    import dataclasses

    cfg = get_config("internlm2-1.8b").reduced()
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    model, model_q = build_model(cfg), build_model(cfg_q)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    pre = {k: v for k, v in make_batch(cfg, B, S, key=7).items()
           if k != "labels"}
    lg, caches = model.prefill(params, pre)
    lg_q, caches_q = model_q.prefill(params, pre)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg_q), rtol=2e-2,
                               atol=2e-2)  # prefill logits identical-ish
    # grow + one decode step each
    for m, c in ((model, caches), (model_q, caches_q)):
        grown = m.init_caches(B, S + 2)

        def fit(dst, src):
            if dst.shape == src.shape:
                return src.astype(dst.dtype)
            pads = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src.astype(dst.dtype), pads)

        c = jax.tree.map(fit, grown, c)
        tok = {"tokens": jnp.ones((B, 1), jnp.int32)}
        logits, _ = m.decode_step(params, tok, jnp.full((B,), S, jnp.int32), c)
        if m is model:
            base = logits
    np.testing.assert_allclose(np.asarray(logits), np.asarray(base),
                               rtol=0.08, atol=0.08)
    # the quantized cache stores int8 + scales
    leaves = jax.tree.leaves(model_q.init_caches(B, 8))
    assert any(x.dtype == jnp.int8 for x in leaves)


# ------------------------------------------------------------ config sanity


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_layer_plan_covers_all_layers(name):
    cfg = get_config(name)
    assert len(cfg.layer_plan_flat()) == cfg.n_layers


def test_assigned_configs_exact():
    """The exact published hyperparameters from the assignment block."""
    a = ARCHS
    c = a["hymba-1.5b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.ssm_state) == (32, 1600, 25, 5, 5504, 32001, 16)
    c = a["mixtral-8x7b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab,
            c.n_experts, c.top_k) == (32, 4096, 32, 8, 14336, 32000, 8, 2)
    c = a["granite-moe-3b-a800m"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff_expert,
            c.vocab, c.n_experts, c.top_k) == (32, 1536, 24, 8, 512, 49155,
                                               40, 8)
    c = a["musicgen-medium"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (48, 1536, 24, 24, 6144, 2048)
    c = a["gemma3-4b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (34, 2560, 8, 4, 10240, 262144)
    c = a["internlm2-1.8b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (24, 2048, 16, 8, 8192, 92544)
    c = a["minitron-8b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 4096, 32, 8, 16384, 256000)
    c = a["stablelm-3b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 2560, 32, 32, 6912, 50304)
    c = a["llama-3.2-vision-90b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (100, 8192, 64, 8, 28672, 128256)
    c = a["mamba2-130m"]
    assert (c.n_layers, c.d_model, c.vocab, c.ssm_state) == (24, 768, 50280,
                                                             128)


def test_cells_cover_40_with_skips():
    all_cells = cells(include_skips=True)
    assert len(all_cells) == 40
    skips = [(c.name, s.name) for c, s, ok in all_cells if not ok]
    assert all(s == "long_500k" for _, s in skips)
    # exactly the pure full-attention archs skip long_500k
    assert sorted(a for a, _ in skips) == sorted([
        "granite-moe-3b-a800m", "musicgen-medium", "internlm2-1.8b",
        "minitron-8b", "stablelm-3b", "llama-3.2-vision-90b"])


def test_param_count_matches_init():
    for name in ("mamba2-130m", "internlm2-1.8b", "mixtral-8x7b",
                 "hymba-1.5b", "llama-3.2-vision-90b"):
        cfg = get_config(name).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        assert actual == cfg.param_count(), name


def test_full_param_counts_plausible():
    """Analytic param counts land near the published model sizes."""
    approx = {
        "mamba2-130m": (0.10e9, 0.18e9),
        "internlm2-1.8b": (1.5e9, 2.2e9),
        "stablelm-3b": (2.2e9, 3.3e9),
        "gemma3-4b": (3.0e9, 5.0e9),
        "minitron-8b": (7.0e9, 10e9),
        "mixtral-8x7b": (44e9, 49e9),
        "hymba-1.5b": (1.2e9, 2.0e9),
        "llama-3.2-vision-90b": (80e9, 100e9),
    }
    for name, (lo, hi) in approx.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


# ----------------------------------------------------------- MoE specifics


def test_moe_aux_loss_nonzero_and_capacity_drops():
    from repro.models.moe import moe_fwd, moe_init

    cfg = get_config("mixtral-8x7b").reduced()
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = moe_fwd(p, x, cfg, mode="train")
    assert out.shape == x.shape
    assert float(aux) > 0.0
    # decode mode: capacity exact, output finite
    out_d, _ = moe_fwd(p, x[:, :1], cfg, mode="decode")
    assert np.isfinite(np.asarray(out_d)).all()


def test_rope_positions_shift():
    from repro.models.layers import rope

    x = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 2, 16))
    p0 = jnp.arange(4)[None, :]
    out0 = rope(x, p0, 10000.0)
    out1 = rope(x, p0 + 3, 10000.0)
    assert not np.allclose(np.asarray(out0), np.asarray(out1))
    # position 0 is identity for the first (cos=1, sin=0) frequency set
    np.testing.assert_allclose(np.asarray(out0[0, 0]), np.asarray(x[0, 0]),
                               rtol=1e-5)
