"""Unit + property tests for the METG metric (the paper's §4)."""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.metg import (
    GrainSample,
    combine_grain_samples,
    compute_metg,
    default_grain_schedule,
    efficiency_curve,
)


def sample(iters, wall, flops, tasks=100, cores=4):
    return GrainSample(iterations=iters, wall_time=wall, total_flops=flops,
                       num_tasks=tasks, cores=cores)


def synthetic_sweep(overhead_per_task=1e-5, flop_rate=1e9, cores=4,
                    tasks=100):
    """Amdahl-style model: wall = tasks*(work + overhead)/cores."""
    out = []
    for iters in default_grain_schedule(1, 1 << 14, points_per_decade=4):
        flops_per_task = 2.0 * 64 * iters
        work = flops_per_task / flop_rate
        wall = tasks * (work + overhead_per_task) / cores
        out.append(sample(iters, wall, flops_per_task * tasks, tasks, cores))
    return out


def test_granularity_formula():
    s = sample(10, wall=2.0, flops=1e9, tasks=1000, cores=48)
    # paper §6.1: wall x cores / tasks
    assert s.granularity_us == pytest.approx(2.0 * 48 / 1000 * 1e6)


def test_efficiency_curve_sorted_and_peak_normalized():
    sweep = synthetic_sweep()
    curve = efficiency_curve(sweep)
    assert all(a.granularity_us <= b.granularity_us
               for a, b in zip(curve, curve[1:]))
    assert max(p.efficiency for p in curve) == pytest.approx(1.0)


def test_metg_monotone_in_overhead():
    """More per-task overhead => larger METG (the paper's core reading)."""
    m_small = compute_metg(synthetic_sweep(overhead_per_task=1e-6)).metg_us
    m_big = compute_metg(synthetic_sweep(overhead_per_task=1e-4)).metg_us
    assert m_small is not None and m_big is not None
    assert m_big > m_small


def test_metg_analytic_value():
    """With wall = tasks*(work + ovh)/cores, efficiency at grain g is
    work/(work+ovh); 50% crossing is work == ovh, i.e. granularity
    = (work + ovh) = 2*ovh."""
    ovh = 1e-5
    res = compute_metg(synthetic_sweep(overhead_per_task=ovh))
    assert res.metg_us == pytest.approx(2 * ovh * 1e6, rel=0.15)


def test_metg_unreached_when_always_inefficient():
    # efficiency never crosses 50% (flat 10%): METG None unless first point
    sweep = [sample(1, 1.0, 1e8), sample(10, 1.0, 1e9)]
    # second point has 10x the rate => first point is 10% efficient
    res = compute_metg(sweep)
    # the curve last point reaches peak => crossing exists here; build a
    # truly-flat case instead:
    flat = [sample(i, 1.0, 1e9) for i in (1, 10, 100)]
    res_flat = compute_metg(flat)
    assert res_flat.metg_us == flat[0].granularity_us  # all at 100%


def test_metg_first_sample_already_efficient():
    sweep = synthetic_sweep(overhead_per_task=0.0)
    res = compute_metg(sweep)
    assert res.metg_us == pytest.approx(
        min(s.granularity_us for s in sweep))


def test_empty_sweep():
    res = compute_metg([])
    assert res.metg_us is None


# ------------------------------------------------- ensemble sample aggregation


def test_combine_grain_samples_sums_work_keeps_wall():
    """Members of a concurrently executed ensemble share one wall clock;
    FLOPs and tasks sum; grain becomes the task-weighted mean."""
    a = sample(8, wall=0.5, flops=1e9, tasks=100, cores=4)
    b = sample(32, wall=0.4, flops=3e9, tasks=300, cores=4)
    agg = combine_grain_samples([a, b])
    assert agg.num_tasks == 400
    assert agg.total_flops == pytest.approx(4e9)
    assert agg.wall_time == 0.5  # max across members by default
    assert agg.iterations == round((8 * 100 + 32 * 300) / 400)
    assert agg.cores == 4
    # explicit ensemble wall wins
    agg2 = combine_grain_samples([a, b], wall_time=0.7)
    assert agg2.wall_time == 0.7
    # granularity follows from the aggregate: wall x cores / total tasks
    assert agg2.granularity_us == pytest.approx(0.7 * 4 / 400 * 1e6)


def test_combine_grain_samples_validates():
    a = sample(8, wall=0.5, flops=1e9, tasks=100, cores=4)
    bad = sample(8, wall=0.5, flops=1e9, tasks=100, cores=8)
    with pytest.raises(ValueError):
        combine_grain_samples([])
    with pytest.raises(ValueError):
        combine_grain_samples([a, bad])


def test_metg_on_ensemble_sweep():
    """compute_metg works unchanged on aggregated ensemble samples, and a
    K=2 ensemble with the same per-task overhead model lands at the same
    METG as K=1 (METG is intensive in ensemble size too)."""
    def ensemble_sweep(K, ovh):
        out = []
        for s1 in synthetic_sweep(overhead_per_task=ovh):
            members = [s1] * K
            agg = combine_grain_samples(
                members, wall_time=s1.wall_time * K)  # serial-equivalent wall
            out.append(agg)
        return out

    m1 = compute_metg(ensemble_sweep(1, 1e-5)).metg_us
    m2 = compute_metg(ensemble_sweep(2, 1e-5)).metg_us
    assert m1 is not None and m2 is not None
    assert m2 == pytest.approx(m1, rel=0.05)


def test_grain_schedule_monotone():
    sched = default_grain_schedule(1, 10_000, 3)
    assert sched[0] == 1
    assert all(a < b for a, b in zip(sched, sched[1:]))
    assert sched[-1] <= 10_000


@given(
    ovh=st.floats(1e-7, 1e-3),
    rate=st.floats(1e8, 1e11),
    cores=st.integers(1, 64),
)
@settings(max_examples=60, deadline=None)
def test_property_metg_scale_invariance(ovh, rate, cores):
    """METG is intensive: independent of task count; ~2*ovh in time units."""
    a = compute_metg(synthetic_sweep(ovh, rate, cores, tasks=64))
    b = compute_metg(synthetic_sweep(ovh, rate, cores, tasks=512))
    if a.metg_us is None or b.metg_us is None:
        return
    assert a.metg_us == pytest.approx(b.metg_us, rel=0.25)


@given(peak_scale=st.floats(0.1, 10.0))
@settings(max_examples=30, deadline=None)
def test_property_external_peak_scales_metg(peak_scale):
    """Supplying a larger external peak moves METG right (harder to hit 50%
    of a larger peak), never left."""
    sweep = synthetic_sweep()
    base = compute_metg(sweep)
    scaled = compute_metg(sweep, peak=base.peak_flops_per_second * peak_scale)
    if peak_scale <= 1.0:
        assert scaled.metg_us is not None
        assert scaled.metg_us <= base.metg_us * 1.001
    elif scaled.metg_us is not None:
        assert scaled.metg_us >= base.metg_us * 0.999
