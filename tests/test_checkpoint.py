"""Checkpoint/restart, failure drill, elastic resharding, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.elastic import (
    FailureInjector,
    SimulatedFailure,
    run_with_restarts,
)
from repro.configs.registry import get_config, get_shape
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch.train import train


def tree(key=0):
    k = jax.random.PRNGKey(key)
    return {
        "a": jax.random.normal(k, (4, 8)),
        "b": {"c": jnp.arange(6, dtype=jnp.int32), "d": jnp.float32(3.5)},
    }


def test_save_restore_roundtrip(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    t = tree()
    ckpt.save(5, t, {"note": "x"})
    restored, extra = ckpt.restore(t)
    assert extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_gc(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ckpt.save(s, tree())
    assert ckpt.all_steps() == [3, 4]


def test_async_save_then_restore(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    t = tree(1)
    ckpt.async_save(7, t)
    ckpt.wait()
    restored, _ = ckpt.restore(t)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]))


def test_restore_shape_mismatch_raises(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, tree())
    bad = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.zeros((6,), jnp.int32),
                                         "d": jnp.float32(0)}}
    with pytest.raises(ValueError):
        ckpt.restore(bad)


def test_atomicity_no_tmp_dirs_left(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(1, tree())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_run_with_restarts_identical_to_uninterrupted(tmp_path):
    """The headline fault-tolerance invariant: a run with an injected crash
    and restart ends bit-identical to an uninterrupted run."""

    def init_state():
        return {"x": jnp.zeros((4,)), "step_sum": jnp.float32(0)}

    def step_fn(state, step):
        return {
            "x": state["x"] + step,
            "step_sum": state["step_sum"] + step * 0.5,
        }

    ckpt_a = Checkpointer(str(tmp_path / "a"))
    final_a, restarts_a = run_with_restarts(
        total_steps=17, ckpt=ckpt_a, ckpt_every=5, init_state=init_state,
        step_fn=step_fn, injector=FailureInjector((7, 13)),
    )
    assert restarts_a == 2

    ckpt_b = Checkpointer(str(tmp_path / "b"))
    final_b, restarts_b = run_with_restarts(
        total_steps=17, ckpt=ckpt_b, ckpt_every=5, init_state=init_state,
        step_fn=step_fn,
    )
    assert restarts_b == 0
    np.testing.assert_array_equal(np.asarray(final_a["x"]),
                                  np.asarray(final_b["x"]))
    np.testing.assert_array_equal(np.asarray(final_a["step_sum"]),
                                  np.asarray(final_b["step_sum"]))


def test_injector_exhausts_restarts(tmp_path):
    ckpt = Checkpointer(str(tmp_path))

    def step_fn(state, step):
        raise SimulatedFailure("always")

    with pytest.raises(SimulatedFailure):
        run_with_restarts(
            total_steps=3, ckpt=ckpt, ckpt_every=1,
            init_state=lambda: {"x": jnp.zeros(())},
            step_fn=step_fn, max_restarts=2,
        )


def test_trainer_restart_matches_uninterrupted(tmp_path):
    """End-to-end: the real trainer with a crash at step 12 reproduces the
    uninterrupted loss trajectory (checkpoint cadence 8)."""
    cfg = get_config("internlm2-1.8b").reduced()
    shape = get_shape("train_4k")
    a = train(cfg, shape, steps=16, batch=2, seq=16,
              ckpt_dir=str(tmp_path / "x"), ckpt_every=8, fail_at=(12,),
              verbose=False, profile=False)
    b = train(cfg, shape, steps=16, batch=2, seq=16,
              ckpt_dir=str(tmp_path / "y"), ckpt_every=8,
              verbose=False, profile=False)
    assert a.restarts == 1 and b.restarts == 0
    # post-restart losses must realign: compare the last 4 steps
    np.testing.assert_allclose(a.losses[-4:], b.losses[-4:], rtol=1e-5)


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint written under one sharding restores onto another mesh
    (subprocess owns the multi-device runtime)."""
    import subprocess
    import sys
    import textwrap

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src")
    code = textwrap.dedent(f"""
        import jax, numpy as np
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.checkpoint.elastic import reshard_restore
        from repro.configs.registry import get_config, get_shape
        from repro.distributed.sharding import ShardingPolicy
        from repro.launch.mesh import make_host_mesh
        from repro.models.model import Model

        cfg = get_config("internlm2-1.8b").reduced()
        shape = get_shape("train_4k")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))

        mesh_a = make_host_mesh((2, 2, 2), ("pod", "data", "model"))
        pol_a = ShardingPolicy.for_step(cfg, shape, mesh_a)
        pa = jax.device_put(params, pol_a.param_shardings(params))
        ckpt = Checkpointer({str(tmp_path)!r})
        ckpt.save(3, pa)

        # "lost a pod": restore onto (4, 2)
        mesh_b = make_host_mesh((4, 2), ("data", "model"))
        pol_b = ShardingPolicy.for_step(cfg, shape, mesh_b)
        pb, _ = reshard_restore(ckpt, params, pol_b)
        for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(pb)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=480, env=env)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


# ------------------------------------------------------------ data pipeline


def test_pipeline_deterministic_and_stateless():
    cfg = get_config("internlm2-1.8b").reduced()
    shape = get_shape("train_4k")
    p1 = SyntheticTokenPipeline(cfg, shape, seed=3, batch_override=2,
                                seq_override=8)
    p2 = SyntheticTokenPipeline(cfg, shape, seed=3, batch_override=2,
                                seq_override=8)
    b1, b2 = p1.batch_at(11), p2.batch_at(11)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p1.batch_at(12)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_pipeline_labels_are_shifted_tokens():
    cfg = get_config("internlm2-1.8b").reduced()
    shape = get_shape("train_4k")
    p = SyntheticTokenPipeline(cfg, shape, batch_override=2, seq_override=8)
    b = p.batch_at(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))
    assert (np.asarray(b["tokens"]) < cfg.vocab).all()
    assert (np.asarray(b["tokens"]) >= 0).all()


def test_pipeline_checkpoint_roundtrip():
    cfg = get_config("internlm2-1.8b").reduced()
    shape = get_shape("train_4k")
    p = SyntheticTokenPipeline(cfg, shape, seed=5, batch_override=2,
                               seq_override=8)
    it = iter(p)
    next(it), next(it), next(it)
    sd = p.state_dict()
    q = SyntheticTokenPipeline(cfg, shape, seed=5, batch_override=2,
                               seq_override=8)
    q.load_state_dict(sd)
    np.testing.assert_array_equal(
        np.asarray(next(iter(p))["tokens"]),
        np.asarray(next(iter(q))["tokens"]))


def test_pipeline_modality_extras():
    cfg = get_config("llama-3.2-vision-90b").reduced()
    shape = get_shape("train_4k")
    p = SyntheticTokenPipeline(cfg, shape, batch_override=2, seq_override=8)
    b = p.batch_at(0)
    assert b["image_embeds"].shape == (2, cfg.n_image_tokens, cfg.d_model)
    cfg2 = get_config("musicgen-medium").reduced()
    p2 = SyntheticTokenPipeline(cfg2, shape, batch_override=2, seq_override=8)
    b2 = p2.batch_at(0)
    assert b2["embeds"].shape == (2, 8, cfg2.d_model)


# ------------------------------------------------ content checksums (PR 8)


def test_manifest_records_content_checksum(tmp_path):
    import json

    ckpt = Checkpointer(str(tmp_path))
    path = ckpt.save(1, tree())
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["checksum"]["algo"] == "sha256"
    assert len(manifest["checksum"]["digest"]) == 64


def test_restore_rejects_corrupt_checkpoint_loudly(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    t = tree()
    path = ckpt.save(1, t)
    npz = os.path.join(path, "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(30)
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(ValueError) as exc:
        ckpt.restore(t, step=1)
    # the error names the file and both digests — debuggable from the log
    msg = str(exc.value)
    assert "arrays.npz" in msg and "sha256" in msg and "!=" in msg


def test_restore_rejects_truncated_checkpoint(tmp_path):
    ckpt = Checkpointer(str(tmp_path))
    t = tree()
    path = ckpt.save(1, t)
    npz = os.path.join(path, "arrays.npz")
    size = os.path.getsize(npz)
    with open(npz, "r+b") as f:
        f.truncate(size // 2)
    with pytest.raises(ValueError, match="corrupt checkpoint"):
        ckpt.restore(t, step=1)


def test_restore_accepts_pre_checksum_manifest(tmp_path):
    import json

    ckpt = Checkpointer(str(tmp_path))
    t = tree()
    path = ckpt.save(1, t)
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["checksum"]  # a checkpoint written before PR 8
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    restored, _ = ckpt.restore(t, step=1)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]))


def test_run_with_restarts_falls_back_past_corrupt_checkpoint(tmp_path):
    """Corrupting the LATEST checkpoint mid-run must not kill the job: the
    restart walks back to the previous good checkpoint and the final state
    is still bit-identical to an uninterrupted run."""

    def init_state():
        return {"x": jnp.zeros((4,)), "step_sum": jnp.float32(0)}

    def step_fn(state, step):
        return {"x": state["x"] + step,
                "step_sum": state["step_sum"] + step * 0.5}

    ckpt = Checkpointer(str(tmp_path / "a"), keep=0)

    class CorruptingInjector(FailureInjector):
        def maybe_fail(self, step):
            if step == 13 and 13 not in self.fired:
                # chew the newest checkpoint right before dying
                latest = ckpt.latest_step()
                npz = os.path.join(ckpt.dir, f"step_{latest:08d}",
                                   "arrays.npz")
                with open(npz, "r+b") as f:
                    f.seek(40)
                    f.write(b"\x00\x00\x00\x00")
            super().maybe_fail(step)

    final_a, restarts = run_with_restarts(
        total_steps=17, ckpt=ckpt, ckpt_every=5, init_state=init_state,
        step_fn=step_fn, injector=CorruptingInjector((13,)),
    )
    assert restarts == 1
    ckpt_b = Checkpointer(str(tmp_path / "b"))
    final_b, _ = run_with_restarts(
        total_steps=17, ckpt=ckpt_b, ckpt_every=5, init_state=init_state,
        step_fn=step_fn,
    )
    np.testing.assert_array_equal(np.asarray(final_a["x"]),
                                  np.asarray(final_b["x"]))
    np.testing.assert_array_equal(np.asarray(final_a["step_sum"]),
                                  np.asarray(final_b["step_sum"]))


def test_simulated_failure_is_an_injected_fault():
    from repro.resilience import InjectedFault

    assert issubclass(SimulatedFailure, InjectedFault)
