"""HLO census unit tests: trip-count multiplication, dot FLOPs, collectives.

The census is the roofline's foundation, so its key behaviours are pinned
against hand-written HLO snippets AND against live-compiled programs with
analytically known costs (in a multi-device subprocess).
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.hlo_analysis import analyze_collectives, analyze_hlo

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


SNIPPET = """
HloModule test

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %y = f32[128,128]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128]{1,0} all-reduce(%y), replica_groups=[1,4]<=[4], to_apply=%sum
  ROOT %t = (s32[], f32[128,128]) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[128,128])) -> pred[] {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[128,128]) -> f32[128,128] {
  %x = f32[128,128] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[128,128]) tuple(%zero, %x)
  %w = (s32[], f32[128,128]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[128,128] get-tuple-element(%w), index=1
}
"""


def test_while_trip_count_multiplies():
    c = analyze_hlo(SNIPPET)
    # 5 iterations x 2*128^3 dot flops
    assert c.dot_flops == pytest.approx(5 * 2 * 128 ** 3)
    # all-reduce: 5 x 2 x 64KiB x 3/4
    want = 5 * 2 * (128 * 128 * 4) * 3 / 4
    assert c.collective_bytes_by_kind["all-reduce"] == pytest.approx(want)
    assert c.collective_ops_by_kind["all-reduce"] == 1  # static count


def test_backend_config_trip_count_wins():
    txt = SNIPPET.replace(
        "condition=%cond, body=%body",
        'condition=%cond, body=%body, backend_config='
        '{"known_trip_count":{"n":"7"}}')
    c = analyze_hlo(txt)
    assert c.dot_flops == pytest.approx(7 * 2 * 128 ** 3)


def test_group_size_parsing_variants():
    base = SNIPPET.replace("replica_groups=[1,4]<=[4]",
                           "replica_groups={{0,1},{2,3}}")
    c = analyze_hlo(base)
    want = 5 * 2 * (128 * 128 * 4) * 1 / 2  # g=2
    assert c.collective_bytes_by_kind["all-reduce"] == pytest.approx(want)


def test_collective_kinds_wire_models():
    hlo = """
HloModule m

ENTRY %main (x: f32[64,64]) -> f32[64,64] {
  %x = f32[64,64] parameter(0)
  %ag = f32[256,64]{1,0} all-gather(%x), replica_groups=[1,4]<=[4], dimensions={0}
  %rs = f32[64,64]{1,0} reduce-scatter(%ag), replica_groups=[1,4]<=[4], dimensions={0}, to_apply=%s
  %cp = f32[64,64]{1,0} collective-permute(%rs), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  ROOT %aa = f32[64,64]{1,0} all-to-all(%cp), replica_groups=[1,4]<=[4], dimensions={0}
}
"""
    c = analyze_hlo(hlo)
    kb = 64 * 64 * 4
    assert c.collective_bytes_by_kind["all-gather"] == pytest.approx(
        4 * kb * 3 / 4)  # result 4x shard, (g-1)/g
    assert c.collective_bytes_by_kind["reduce-scatter"] == pytest.approx(
        4 * kb * 3 / 4)  # operand is the gathered tensor
    assert c.collective_bytes_by_kind["collective-permute"] == pytest.approx(
        kb)
    assert c.collective_bytes_by_kind["all-to-all"] == pytest.approx(
        kb * 3 / 4)


def test_async_pairs_counted_once():
    hlo = """
HloModule m

ENTRY %main (x: f32[64,64]) -> f32[256,64] {
  %x = f32[64,64] parameter(0)
  %s = (f32[64,64], f32[256,64]) all-gather-start(%x), replica_groups=[1,4]<=[4], dimensions={0}
  ROOT %d = f32[256,64]{1,0} all-gather-done(%s)
}
"""
    c = analyze_hlo(hlo)
    assert c.collective_ops_by_kind["all-gather"] == 1
    kb = 64 * 64 * 4
    assert c.collective_bytes_by_kind["all-gather"] == pytest.approx(
        4 * kb * 3 / 4)


def test_live_compiled_program_census():
    """Live end-to-end: compile a sharded scan with known analytic cost and
    check the census against it (subprocess owns the 8 host devices)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh((8,), ("d",))
        x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
        def f(v):
            def body(c, _):
                c = c @ c
                c = jax.lax.with_sharding_constraint(
                    c, NamedSharding(mesh, P("d", None)))
                return c, None
            c, _ = jax.lax.scan(body, v, None, length=10)
            return c
        with mesh:
            comp = jax.jit(
                f, in_shardings=NamedSharding(mesh, P("d", None))
            ).lower(x).compile()
        c = analyze_hlo(comp.as_text())
        want = 10 * 2 * 1024**3 / 8  # 10 steps, sharded 8 ways
        assert abs(c.dot_flops - want) / want < 0.01, (c.dot_flops, want)
        assert c.collective_ops_by_kind.get("all-gather", 0) >= 1
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=480, env=env)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


def test_backcompat_analyze_collectives():
    stats = analyze_collectives(SNIPPET)
    assert stats.wire_bytes > 0
    assert stats.op_counts["all-reduce"] == 1
