"""Optimizer + gradient-compression units (including hypothesis properties)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim.grad_compression import (
    compress_with_feedback,
    dequantize_int8,
    quantize_int8,
)
from repro.optim.optimizer import AdamW, AdamWConfig, cosine_schedule


def test_adamw_decreases_quadratic_loss():
    opt = AdamW(AdamWConfig(lr=0.05, warmup_steps=1, total_steps=200,
                            weight_decay=0.0))
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2 * l0


def test_adamw_clipping_bounds_update():
    opt = AdamW(AdamWConfig(lr=1.0, clip_norm=1.0, warmup_steps=1,
                            weight_decay=0.0))
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    new, state, metrics = opt.update(huge, state, params)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    # effective grad after clipping has norm 1 -> adam step bounded by lr
    assert np.abs(np.asarray(new["w"])).max() <= 1.1


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lr = cosine_schedule(cfg)
    assert float(lr(jnp.int32(0))) == pytest.approx(0.0)
    assert float(lr(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(lr(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)
    assert float(lr(jnp.int32(55))) < 1e-3


def test_weight_decay_applies_to_matrices_only():
    opt = AdamW(AdamWConfig(lr=0.1, weight_decay=0.5, warmup_steps=1))
    params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones((2,))}
    state = opt.init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = opt.update(zeros, state, params)
    assert np.all(np.asarray(new["mat"]) < 1.0)  # decayed
    np.testing.assert_allclose(np.asarray(new["vec"]), 1.0)  # not decayed


def test_moments_stay_f32_for_bf16_params():
    opt = AdamW()
    params = {"w": jnp.ones((3,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.float32
    g = {"w": jnp.ones((3,), jnp.bfloat16)}
    new, state, _ = opt.update(g, state, params)
    assert new["w"].dtype == jnp.bfloat16
    assert state.v["w"].dtype == jnp.float32


# --------------------------------------------------------- int8 compression


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, scale = quantize_int8(x, jax.random.PRNGKey(1))
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) + 1e-7


def test_error_feedback_accumulates_residual():
    x = jnp.full((16,), 0.41)
    ef = jnp.zeros((16,))
    q, scale, ef2 = compress_with_feedback(x, ef, jax.random.PRNGKey(0))
    recon = dequantize_int8(q, scale)
    np.testing.assert_allclose(np.asarray(recon + ef2), np.asarray(x),
                               rtol=1e-6)


@given(seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_property_stochastic_rounding_unbiased(seed):
    """E[quantized] == input when averaged over rounding keys."""
    x = jnp.full((8,), 0.3)
    recons = []
    for i in range(64):
        q, s = quantize_int8(x, jax.random.PRNGKey(seed * 64 + i))
        recons.append(np.asarray(dequantize_int8(q, s)))
    mean = np.stack(recons).mean(0)
    scale = float(jnp.max(jnp.abs(x)) / 127.0)
    assert np.abs(mean - 0.3).max() < 0.5 * scale


@given(
    shape=st.sampled_from([(8,), (4, 4), (2, 3, 5)]),
    scale_exp=st.integers(-8, 8),
)
@settings(max_examples=30, deadline=None)
def test_property_quantize_handles_scales(shape, scale_exp):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * (2.0 ** scale_exp)
    q, s = quantize_int8(x, jax.random.PRNGKey(1))
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 1.01 + 1e-12
