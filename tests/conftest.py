"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single-CPU device set (the 512-device forcing belongs ONLY to
launch/dryrun.py). Tests that need multi-device meshes spawn subprocesses
(see test_distributed.py) or use what `jax.devices()` offers.

`hypothesis` is an OPTIONAL test dependency (declared in pyproject's `test`
extra). When it is absent we install a stub into sys.modules so every test
module still collects; tests decorated with the stub's @given skip with a
clear reason instead of killing collection for the whole module.
"""
import os
import sys
import types

import pytest

# determinism + quieter logs
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Scheduling tests assert the ANALYTIC cost model's verdicts; a developer's
# ambient calibration cache (artifacts/bench/cost_model.json) would silently
# flip them. "off" pins the analytic fallback; cost-model tests that need a
# cache point REPRO_COST_MODEL at a tmp_path file via monkeypatch.
os.environ.setdefault("REPRO_COST_MODEL", "off")


def _install_hypothesis_stub() -> None:
    mod = types.ModuleType("hypothesis")
    strategies = types.ModuleType("hypothesis.strategies")

    class _Strategy:
        """Opaque placeholder accepted anywhere a SearchStrategy goes."""

        def __init__(self, *args, **kwargs):
            pass

        def map(self, *a, **k):
            return self

        def filter(self, *a, **k):
            return self

        def flatmap(self, *a, **k):
            return self

    def _make_strategy(*args, **kwargs):
        return _Strategy()

    for name in ("integers", "floats", "booleans", "text", "sampled_from",
                 "lists", "tuples", "just", "one_of", "none", "composite",
                 "dictionaries", "sets", "builds", "binary"):
        setattr(strategies, name, _make_strategy)

    def given(*_args, **_kwargs):
        def decorate(fn):
            def skipper():
                pytest.skip("hypothesis not installed "
                            "(pip install '.[test]' to run property tests)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.__module__ = fn.__module__
            return skipper

        return decorate

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    mod.assume = lambda condition: bool(condition)
    mod.example = settings  # decorator-compatible no-op
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()


@pytest.fixture(scope="session")
def rng_key():
    import jax

    return jax.random.PRNGKey(0)
