"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single-CPU device set (the 512-device forcing belongs ONLY to
launch/dryrun.py). Tests that need multi-device meshes spawn subprocesses
(see test_distributed.py) or use what `jax.devices()` offers.
"""
import os

import jax
import pytest

# determinism + quieter logs
os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
