"""The span tracer, exporters, and wall decomposition (DESIGN.md §10).

Three layers of coverage:

  * pure-unit: Span/Tracer semantics (nesting depth, category validation,
    the NullTracer fast path), exporter schemas, and the decompose interval
    math + overlap verdict on SYNTHETIC spans with known answers;
  * parity: for every backend, the traced executor built by
    ``_build_traced`` must be numerically identical to the production
    ``execute`` path — tracing is evidence, never a different program
    (single-device in-process; the 2-device matrix runs in a subprocess);
  * the off-by-default contract: a disabled tracer's per-span cost times
    the spans-per-step rate must stay under 1% of a measured step wall.
"""
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.obs import (
    CAT_DECISION,
    CAT_LAUNCH,
    CATEGORIES,
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    coerce_tracer,
    summarize,
    to_chrome_trace,
    union_us,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.decompose import (
    category_walls,
    overlap_verdict,
    probe_costs,
    wall_extent_us,
)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------- tracer --

def test_span_nesting_records_depth():
    tr = Tracer()
    with tr.span("outer", "dispatch"):
        with tr.span("inner", "compute.interior", step=3):
            pass
    # inner exits (and appends) first
    assert [s.name for s in tr.spans] == ["inner", "outer"]
    inner, outer = tr.spans
    assert inner.depth == 1 and outer.depth == 0
    assert inner.attrs == {"step": 3}
    assert inner.start_us >= outer.start_us
    assert inner.end_us <= outer.end_us
    assert outer.duration_us >= inner.duration_us >= 0.0


def test_unknown_category_rejected():
    tr = Tracer()
    with pytest.raises(ValueError, match="unknown span category"):
        tr.span("x", "comms")
    with pytest.raises(ValueError, match="unknown span category"):
        tr.add("x", "comms", 0.0, 1.0)
    # every taxonomy member and both structured categories are accepted
    for cat in CATEGORIES + (CAT_LAUNCH,):
        with tr.span("x", cat):
            pass


def test_add_and_instant_and_clear():
    tr = Tracer()
    tr.add("probe", "exchange", 10.0, 25.0, probe=True, phase="exchange",
           per_launch_us=5.0)
    tr.instant("schedule.resolve", plan="halo")
    assert tr.spans[0].duration_us == 15.0
    dec = tr.spans[1]
    assert dec.category == CAT_DECISION
    assert dec.start_us == dec.end_us
    assert dec.attrs["plan"] == "halo"
    tr.clear()
    assert tr.spans == [] and tr._depth == 0


def test_coerce_tracer():
    assert coerce_tracer(None) is NULL_TRACER
    assert coerce_tracer(False) is NULL_TRACER
    assert isinstance(coerce_tracer(True), Tracer)
    assert isinstance(coerce_tracer("on"), Tracer)
    assert isinstance(coerce_tracer(1), Tracer)
    tr = Tracer()
    assert coerce_tracer(tr) is tr  # callers can share one recorder
    assert coerce_tracer(NULL_TRACER) is NULL_TRACER
    with pytest.raises(ValueError, match="trace option"):
        coerce_tracer("loud")


def test_null_tracer_is_inert():
    nt = NULL_TRACER
    assert isinstance(nt, NullTracer) and nt.enabled is False
    ctx1 = nt.span("a", "dispatch")
    ctx2 = nt.span("b", "nonsense-category")  # not even validated
    assert ctx1 is ctx2  # ONE preallocated context, no allocation
    with ctx1:
        pass
    nt.add("x", "exchange", 0.0, 1.0)
    nt.instant("x")
    nt.clear()
    assert nt.spans == ()


def test_null_tracer_overhead_under_one_percent():
    """The off-by-default contract: instrumenting a hot path with TWO null
    spans per step (attrs and all, exactly as the runtimes call it) must
    cost < 1% of a step wall at the smoke benches' own shape (grain 64)."""
    from repro.core import KernelSpec, TaskGraph, get_runtime

    nt = NULL_TRACER
    n = 20000
    t0 = time.perf_counter()
    for _ in range(n):
        with nt.span("dispatch", "dispatch", step=0):
            pass
        with nt.span("kernel", "compute.interior", step=0):
            pass
    per_step_overhead = (time.perf_counter() - t0) / n

    g = TaskGraph(steps=8, width=64, pattern="stencil_1d", payload=64,
                  kernel=KernelSpec("compute_bound", 64), radius=1, seed=0)
    rt = get_runtime("bsp")
    sample, _ = rt.measure(g, reps=2, warmup=1)
    step_wall = sample.wall_time / g.steps
    assert per_step_overhead < 0.01 * step_wall, (
        f"null-tracer cost {per_step_overhead * 1e9:.0f} ns/step vs "
        f"step wall {step_wall * 1e6:.1f} us")


# ------------------------------------------------------------- exporters --

def _spans_for_export():
    return [
        Span("launch", "dispatch", 10.0, 30.0, 0, {"launch": 0}),
        Span("decide", CAT_DECISION, 12.0, 12.0, 1, {"plan": "halo"}),
        Span("kernel", "compute.interior", 15.0, 28.0, 1, {}),
    ]


def test_chrome_trace_schema():
    doc = to_chrome_trace(_spans_for_export(), process_name="t")
    assert doc["schemaVersion"] == 1
    evs = doc["traceEvents"]
    assert evs[0]["ph"] == "M" and evs[0]["args"]["name"] == "t"
    complete = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    assert len(complete) == 2 and len(instants) == 1
    k = next(e for e in complete if e["name"] == "kernel")
    assert k["ts"] == 15.0 and k["dur"] == 13.0 and k["tid"] == 1
    assert k["args"]["category"] == "compute.interior"
    assert instants[0]["args"]["plan"] == "halo"


def test_write_chrome_trace_and_jsonl_roundtrip(tmp_path):
    spans = _spans_for_export()
    cpath = write_chrome_trace(str(tmp_path / "t.json"), spans)
    with open(cpath) as f:
        doc = json.load(f)
    assert len(doc["traceEvents"]) == 4  # metadata + 3 spans
    jpath = write_jsonl(str(tmp_path / "t.jsonl"), spans)
    lines = [json.loads(ln) for ln in open(jpath)]
    assert lines[0] == {"schema": 1}
    assert len(lines) == 4
    assert lines[1]["name"] == "launch" and lines[1]["end_us"] == 30.0
    assert lines[2]["attrs"] == {"plan": "halo"}


# ------------------------------------------------------------- decompose --

def test_union_merges_overlaps():
    assert union_us([(0, 10), (5, 15), (20, 25)]) == 20.0
    assert union_us([(0, 0), (3, 2)]) == 0.0  # degenerate dropped


def test_category_walls_no_double_count_and_idle():
    spans = [
        Span("a", "dispatch", 0.0, 10.0),
        Span("b", "dispatch", 5.0, 12.0),     # overlaps a: union, not sum
        Span("c", "exchange", 20.0, 30.0),
        Span("d", CAT_DECISION, 1.0, 1.0),    # never attributed
    ]
    walls = category_walls(spans)
    assert walls["dispatch"] == 12.0
    assert walls["exchange"] == 10.0
    # extent [0, 30], gap (12, 20) -> idle
    assert wall_extent_us(spans) == 30.0
    assert walls["idle"] == pytest.approx(8.0)
    s = summarize(spans)
    assert s["schema"] == 1 and s["span_count"] == 4
    assert sum(s["fractions"].values()) == pytest.approx(1.0)
    assert s["decisions"] == [{"name": "d"}]


def _probe(phase, cost):
    return Span(f"probe.{phase}", "exchange", 100.0, 101.0, 0,
                {"probe": True, "phase": phase, "per_launch_us": cost})


def test_launch_split_known_answer():
    # C=100, Bd=20, I=70, E=40: boundary+interior leave 10us visible,
    # so 30us of the exchange rode under compute.
    spans = [Span("L", CAT_LAUNCH, 0.0, 100.0),
             _probe("boundary", 20.0), _probe("interior", 70.0),
             _probe("exchange", 40.0)]
    assert probe_costs(spans) == {
        "boundary": 20.0, "interior": 70.0, "exchange": 40.0}
    walls = category_walls(spans)
    assert walls["compute.boundary"] == 20.0
    assert walls["compute.interior"] == 70.0
    assert walls["exchange"] == 10.0
    assert walls["dispatch"] == 0.0
    v = overlap_verdict(spans)
    assert v["verdict"] == "hidden"
    assert v["hidden_fraction"] == pytest.approx(0.75)
    assert v["exchange_hidden_us"] == pytest.approx(30.0)


def test_launch_split_visible_and_slack():
    # C=140 > Bd+I+E=130: the whole exchange is visible, 10us of host
    # slack lands in dispatch, verdict flips to "visible".
    spans = [Span("L", CAT_LAUNCH, 0.0, 140.0),
             _probe("boundary", 20.0), _probe("interior", 70.0),
             _probe("exchange", 40.0)]
    walls = category_walls(spans)
    assert walls["exchange"] == 40.0
    assert walls["dispatch"] == pytest.approx(10.0)
    v = overlap_verdict(spans)
    assert v["verdict"] == "visible"
    assert v["hidden_fraction"] == 0.0


def test_overlap_verdict_edge_cases():
    assert overlap_verdict([Span("k", "compute.interior", 0, 5)]) is None
    v = overlap_verdict([Span("L", CAT_LAUNCH, 0.0, 10.0)])
    assert v["verdict"] == "unavailable"
    # probe spans are excluded from extent/attribution
    spans = [Span("k", "exchange", 0.0, 10.0),
             _probe("exchange", 5.0)]
    assert wall_extent_us(spans) == 10.0
    assert category_walls(spans)["exchange"] == 10.0


def test_summarize_empty():
    s = summarize([])
    assert s["wall_us"] == 0.0 and s["span_count"] == 0
    assert s["overlap"] is None


# --------------------------------------------------- schedule decisions --

def test_record_resolution_null_and_live():
    from repro.kernels.schedule import record_resolution

    record_resolution(None, plan="halo", steps_per_launch=4, pipeline=True)
    record_resolution(NULL_TRACER, plan="halo", steps_per_launch=4,
                      pipeline=True)  # both no-ops, no error
    tr = Tracer()
    record_resolution(tr, plan="halo", steps_per_launch=4, pipeline=True,
                      reason="covering rule", pattern="stencil_1d")
    (s,) = tr.spans
    assert s.category == CAT_DECISION and s.name == "schedule.resolve"
    assert s.attrs["plan"] == "halo"
    assert s.attrs["steps_per_launch"] == 4
    assert s.attrs["pipeline"] is True
    assert s.attrs["reason"] == "covering rule"
    assert s.attrs["cost_model_source"] in ("analytic", "measured", "env")
    assert s.attrs["exchange_row_steps"] > 0


# ------------------------------------------------------ traced executors --

def _graph(pattern, **kw):
    from repro.core import KernelSpec, TaskGraph

    base = dict(steps=6, width=16, payload=8,
                kernel=KernelSpec("compute_bound", 8), radius=1, seed=3)
    base.update(kw)
    return TaskGraph(pattern=pattern, **base)


BACKEND_CASES = [
    ("fused", "stencil_1d", {}),
    ("serialized", "stencil_1d", {}),
    ("bsp", "stencil_1d", {}),
    ("bsp", "fft", {}),
    ("bsp", "spread", {}),
    ("bsp_scan", "stencil_1d", {}),
    ("overlap", "stencil_1d", {}),
]


@pytest.mark.parametrize("name,pattern,opts", BACKEND_CASES,
                         ids=[f"{n}-{p}" for n, p, _ in BACKEND_CASES])
def test_traced_matches_execute(name, pattern, opts):
    from repro.core import get_runtime

    g = _graph(pattern)
    ref = get_runtime(name, **opts).execute(g)
    rt = get_runtime(name, trace=True, **opts)
    out = rt.trace_once(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    s = summarize(rt.tracer.spans)
    assert s["span_count"] > 0 and s["wall_us"] > 0
    assert sum(s["fractions"].values()) == pytest.approx(1.0)
    assert s["fractions"]["dispatch"] > 0  # every backend dispatches


PALLAS_CASES = [
    ("halo-S1", "stencil_1d", {}, dict()),
    ("blocked-serial", "stencil_1d", {},
     dict(steps_per_launch=2, pipeline=False)),
    ("blocked-pipelined", "stencil_1d", {"width": 32},
     dict(steps_per_launch=2)),
    ("stride", "fft", {}, dict()),
    ("allgather-step", "spread", {}, dict()),
    ("allgather-blocked", "spread", {}, dict(steps_per_launch=2)),
    ("allgather-period1", "all_to_all", {}, dict()),
]


@pytest.mark.parametrize("label,pattern,gkw,opts", PALLAS_CASES,
                         ids=[c[0] for c in PALLAS_CASES])
def test_pallas_step_traced_matches_execute(label, pattern, gkw, opts):
    """Every traced pallas_step plan path is bit-compatible with the
    production executor AND records a plan decision."""
    from repro.core import get_runtime

    g = _graph(pattern, **gkw)
    ref = get_runtime("pallas_step", **opts).execute(g)
    rt = get_runtime("pallas_step", trace=True, **opts)
    out = rt.trace_once(g)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
    s = summarize(rt.tracer.spans)
    assert s["decisions"], "schedule decision record missing"
    d = s["decisions"][0]
    assert d["name"] == "schedule.resolve"
    assert d["plan"] in ("halo", "stride", "allgather")
    assert d["runtime"] == "pallas_step"


def test_trace_once_null_tracer_is_plain_execute():
    from repro.core import get_runtime

    g = _graph("stencil_1d")
    rt = get_runtime("bsp")
    assert rt.tracer is NULL_TRACER
    ref = rt.execute(g)
    out = rt.trace_once(g)
    np.testing.assert_allclose(out, ref, rtol=1e-6)
    assert rt.tracer.spans == ()


def test_trace_once_warmup_does_not_duplicate_spans():
    """trace_once runs a warmup (compile) pass and rolls its spans back:
    two consecutive summaries must agree on the span count."""
    from repro.core import get_runtime

    g = _graph("stencil_1d")
    rt = get_runtime("serialized", trace=True)
    rt.trace_once(g)
    n1 = len(rt.tracer.spans)
    rt.tracer.clear()
    rt.trace_once(g)
    assert len(rt.tracer.spans) == n1


def test_pallas_pipelined_trace_has_probes_and_verdict():
    """The pipelined path records composite launch spans plus the three
    phase probes, so the decomposition yields an overlap verdict (the
    physics at tiny CPU shapes says 'visible' — the assertion is that the
    verdict machinery produces a well-formed answer, not which way)."""
    from repro.core import get_runtime

    g = _graph("stencil_1d", width=32, steps=9)
    rt = get_runtime("pallas_step", trace=True, steps_per_launch=4)
    rt.trace_once(g)
    spans = rt.tracer.spans
    launches = [s for s in spans if s.category == CAT_LAUNCH]
    assert launches, "no composite launch spans — pipeline did not engage"
    costs = probe_costs(spans)
    assert set(costs) == {"boundary", "exchange", "interior"}
    assert all(v > 0 for v in costs.values())
    v = summarize(spans)["overlap"]
    assert v["verdict"] in ("hidden", "visible")
    assert 0.0 <= v["hidden_fraction"] <= 1.0
    assert v["launches"] == len(launches)


def test_traced_parity_two_devices_subprocess():
    """The 2-device matrix: real ppermute/all-gather transports under every
    traced plan path, vs production execute."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    env["REPRO_COST_MODEL"] = "off"
    code = textwrap.dedent("""
        import numpy as np
        from repro.core import TaskGraph, KernelSpec, get_runtime
        from repro.obs import summarize

        def g(pattern, **kw):
            base = dict(steps=6, width=16, payload=8,
                        kernel=KernelSpec("compute_bound", 8), radius=1,
                        seed=3)
            base.update(kw)
            return TaskGraph(pattern=pattern, **base)

        cases = [
            ("pallas_step", g("stencil_1d"), {}),
            ("pallas_step", g("stencil_1d"),
             dict(steps_per_launch=2, pipeline=False)),
            ("pallas_step", g("stencil_1d", width=32),
             dict(steps_per_launch=2)),
            ("pallas_step", g("fft"), {}),
            ("pallas_step", g("spread"), {}),
            ("pallas_step", g("spread"), dict(steps_per_launch=2)),
            ("bsp", g("stencil_1d"), {}),
            ("overlap", g("stencil_1d"), {}),
        ]
        for name, graph, opts in cases:
            ref = get_runtime(name, **opts).execute(graph)
            rt = get_runtime(name, trace=True, **opts)
            out = rt.trace_once(graph)
            assert np.allclose(ref, out, rtol=1e-5, atol=1e-6), (
                name, graph.pattern, opts)
            s = summarize(rt.tracer.spans)
            assert s["span_count"] > 0 and s["wall_us"] > 0
        print("ALL OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, (
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    assert "ALL OK" in out.stdout
