"""OverheadProfiler / OverheadReport math (core/instrumentation.py).

The profiler is the production-loop face of the paper's methodology; these
tests pin the report arithmetic with synthetic records (no timing noise),
the skip_warmup edge cases, the module-level dispatch-probe memoization,
and the tracer-fed category fractions.
"""
import numpy as np
import pytest

from repro.core.instrumentation import (
    OverheadProfiler,
    OverheadReport,
    StepRecord,
    measure_dispatch_overhead,
)


def _profiler(**kw):
    kw.setdefault("devices", 2)
    kw.setdefault("tasks_per_step", 4)
    p = OverheadProfiler(**kw)
    p._dispatch = 1e-4  # pin the probe: report math must be deterministic
    return p


def test_report_math_known_answer():
    p = _profiler(flops_per_step=1e6, tokens_per_step=8)
    for wall in (0.5, 0.01, 0.02, 0.03):  # first record is warmup
        p.record(wall)
    r = p.report(skip_warmup=1)
    assert r.steps == 3
    assert r.mean_wall == pytest.approx(0.02)
    assert r.p50_wall == pytest.approx(0.02)
    assert r.best_wall == pytest.approx(0.01)
    assert r.dispatch_overhead == 1e-4
    assert r.overhead_fraction == pytest.approx(1e-4 / 0.02)
    # granularity = wall * devices / tasks_per_step
    assert r.granularity_us == pytest.approx(0.02 * 2 / 4 * 1e6)
    assert r.sustained_flops_per_s == pytest.approx(1e6 / 0.02)
    # tokens: 3 steps x 8 tokens over 0.06 s total
    assert r.tokens_per_s == pytest.approx(24 / 0.06)
    # step-METG at 50%: c = overhead, per task, in us
    assert r.step_metg_us == pytest.approx(1e-4 / 4 * 1e6)


def test_explicit_tokens_override_per_step_default():
    p = _profiler(tokens_per_step=8)
    p.record(0.01)            # 8 tokens (the default)
    p.record(0.01, tokens=2)  # partial batch
    assert [r.tokens for r in p.records] == [8, 2]
    r = p.report(skip_warmup=0)
    assert r.tokens_per_s == pytest.approx(10 / 0.02)


def test_tokens_zero_keeps_report_quiet():
    p = _profiler()
    p.record(0.01)
    r = p.report(skip_warmup=0)
    assert r.tokens_per_s == 0.0
    assert not any("tokens/s" in ln for ln in r.lines())
    p2 = _profiler(tokens_per_step=4)
    p2.record(0.01)
    assert any("tokens/s" in ln for ln in p2.report(skip_warmup=0).lines())


def test_skip_warmup_edges():
    p = _profiler()
    p.record(0.5)
    # skipping everything falls back to ALL records rather than erroring
    r = p.report(skip_warmup=1)
    assert r.steps == 1 and r.mean_wall == pytest.approx(0.5)
    r = p.report(skip_warmup=100)
    assert r.steps == 1
    # no warmup skip keeps every record
    p.record(0.1)
    assert p.report(skip_warmup=0).steps == 2


def test_empty_records_raise():
    p = _profiler()
    with pytest.raises(ValueError, match="no steps recorded"):
        p.report()


def test_overhead_fraction_clamped():
    p = _profiler()
    p._dispatch = 1.0  # dispatch slower than the step itself
    p.record(0.001)
    p.record(0.001)
    r = p.report()
    assert r.overhead_fraction == 1.0


def test_wrap_routes_through_record():
    import jax.numpy as jnp

    p = _profiler(tokens_per_step=3)
    timed = p.wrap(lambda x: x + 1)
    out = timed(jnp.zeros(()))
    assert float(out) == 1.0
    assert len(p.records) == 1
    assert p.records[0].wall > 0 and p.records[0].tokens == 3


def test_dispatch_probe_memoized_across_profilers():
    measure_dispatch_overhead.cache_clear()
    v1 = measure_dispatch_overhead()
    v2 = measure_dispatch_overhead()
    assert v1 == v2
    info = measure_dispatch_overhead.cache_info()
    assert info.hits >= 1 and info.misses == 1
    # two profilers ask the same memo, not the device queue twice
    a, b = OverheadProfiler(), OverheadProfiler()
    assert a.dispatch_overhead == b.dispatch_overhead == v1
    assert measure_dispatch_overhead.cache_info().misses == 1
    # distinct reps is a distinct cache key
    measure_dispatch_overhead(reps=5)
    assert measure_dispatch_overhead.cache_info().misses == 2
    measure_dispatch_overhead.cache_clear()


def test_category_fractions_from_attached_tracer():
    from repro.obs import Tracer

    tr = Tracer()
    tr.add("feed", "dispatch", 0.0, 25.0)
    tr.add("step", "compute.interior", 25.0, 100.0)
    p = _profiler(tracer=tr)
    p.record(0.01)
    r = p.report(skip_warmup=0)
    assert r.category_fractions["dispatch"] == pytest.approx(0.25)
    assert r.category_fractions["compute.interior"] == pytest.approx(0.75)
    assert any("wall by category" in ln for ln in r.lines())


def test_category_fractions_absent_without_tracer():
    p = _profiler()
    p.record(0.01)
    r = p.report(skip_warmup=0)
    assert r.category_fractions is None
    assert not any("wall by category" in ln for ln in r.lines())
    # attached but empty tracer: still absent (nothing to attribute)
    from repro.obs import Tracer

    p2 = _profiler(tracer=Tracer())
    p2.record(0.01)
    assert p2.report(skip_warmup=0).category_fractions is None


def test_report_lines_render():
    r = OverheadReport(
        steps=3, mean_wall=0.02, p50_wall=0.02, best_wall=0.01,
        dispatch_overhead=1e-4, overhead_fraction=0.005,
        granularity_us=10000.0, step_metg_us=25.0,
        sustained_flops_per_s=5e7, tokens_per_s=400.0,
        category_fractions={"dispatch": 0.3, "compute.interior": 0.7,
                            "idle": 0.0},
    )
    text = "\n".join(r.lines())
    assert "step-METG(50%)        : 25.0 us" in text
    assert "tokens/s              : 400.0" in text
    assert "dispatch=30.0%" in text
    assert "idle=" not in text  # zero-fraction categories are omitted


def test_step_record_defaults():
    r = StepRecord(step=0, wall=0.5)
    assert r.tokens == 0 and r.flops == 0.0
