"""Unit + property tests for task-graph patterns (normative index math)."""
import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import patterns as P
from repro.core.graph import TaskGraph
from repro.core.task_kernels import KernelSpec


def make(pattern, width=16, steps=8, **kw):
    return TaskGraph(steps=steps, width=width, pattern=pattern,
                     kernel=KernelSpec("empty"), **kw)


# ------------------------------------------------------------------ shapes


@pytest.mark.parametrize("pattern", P.PATTERNS)
def test_dependency_arrays_shapes(pattern):
    g = make(pattern)
    idx, mask = g.dependency_arrays()
    assert idx.shape == (g.period, g.width, g.max_deps)
    assert mask.shape == idx.shape
    assert idx.dtype == np.int32
    assert ((idx >= 0) & (idx < g.width)).all()
    assert set(np.unique(mask)) <= {0.0, 1.0}


@pytest.mark.parametrize("pattern", P.PATTERNS)
def test_dependencies_match_arrays(pattern):
    """dependency_arrays must agree with the scalar dependencies() oracle."""
    g = make(pattern)
    idx, mask = g.dependency_arrays()
    for t in range(1, g.steps):
        s = (t - 1) % g.period
        for p in range(g.width):
            from_arrays = sorted(
                int(i) for i, m in zip(idx[s, p], mask[s, p]) if m > 0
            )
            assert from_arrays == sorted(set(g.dependencies(t, p))), (
                pattern, t, p)


# --------------------------------------------------------------- specifics


def test_stencil_edges_clip():
    g = make("stencil_1d", width=8)
    assert g.dependencies(1, 0) == (0, 1)
    assert g.dependencies(1, 7) == (6, 7)
    assert g.dependencies(1, 3) == (2, 3, 4)


def test_stencil_periodic_wraps():
    g = make("stencil_1d_periodic", width=8)
    assert sorted(g.dependencies(1, 0)) == [0, 1, 7]


def test_dom_is_lower_triangular():
    g = make("dom", width=8)
    for p in range(8):
        assert all(q <= p for q in g.dependencies(1, p))


def test_fft_butterfly_strides():
    g = make("fft", width=8, steps=7)
    # stride 1, 2, 4 cycling
    assert set(g.dependencies(1, 0)) == {0, 1}
    assert set(g.dependencies(2, 0)) == {0, 2}
    assert set(g.dependencies(3, 0)) == {0, 4}
    assert set(g.dependencies(4, 0)) == {0, 1}  # period wraps


def test_tree_rises_then_falls():
    g = make("tree", width=8, steps=13)
    L = 3
    strides = []
    for t in range(1, 1 + 2 * L):
        deps = set(g.dependencies(t, 0)) - {0}
        strides.append(deps.pop() if deps else 0)
    assert strides == [1, 2, 4, 4, 2, 1]


def test_all_to_all_full_fanin():
    g = make("all_to_all", width=8)
    assert g.dependencies(1, 3) == tuple(range(8))


def test_nearest_radius():
    g = make("nearest", width=16, radius=3)
    assert sorted(g.dependencies(1, 8)) == list(range(5, 12))
    assert len(g.dependencies(1, 0)) == 7  # periodic wrap keeps count


def test_random_nearest_deterministic_and_contains_self():
    g1 = make("random_nearest", width=16, radius=2, seed=7)
    g2 = make("random_nearest", width=16, radius=2, seed=7)
    g3 = make("random_nearest", width=16, radius=2, seed=8)
    d1 = [g1.dependencies(1, p) for p in range(16)]
    assert d1 == [g2.dependencies(1, p) for p in range(16)]
    assert any(d1[p] != g3.dependencies(1, p) for p in range(16))
    for p in range(16):
        assert p in d1[p]
    # fixed across timesteps (period 1)
    assert d1 == [g1.dependencies(5, p) for p in range(16)]


def test_spread_fanout_count():
    g = make("spread", width=16, fanout=4)
    for t in (1, 2, 9):
        for p in range(16):
            deps = g.dependencies(t, p)
            assert 1 <= len(deps) <= 4
            assert all(0 <= d < 16 for d in deps)


# ------------------------------------------------------------- validation


def test_pow2_required_for_butterflies():
    with pytest.raises(ValueError):
        make("fft", width=12)
    with pytest.raises(ValueError):
        make("tree", width=6)


def test_unknown_pattern_rejected():
    with pytest.raises(ValueError):
        make("nope")


def test_reverse_dependencies_inverts():
    g = make("stencil_1d", width=8)
    for p in range(8):
        for q in g.reverse_dependencies(1, p):
            assert p in g.dependencies(2, q)


# ------------------------------------------------------------- properties


@given(
    pattern=st.sampled_from([p for p in P.PATTERNS]),
    wexp=st.integers(2, 6),
    t=st.integers(1, 40),
)
@settings(max_examples=120, deadline=None)
def test_property_deps_in_range_and_nonempty(pattern, wexp, t):
    W = 1 << wexp
    g = TaskGraph(steps=t + 1, width=W, pattern=pattern,
                  kernel=KernelSpec("empty"))
    for p in (0, W // 2, W - 1):
        deps = g.dependencies(t, p)
        assert all(0 <= d < W for d in deps)
        assert len(set(deps)) == len(deps)  # no duplicates
        if pattern != "trivial":
            assert deps, f"{pattern} must have deps at t>=1"
        assert len(deps) <= g.max_deps


@given(wexp=st.integers(2, 5), steps=st.integers(2, 12))
@settings(max_examples=40, deadline=None)
def test_property_num_dependencies_consistent(wexp, steps):
    W = 1 << wexp
    g = TaskGraph(steps=steps, width=W, pattern="stencil_1d",
                  kernel=KernelSpec("empty"))
    manual = sum(
        len(g.dependencies(t, p)) for t in range(1, steps) for p in range(W)
    )
    assert g.num_dependencies == manual
