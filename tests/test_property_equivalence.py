"""Property-based cross-backend equivalence suite.

The system's core invariant (DESIGN.md §2, §7) fuzz-tested: for ANY
(pattern x combine mode x steps_per_launch x hetero-steps ensemble) drawn
by hypothesis, `pallas_step` must reproduce the `fused` oracle and
`bsp_scan` — covering every pattern->plan dispatch path (halo / stride /
allgather), both megakernel schedules (per-step and blocked, with the
blocked time-varying tables for butterfly/rotation), and the tuple
ensemble's mixed-plan freezing in one sweep.

Equality strength is principled, not empirical:

  * EXACT_PATTERNS — patterns whose tasks all have 1 or 2 live
    dependencies. Their combine weights (1.0, 0.5) are powers of two and
    the weighted sums have at most two nonzero terms, so prenormalized
    weights (pallas_step), mask-sum-then-divide (fused/bsp_scan), and
    (a + b) * 0.5 (bsp_scan's butterfly body) are all the SAME float32
    value: the suite asserts bit-identity, any schedule, any device
    count. This locks in the PR-5 acceptance criterion (fft/tree
    bit-identical to fused) as a property, not a point test.
  * everything else (3+ live deps: stencil interiors, nearest, spread,
    random_nearest, all_to_all) carries non-representable 1/n weights,
    where prenormalization legitimately differs from sum/n in the last
    ulp — asserted allclose at the repo's standard tolerance (and
    frequently still bit-identical in practice).

`hypothesis` is an optional test dependency: when absent, the
tests/conftest.py stub turns every @given test into a clean skip.
"""
import numpy as np
import pytest

from hypothesis import given, settings, strategies as st

from repro.core import GraphEnsemble, KernelSpec, TaskGraph, get_runtime

WIDTH = 16  # power of two: butterfly-valid, divides every device count
PAYLOAD = 4
PATTERNS = ("trivial", "no_comm", "stencil_1d", "stencil_1d_periodic",
            "dom", "tree", "fft", "all_to_all", "nearest", "spread",
            "random_nearest")
#: every task has <= 2 live deps => all weights are powers of two and all
#: combine sums have <= 2 terms => bit-identity is guaranteed, not lucky
EXACT_PATTERNS = frozenset({"trivial", "no_comm", "dom", "fft", "tree"})
COMBINES = ("window", "gather", "onehot")
S_VALUES = (1, 3, 8)
STEPS = (1, 4, 7)


def _graph(pattern: str, steps: int, seed: int) -> TaskGraph:
    return TaskGraph(steps=steps, width=WIDTH, payload=PAYLOAD,
                     pattern=pattern, radius=2, fanout=3,
                     kernel=KernelSpec("compute_bound", 4), seed=seed)


def _check(pattern, got, want, msg):
    if pattern in EXACT_PATTERNS:
        assert np.array_equal(got, want), f"{msg}: bits differ"
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6,
                                   err_msg=msg)


single_cases = st.tuples(
    st.sampled_from(PATTERNS),
    st.sampled_from(COMBINES),
    st.sampled_from(S_VALUES),
    st.sampled_from(STEPS),
    st.integers(min_value=0, max_value=3),
)


@settings(max_examples=25, deadline=None, derandomize=True)
@given(single_cases)
def test_property_single_graph_cross_backend(case):
    """pallas_step == fused == bsp_scan for any drawn single graph."""
    pattern, combine, s, steps, seed = case
    g = _graph(pattern, steps, seed)
    rt = get_runtime("pallas_step", combine=combine, steps_per_launch=s)
    ok, why = rt.supports(g)
    assert ok, why  # every paper pattern must have a plan at this width
    ref = get_runtime("fused").execute(g)
    _check(pattern, rt.execute(g), ref,
           f"pallas_step {pattern}/{combine}/S{s}/T{steps} vs fused")
    _check(pattern, get_runtime("bsp_scan").execute(g), ref,
           f"bsp_scan {pattern}/T{steps} vs fused")


ensemble_cases = st.tuples(
    st.lists(
        st.tuples(st.sampled_from(PATTERNS), st.sampled_from(STEPS)),
        min_size=2, max_size=4,
    ),
    st.sampled_from(COMBINES),
    st.sampled_from(S_VALUES),
)


@settings(max_examples=15, deadline=None, derandomize=True)
@given(ensemble_cases)
def test_property_hetero_ensemble_cross_backend(case):
    """Concurrent hetero-steps ensembles (mixed patterns => mixed plans in
    one tuple scan, masked freezing mid-run) reproduce, per member, the
    state of running that member alone under fused — on pallas_step AND
    bsp_scan."""
    member_specs, combine, s = case
    members = [_graph(p, t, seed=k) for k, (p, t) in enumerate(member_specs)]
    ens = GraphEnsemble(members)
    rt = get_runtime("pallas_step", combine=combine, steps_per_launch=s)
    ok, why = rt.supports_ensemble(ens)
    assert ok, why
    refs = [get_runtime("fused").execute(g) for g in members]
    for k, (g, out) in enumerate(zip(members, rt.execute_ensemble(ens))):
        _check(g.pattern, out, refs[k],
               f"pallas_step member {k} ({g.pattern}/T{g.steps}) "
               f"combine={combine} S={s}")
    for k, (g, out) in enumerate(
            zip(members, get_runtime("bsp_scan").execute_ensemble(ens))):
        _check(g.pattern, out, refs[k],
               f"bsp_scan member {k} ({g.pattern}/T{g.steps})")


def test_property_suite_skips_cleanly_without_hypothesis():
    """Collection sanity: whether or not hypothesis is installed, the
    @given tests above must be collectable callables (the conftest stub
    replaces them with skippers when it is absent)."""
    assert callable(test_property_single_graph_cross_backend)
    assert callable(test_property_hetero_ensemble_cross_backend)
