"""The reframe-style regression suite (benchmarks/floor_guard.py).

The guard's contract, locked per rule:

  two-signal   an absolute regression vs the committed baseline alone is
               a WARN (shared runners drift); it only FAILs when the
               run's OWN health signal collapsed too (S1/S8 amortization
               gone, or pallas_step above fused in the same process).
  sanity       malformed artifacts FAIL loudly instead of skipping into
               green, and a suite that judged ZERO checks of an armed
               family is itself a failure (schema drift detector).
  references   the baseline's "references" object pins per-system
               reference/factor overrides without touching the guard.
  cost model   the CI calibration artifact gets sanity-only checks: a
               garbage calibration fails before it silently steers every
               "auto" schedule; a sane one never perf-fails.
"""
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:  # `python -m pytest` adds cwd; be explicit
    sys.path.insert(0, str(ROOT))

from benchmarks import floor_guard as fg  # noqa: E402


def baseline(**kw):
    base = {
        "floor_wall_per_step": {"64": 1.0e-4},
        "butterfly_floor_wall_per_step": {"fft@64": 2.0e-4},
    }
    base.update(kw)
    return base


def current(*, floor=1.0e-4, amort=3.0, butterfly=2.0e-4, vs_fused=0.8,
            **kw):
    cur = {
        "floor_wall_per_step": {"64": floor},
        "s1_over_s8_speedup": {"64": amort},
        "butterfly_floor_wall_per_step": {"fft@64": butterfly},
        "butterfly_over_fused_per_step": {"fft": {"64": vs_fused}},
    }
    cur.update(kw)
    return cur


def run(cur, base, factor=2.0, min_amortization=1.05, cost_model=None):
    return fg.check(cur, base, factor, min_amortization, cost_model)


def test_identical_run_passes():
    assert run(current(), baseline()) == []


def test_regression_with_healthy_signal_only_warns(capsys):
    # 10x the baseline, but the run's own S1/S8 amortization is healthy
    # and pallas_step still beats fused: slow runner, not a broken path
    assert run(current(floor=1.0e-3, butterfly=2.0e-3), baseline()) == []
    out = capsys.readouterr().out
    assert "SLOW-RUNNER?" in out and "[WARN]" in out
    assert "[FAIL]" not in out


def test_regression_with_collapsed_amortization_fails():
    failures = run(current(floor=1.0e-3, amort=1.0), baseline())
    assert len(failures) == 1
    assert "floor@64" in failures[0]
    assert "health signal collapsed" in failures[0]


def test_butterfly_regression_above_fused_fails():
    failures = run(current(butterfly=2.0e-3, vs_fused=1.4), baseline())
    assert len(failures) == 1 and "butterfly@fft@64" in failures[0]


def test_healthy_signal_missing_stays_warn():
    # no amortization key at all: conservative, never promote to FAIL
    cur = current(floor=1.0e-3)
    del cur["s1_over_s8_speedup"]
    assert run(cur, baseline()) == []


def test_reference_override_tunes_one_check():
    # a platform with a known-different floor pins its own reference; the
    # same value that would have tripped the default baseline passes
    cur = current(floor=1.5e-3, amort=1.0)  # collapsed health, 15x default
    assert run(cur, baseline()) != []  # default reference: FAIL
    assert run(cur, baseline(
        references={"floor@64": {"reference": 1.0e-3, "factor": 2.0}})) == []


def test_malformed_value_fails_sanity():
    failures = run(current(floor=-1.0), baseline())
    assert any("finite and positive" in f for f in failures)
    failures = run(current(floor="soon"), baseline())
    assert any("not a number" in f for f in failures)


def test_zero_judged_family_is_a_failure():
    # a current run whose rows all went missing must not pass by SKIPs
    cur = {"floor_wall_per_step": {}, "s1_over_s8_speedup": {}}
    failures = run(cur, baseline())
    assert any("judged 0 floor@* checks" in f for f in failures)


def test_baseline_without_floors_fails():
    assert run(current(), {"something": 1}) != []


def test_butterfly_family_armed_only_with_baseline_keys():
    # pre-butterfly baselines carry no keys: nothing to guard, no failure
    base = {"floor_wall_per_step": {"64": 1.0e-4}}
    cur = {"floor_wall_per_step": {"64": 1.0e-4},
           "s1_over_s8_speedup": {"64": 3.0}}
    assert run(cur, base) == []


def sane_model_file():
    return {
        "schema": 1,
        "entries": {
            "cpu|d2|p64": {
                "source": "measured", "exchange_row_steps": 12000.0,
                "launch_us": 33.0, "row_step_us": 0.012,
                "halo_exchange_us": {"xla": 150.0},
                "stride_exchange_us": {"xla": 120.0},
                "gather_us": {"64": 160.0},
                "platform": "cpu", "devices": 2, "payload": 64,
            },
        },
    }


def test_cost_model_sane_passes_and_is_summarized(capsys):
    assert run(current(), baseline(), cost_model=sane_model_file()) == []
    out = capsys.readouterr().out
    assert "cost_model[cpu|d2|p64]" in out and "exchange=12000" in out


def test_cost_model_garbage_fails():
    bad = sane_model_file()
    bad["entries"]["cpu|d2|p64"]["launch_us"] = -5.0
    failures = run(current(), baseline(), cost_model=bad)
    assert any("launch_us" in f for f in failures)
    missing = sane_model_file()
    del missing["entries"]["cpu|d2|p64"]["row_step_us"]
    failures = run(current(), baseline(), cost_model=missing)
    assert any("row_step_us" in f for f in failures)
    unmeasured = sane_model_file()
    unmeasured["entries"]["cpu|d2|p64"]["source"] = "analytic"
    failures = run(current(), baseline(), cost_model=unmeasured)
    assert any("not 'measured'" in f for f in failures)
    assert any("no entries" in f
               for f in run(current(), baseline(),
                            cost_model={"schema": 1, "entries": {}}))


# ------------------------------------------------- chaos leg (resilience)


def chaos_art(*, identical=True, tax=1.2, armor=1.3, schema=1):
    def cls(name, bit):
        return {"rows": 2, "max_recovery_tax": tax, "bit_identical": bit,
                "total_retries": 3, "total_replays": 1}

    return {
        "schema": schema,
        "rows": [{"fault": "transport", "bit_identical": identical}],
        "verdict": {
            "recovery_bit_identical": identical,
            "max_armor_tax": armor,
            "max_hook_tax": 1.02,
            "per_class": {"transport": cls("transport", identical),
                          "launch": cls("launch", identical),
                          "straggler": {"rows": 1, "max_recovery_tax": 3.2,
                                        "bit_identical": identical}},
            "devices_proven": [1, 4] if identical else [],
        },
    }


def run_chaos(art, max_recovery_tax=2.5, max_armor_tax=3.0):
    return fg.check(current(), baseline(), 2.0, 1.05,
                    chaos_art=art, max_recovery_tax=max_recovery_tax,
                    max_armor_tax=max_armor_tax)


def test_chaos_healthy_artifact_passes():
    assert run_chaos(chaos_art()) == []


def test_chaos_tax_regression_alone_warns(capsys):
    # two-signal rule: 4x recovery tax with bit-identity intact is a WARN
    assert run_chaos(chaos_art(tax=4.0)) == []
    assert "SLOW-RUNNER?" in capsys.readouterr().out


def test_chaos_tax_regression_with_identity_loss_fails():
    failures = run_chaos(chaos_art(identical=False, tax=4.0))
    assert any("chaos@tax" in f and "health signal collapsed" in f
               for f in failures)


def test_chaos_identity_loss_alone_fails():
    failures = run_chaos(chaos_art(identical=False))
    assert any("chaos@identity" in f and "NOT bit-identical" in f
               for f in failures)


def test_chaos_straggler_tax_is_not_judged(capsys):
    # the straggler row's tax is a deliberate stall, never a regression
    assert run_chaos(chaos_art()) == []
    assert "chaos@tax:straggler" not in capsys.readouterr().out


def test_chaos_schema_drift_fails():
    failures = run_chaos(chaos_art(schema=99))
    assert any("chaos@schema" in f for f in failures)


def test_chaos_armor_tax_regression_warns_not_fails(capsys):
    assert run_chaos(chaos_art(armor=5.0)) == []
    assert "chaos@armor" in capsys.readouterr().out


def test_real_chaos_artifact_if_present():
    """The committed/CI chaos.json (when one exists locally) must satisfy
    its own guard — catches schema drift between chaos.py and the leg."""
    import json

    path = pathlib.Path(__file__).resolve().parents[1] / \
        "artifacts/bench/chaos.json"
    if not path.exists():
        import pytest

        pytest.skip("no local chaos artifact")
    with open(path) as f:
        art = json.load(f)
    failures = run_chaos(art, max_recovery_tax=1e9, max_armor_tax=1e9)
    assert failures == []


# ------------------------------------------------------- scaling@ (PR 9)


def scaling_art(*, eff=0.8, gd=16, pallas=1.2, bsp=3.5, speedup=1.3,
                **kw):
    art = {
        "guard": {
            "guard_devices": gd,
            "weak_efficiency": eff,
            "strong_efficiency": 0.2,
            "pallas_wall_per_task_us": pallas,
            "bsp_wall_per_task_us": bsp,
        },
    }
    if speedup is not None:
        art["guard"]["chunked_speedup_at_16plus"] = speedup
    art.update(kw)
    return art


def run_scaling(cur, base=None, **kw):
    return fg.check(current(), baseline(), 2.0, 1.05,
                    scaling_art=cur, scaling_base=base, **kw)


def test_scaling_healthy_artifact_passes():
    assert run_scaling(scaling_art(), scaling_art()) == []


def test_scaling_weak_regression_alone_warns(capsys):
    # efficiency halved vs the committed baseline, but the run's own
    # pallas/bsp ratio is healthy: slow runner territory
    assert run_scaling(scaling_art(eff=0.3), scaling_art(eff=0.8)) == []
    out = capsys.readouterr().out
    assert "SLOW-RUNNER?" in out and "[FAIL]" not in out


def test_scaling_weak_regression_with_pallas_above_bsp_fails():
    failures = run_scaling(scaling_art(eff=0.3, pallas=6.0, bsp=3.0),
                           scaling_art(eff=0.8))
    assert len(failures) == 1
    assert "scaling@weak:D16" in failures[0]
    assert "health signal collapsed" in failures[0]


def test_scaling_gather_slowdown_fails_without_escape():
    # the ablation ratio comes from ONE worker process: chunked falling
    # behind monolithic at D>=16 is a real regression, no slow-runner out
    failures = run_scaling(scaling_art(speedup=0.7), scaling_art())
    assert len(failures) == 1 and "scaling@gather" in failures[0]


def test_scaling_smoke_artifact_skips_gather_but_family_holds(capsys):
    # a D<=8 smoke artifact has no 16+ ablation: gather SKIPs, the
    # schema check still judges the family
    assert run_scaling(scaling_art(gd=8, speedup=None),
                       scaling_art(gd=8, speedup=None)) == []
    assert "scaling@gather" in capsys.readouterr().out


def test_scaling_guard_devices_mismatch_skips_weak(capsys):
    # efficiency at D=8 says nothing about the D=16 bar: no reference
    assert run_scaling(scaling_art(gd=8), scaling_art(gd=16)) == []
    assert "no reference value" in capsys.readouterr().out


def test_scaling_reference_override_is_keyed_by_guard_devices():
    cur = scaling_art(eff=0.3, pallas=6.0, bsp=3.0)  # collapsed health
    assert run_scaling(cur, scaling_art(eff=0.8)) != []
    assert run_scaling(cur, scaling_art(
        eff=0.8,
        references={"scaling@weak:D16": {"reference": 3.0,
                                         "factor": 2.0}})) == []


def test_scaling_malformed_guard_fails_sanity():
    failures = run_scaling({"guard": {}}, scaling_art())
    assert any("scaling@schema" in f for f in failures)
    failures = run_scaling(scaling_art(eff=-0.5), scaling_art())
    assert any("out of (0, 2]" in f for f in failures)


def test_real_scaling_artifact_if_present():
    """The committed fig2_scaling artifacts must satisfy their own guard
    against themselves — catches schema drift between fig2_scaling.py and
    this leg."""
    import json

    bench = pathlib.Path(__file__).resolve().parents[1] / "artifacts/bench"
    found = False
    for name in ("fig2_scaling.json", "fig2_scaling_smoke.json"):
        path = bench / name
        if not path.exists():
            continue
        found = True
        with open(path) as f:
            art = json.load(f)
        assert run_scaling(art, art) == []
    if not found:
        import pytest

        pytest.skip("no local scaling artifact")


# ------------------------------------------------ serve leg (PR 10)


def serve_art(*, identical=True, dynamic=True, stacked=2, p99=40.0,
              util=0.85, schema=1):
    return {
        "schema": schema,
        "smoke": True,
        "rows": [{"slots": 2, "p99_ms": p99, "slot_utilization": util,
                  "bit_identical": identical}],
        "verdict": {
            "bit_identical": identical,
            "dynamic_cohort": dynamic,
            "min_stacked_cohorts": stacked,
            "p99_ms_by_slots": {"2": p99},
        },
    }


def run_serve(art, base=None, min_slot_utilization=0.5):
    return fg.check(current(), baseline(), 2.0, 1.05,
                    serve_art=art, serve_base=base or serve_art(),
                    min_slot_utilization=min_slot_utilization)


def test_serve_healthy_artifact_passes():
    assert run_serve(serve_art()) == []


def test_serve_p99_regression_alone_warns(capsys):
    # two-signal rule: 4x p99 with slots still busy is a WARN
    assert run_serve(serve_art(p99=160.0)) == []
    assert "SLOW-RUNNER?" in capsys.readouterr().out


def test_serve_p99_regression_with_idle_slots_fails():
    failures = run_serve(serve_art(p99=160.0, util=0.2))
    assert any("serve@p99" in f and "health signal collapsed" in f
               for f in failures)


def test_serve_identity_loss_alone_fails():
    failures = run_serve(serve_art(identical=False))
    assert any("serve@identity" in f and "NOT bit-identical" in f
               for f in failures)


def test_serve_static_cohorts_fail():
    failures = run_serve(serve_art(dynamic=False))
    assert any("serve@churn" in f and "continuous batching degraded" in f
               for f in failures)
    failures = run_serve(serve_art(stacked=1))
    assert any("serve@churn" in f and "collapsed compatibility" in f
               for f in failures)


def test_serve_schema_drift_fails():
    failures = run_serve(serve_art(schema=99))
    assert any("serve@schema" in f for f in failures)


def test_serve_without_baseline_skips_p99_but_judges_contract(capsys):
    assert fg.check(current(), baseline(), 2.0, 1.05,
                    serve_art=serve_art(), serve_base=None) == []
    out = capsys.readouterr().out
    assert "serve@p99:K2" in out and "no reference value" in out


def test_real_serve_artifact_if_present():
    """The committed serving baseline must satisfy its own guard against
    itself — catches schema drift between serve_taskbench.py and this
    leg."""
    import json

    bench = pathlib.Path(__file__).resolve().parents[1] / "artifacts/bench"
    path = bench / "serve_taskbench_baseline.json"
    if not path.exists():
        import pytest

        pytest.skip("no local serve artifact")
    with open(path) as f:
        art = json.load(f)
    assert run_serve(art, base=art) == []
