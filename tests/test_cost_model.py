"""Measured cost model (kernels/probes.py): precedence, cache codec,
resolver parity, plan re-routing.

Three contracts keep the autotuner honest:

  precedence   explicit model > REPRO_PIPELINE_EXCHANGE_ROW_STEPS env >
               cached probes (REPRO_COST_MODEL) > analytic fallback —
               locked here so a cached calibration can never shadow a
               deliberate env override, and an explicit model always wins.
  parity       a MEASURED model whose exchange_row_steps equals the
               analytic constant makes every depth resolver decide
               IDENTICALLY to the analytic fallback across a shape grid —
               measurement refines the constants, never the rules.
  re-routing   only a measured model may flip a butterfly's "auto" from
               the per-step stride plan to the blocked all-gather plan,
               the verdict reason names the measured numbers, and the
               re-routed schedule stays bit-compatible with fused.

conftest pins REPRO_COST_MODEL=off so the ambient cache can't leak in;
tests that need a cache point the env at a tmp_path file.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core import KernelSpec, TaskGraph, get_runtime
from repro.kernels import probes
from repro.kernels import schedule


def graph(pattern, **kw):
    base = dict(steps=6, width=16, payload=8,
                kernel=KernelSpec("compute_bound", 8), radius=2, seed=3)
    base.update(kw)
    return TaskGraph(pattern=pattern, **base)


def measured(**kw):
    """A fully-populated measured model (rankable unless overridden)."""
    base = dict(
        source="measured", exchange_row_steps=512.0, launch_us=50.0,
        row_step_us=0.1, halo_exchange_us={"xla": 51.2},
        stride_exchange_us={"xla": 40.0}, gather_us={64: 30.0, 512: 90.0},
        platform=probes._platform(), devices=1, payload=8)
    base.update(kw)
    return probes.CostModel(**base)


# ------------------------------------------------------------- cache codec


def test_cache_round_trip_and_merge(tmp_path):
    path = tmp_path / "cm.json"
    m1 = measured(payload=8)
    probes.save_cost_model(m1, path)
    loaded = probes.load_cost_model(path)
    assert loaded == {m1.cache_key(): m1}
    # gather widths survive the str->int JSON round trip exactly
    assert loaded[m1.cache_key()].gather_us == {64: 30.0, 512: 90.0}
    # a second calibration MERGES (different payload = different key)
    m2 = measured(payload=128)
    probes.save_cost_model(m2, path)
    loaded = probes.load_cost_model(path)
    assert set(loaded) == {m1.cache_key(), m2.cache_key()}
    assert loaded[m1.cache_key()] == m1
    # recalibrating an existing key REPLACES it
    m1b = dataclasses.replace(m1, launch_us=99.0)
    probes.save_cost_model(m1b, path)
    assert probes.load_cost_model(path)[m1.cache_key()].launch_us == 99.0


def test_cache_rejects_corruption_loudly(tmp_path):
    path = tmp_path / "cm.json"
    path.write_text("{ not json")
    with pytest.raises(ValueError, match="corrupt"):
        probes.load_cost_model(path)
    path.write_text(json.dumps({"schema": 999, "entries": {}}))
    with pytest.raises(ValueError, match="schema"):
        probes.load_cost_model(path)
    entry = measured().to_dict()
    entry["mystery_field"] = 1
    path.write_text(json.dumps(
        {"schema": probes.SCHEMA_VERSION, "entries": {"k": entry}}))
    with pytest.raises(ValueError, match="corrupt"):
        probes.load_cost_model(path)


def test_match_entry_platform_devices_payload():
    a = measured(devices=2, payload=8)
    b = measured(devices=2, payload=128)
    other = measured(devices=4, payload=8)
    alien = measured(platform="tpu", devices=2, payload=8)
    entries = {m.cache_key(): m for m in (a, b, other, alien)}
    plat = probes._platform()
    # device count must match exactly; payload picks the nearest probe
    assert probes._match_entry(entries, plat, 2, 8) == a
    assert probes._match_entry(entries, plat, 2, 100) == b
    assert probes._match_entry(entries, plat, 4, 999) == other
    assert probes._match_entry(entries, plat, 8, 8) is None
    assert probes._match_entry(entries, "rocm", 2, 8) is None


# -------------------------------------------------------------- precedence


def test_precedence_cached_beats_analytic(tmp_path, monkeypatch):
    path = tmp_path / "cm.json"
    probes.save_cost_model(measured(exchange_row_steps=777.0), path)
    monkeypatch.setenv(probes.COST_MODEL_ENV, str(path))
    m = probes.default_cost_model(devices=1, payload=8)
    assert m.source == "measured" and m.exchange_row_steps == 777.0
    assert schedule.exchange_row_steps() == 777.0


def test_precedence_env_beats_cache(tmp_path, monkeypatch):
    path = tmp_path / "cm.json"
    probes.save_cost_model(measured(exchange_row_steps=777.0), path)
    monkeypatch.setenv(probes.COST_MODEL_ENV, str(path))
    monkeypatch.setenv(schedule._EXCHANGE_ROW_STEPS_ENV, "99")
    m = probes.default_cost_model(devices=1, payload=8)
    assert m.source == "env" and m.exchange_row_steps == 99.0
    assert schedule.exchange_row_steps() == 99.0
    # an env model is NOT measured: it carries the constant, nothing else
    assert not m.can_rank_plans


def test_precedence_explicit_beats_env(monkeypatch):
    monkeypatch.setenv(schedule._EXCHANGE_ROW_STEPS_ENV, "99")
    explicit = measured(exchange_row_steps=321.0)
    assert schedule.exchange_row_steps(explicit) == 321.0
    # ... and the resolvers thread it through
    assert schedule.gathered_pays_off(16, 16, 4, model=explicit)


def test_precedence_off_pins_analytic(monkeypatch):
    monkeypatch.setenv(probes.COST_MODEL_ENV, "off")
    m = probes.default_cost_model()
    assert m.source == "analytic"
    assert m.exchange_row_steps == schedule.PIPELINE_EXCHANGE_ROW_STEPS
    assert not m.can_rank_plans


def test_env_override_invalid_fails_loudly(monkeypatch):
    monkeypatch.setenv(schedule._EXCHANGE_ROW_STEPS_ENV, "-3")
    with pytest.raises(ValueError, match="positive"):
        schedule.exchange_row_steps()
    monkeypatch.setenv(schedule._EXCHANGE_ROW_STEPS_ENV, "lots")
    with pytest.raises(ValueError):
        schedule.exchange_row_steps()


def test_coerce_cost_model_forms(tmp_path):
    m = measured()
    assert probes.coerce_cost_model(m) is m
    assert probes.coerce_cost_model(m.to_dict()) == m
    path = tmp_path / "cm.json"
    probes.save_cost_model(m, path)
    assert probes.coerce_cost_model(str(path), devices=1, payload=8) == m
    with pytest.raises(ValueError, match="no entry"):
        probes.coerce_cost_model(str(path), devices=64)
    with pytest.raises(TypeError):
        probes.coerce_cost_model(3.14)


# ----------------------------------------------------------------- queries


def test_gather_us_at_interpolates_and_extrapolates():
    m = measured(gather_us={64: 30.0, 512: 90.0})
    assert m.gather_us_at(64) == 30.0
    assert m.gather_us_at(512) == 90.0
    assert m.gather_us_at(288) == pytest.approx(60.0)  # midpoint
    # end-slope extrapolation, clamped at zero below the first point
    assert m.gather_us_at(1024) == pytest.approx(158.57, abs=0.1)
    assert m.gather_us_at(1) >= 0.0
    assert measured(gather_us={64: 30.0}).gather_us_at(512) == 30.0
    assert measured(gather_us={}).gather_us_at(64) is None


def test_stride_us_for_fallback():
    m = measured(stride_exchange_us={"xla": 40.0, "ppermute": 25.0})
    assert m.stride_us_for("xla") == 40.0
    assert m.stride_us_for("shmem") == 25.0  # any probed transport
    assert measured(stride_exchange_us={}).stride_us_for("xla") is None


def test_describe_names_the_verdict_source():
    assert "analytic fallback" in probes.analytic_cost_model().describe()
    env = probes.CostModel(source="env", exchange_row_steps=99.0)
    assert schedule._EXCHANGE_ROW_STEPS_ENV in env.describe()
    d = measured().describe(width=64)
    for needle in ("measured on", "launch=", "gather=30.0us@w64", "->"):
        assert needle in d, d


# ------------------------------------------------- parity with the analytic


PARITY_SHAPES = [
    dict(block=b, radius=r, payload=p)
    for b in (32, 64, 256, 1024) for r in (1, 2, 4) for p in (8, 64, 512)
]


def test_depth_resolver_parity_measured_vs_analytic():
    """A measured model with the analytic exchange constant decides
    exactly like the analytic fallback everywhere — proof that wiring the
    model through the resolvers changed WHO supplies the constant, not
    the rules. (This is what keeps a cacheless run bit-identical.)"""
    analytic = probes.analytic_cost_model()
    twin = measured(
        exchange_row_steps=float(schedule.PIPELINE_EXCHANGE_ROW_STEPS))
    for shape in PARITY_SHAPES:
        for s in (1, 2, 4, 8, 16):
            assert (schedule.pipeline_interior_covers_exchange(
                        shape["block"], shape["radius"], s, model=analytic)
                    == schedule.pipeline_interior_covers_exchange(
                        shape["block"], shape["radius"], s, model=twin)), shape
        for pipeline in (False, True):
            assert (schedule.choose_steps_per_launch(
                        **shape, total_steps=33, pipeline=pipeline,
                        model=analytic)
                    == schedule.choose_steps_per_launch(
                        **shape, total_steps=33, pipeline=pipeline,
                        model=twin)), shape
    for width, block in [(16, 16), (64, 32), (512, 64), (2048, 256)]:
        for s in (2, 4, 8, 16):
            assert (schedule.gathered_pays_off(width, block, s,
                                               model=analytic)
                    == schedule.gathered_pays_off(width, block, s,
                                                  model=twin))
        assert (schedule.choose_steps_per_launch_gathered(
                    width=width, block=block, max_deps=2, payload=64,
                    total_steps=33, model=analytic)
                == schedule.choose_steps_per_launch_gathered(
                    width=width, block=block, max_deps=2, payload=64,
                    total_steps=33, model=twin))


# -------------------------------------------------------- plan re-routing


def test_gathered_beats_strides_analytic_always_declines():
    ok, why = schedule.gathered_beats_strides(
        width=64, block=64, steps_per_launch=4, off_block_strides=0,
        period=6, model=probes.analytic_cost_model())
    assert not ok
    assert "analytic fallback" in why


def test_gathered_beats_strides_ranks_measured_walls():
    # expensive launches + cheap gather: amortizing S launches wins
    win = measured(launch_us=500.0, row_step_us=0.01, gather_us={64: 50.0})
    ok, why = schedule.gathered_beats_strides(
        width=64, block=64, steps_per_launch=4, off_block_strides=3,
        period=6, model=win)
    assert ok
    for needle in ("measured:", "launch=500.0us", "gather=50.0us@w64"):
        assert needle in why, why
    # monstrous gather: per-step strides stay
    lose = measured(launch_us=1.0, gather_us={64: 100000.0})
    ok, why = schedule.gathered_beats_strides(
        width=64, block=64, steps_per_launch=4, off_block_strides=3,
        period=6, model=lose)
    assert not ok and "measured:" in why
    # off-block strides with no stride probe: unrankable, decline
    ok, why = schedule.gathered_beats_strides(
        width=64, block=32, steps_per_launch=4, off_block_strides=3,
        period=6, model=measured(stride_exchange_us={}))
    assert not ok and "stride-exchange" in why


def test_auto_reroutes_butterfly_under_winning_model():
    """The new capability: a measured model that prices per-step stride
    launches above the amortized gather re-routes "auto" to the blocked
    all-gather plan — and the numerics stay bit-compatible with fused."""
    g = graph("fft", width=64, steps=9)
    win = measured(launch_us=500.0, row_step_us=0.01, gather_us={64: 50.0})
    rt = get_runtime("pallas_step", steps_per_launch="auto", cost_model=win)
    plan = rt._schedule_for_graph(g)
    assert plan.kind == "allgather" and plan.steps_per_launch > 1
    assert plan.reason.startswith("measured:")
    # fewer launches than the per-step stride plan would pay
    stride_rt = get_runtime("pallas_step", steps_per_launch=1,
                            cost_model=win)
    assert rt.dispatches_per_run(g) < stride_rt.dispatches_per_run(g)
    ref = get_runtime("fused").execute(g)
    np.testing.assert_allclose(rt.execute(g), ref, rtol=1e-5, atol=1e-6)


def test_auto_keeps_stride_when_model_declines():
    g = graph("fft", width=64, steps=9)
    # losing measured model: verdict recorded, plan unchanged
    lose = measured(launch_us=1.0, gather_us={64: 100000.0})
    plan = get_runtime("pallas_step", steps_per_launch="auto",
                       cost_model=lose)._schedule_for_graph(g)
    assert plan.kind == "stride" and plan.steps_per_launch == 1
    assert "measured:" in plan.reason
    # analytic fallback (conftest pins REPRO_COST_MODEL=off): the
    # pre-measurement behavior, with the source named in the reason
    plan = get_runtime("pallas_step",
                       steps_per_launch="auto")._schedule_for_graph(g)
    assert plan.kind == "stride" and plan.steps_per_launch == 1
    assert "analytic fallback" in plan.reason


def test_rejection_message_names_verdict_source():
    rt = get_runtime("pallas_step", gather_width_cap=64)
    ok, why = rt.supports(graph("spread", width=128))
    assert not ok
    assert "verdict source" in why and "analytic fallback" in why


def test_explicit_blocked_butterfly_routing_unchanged():
    """The pre-existing explicit-depth re-route neither needs nor
    consults a measured model — it stays under the analytic fallback."""
    g = graph("fft", width=64, steps=9)
    plan = get_runtime("pallas_step",
                       steps_per_launch=4)._schedule_for_graph(g)
    assert plan.kind == "allgather" and plan.steps_per_launch == 4
    assert plan.reason == "explicit blocked request"


# ------------------------------------------------------------------ probes


def test_run_probes_structure_and_round_trip(tmp_path):
    """Single-device smoke probes: every cost positive and finite, the
    stride probe skipped (no partner), and save/load reproduces the model
    EXACTLY (the calibration a run records is the calibration a later run
    resolves)."""
    m = probes.run_probes(devices=1, payload=8, smoke=True)
    assert m.source == "measured" and m.devices == 1 and m.payload == 8
    assert m.platform == probes._platform()
    for v in (m.exchange_row_steps, m.launch_us, m.row_step_us):
        assert np.isfinite(v) and v > 0
    assert set(m.halo_exchange_us) and all(
        v > 0 for v in m.halo_exchange_us.values())
    assert m.stride_exchange_us == {}  # single device: no XOR partner
    assert m.gather_us and all(v > 0 for v in m.gather_us.values())
    assert m.can_rank_plans
    path = probes.save_cost_model(m, tmp_path / "cm.json")
    assert probes.load_cost_model(path)[m.cache_key()] == m


# ------------------------------------- gather transport choice (PR 9)


def test_gather_impl_us_codec_round_trip():
    """The devices-dimension probes survive JSON (string keys at both
    nested int levels) and stay OPTIONAL: a pre-PR-9 dict without the
    field loads as an empty table under the same schema."""
    m = measured(devices=16,
                 gather_impl_us={"xla": {16: {64: 900.0, 256: 1100.0}},
                                 "chunked": {16: {64: 500.0}, 8: {64: 450.0}}})
    r = probes.CostModel.from_dict(json.loads(json.dumps(m.to_dict())))
    assert r == m
    assert r.gather_walls_at(64, 16) == {"xla": 900.0, "chunked": 500.0}
    # exact-device-match rule: D=8 only has the chunked probe
    assert r.gather_walls_at(64, 8) == {"chunked": 450.0}
    assert r.gather_walls_at(64, 4) == {}
    legacy = {k: v for k, v in m.to_dict().items() if k != "gather_impl_us"}
    assert probes.CostModel.from_dict(legacy).gather_impl_us == {}


def test_choose_gather_impl_measured_ranks_walls():
    m = measured(devices=16,
                 gather_impl_us={"xla": {16: {64: 900.0}},
                                 "chunked": {16: {64: 500.0}}})
    impl, why = schedule.choose_gather_impl(width=64, devices=16, model=m)
    assert impl == "chunked"
    for needle in ("measured", "chunked=500.0us", "xla=900.0us"):
        assert needle in why, why
    # the measured table outranks the structural rule in BOTH directions
    m2 = measured(devices=16,
                  gather_impl_us={"xla": {16: {64: 400.0}},
                                  "chunked": {16: {64: 500.0}}})
    impl, _ = schedule.choose_gather_impl(width=64, devices=16, model=m2)
    assert impl == "xla"


def test_choose_gather_impl_structural_crossover():
    """No devices-dimension probes -> the structural rule: monolithic
    below D=16, chunked at and above, and the reason says why."""
    for d, want in [(2, "xla"), (8, "xla"), (16, "chunked"),
                    (64, "chunked")]:
        impl, why = schedule.choose_gather_impl(width=256, devices=d,
                                                model=measured())
        assert impl == want, (d, impl, why)
    _, why = schedule.choose_gather_impl(width=256, devices=16,
                                         model=measured())
    assert "sqrt(D)" in why


def test_choose_member_shards_analytic_keeps_replicated():
    dk, why = schedule.choose_member_shards(devices=8, num_members=4,
                                            width=64)
    assert dk == 1
    assert "analytic" in why


def test_choose_member_shards_measured_prices_split():
    """With a measured model, sharding K divides the moved halo rows, so
    the priced argmin picks a real split; candidates that break a row
    ring (Dr < 2) or width divisibility are never offered."""
    m = measured(devices=8)
    dk, why = schedule.choose_member_shards(devices=8, num_members=4,
                                            width=64, steps_per_launch=2,
                                            model=m)
    assert dk == 4  # Dr=2 keeps the ring; the largest K split wins
    assert "measured" in why and "us/launch" in why
    # K=3 shares no divisor > 1 with D=8: no viable split, loud reason
    dk, why = schedule.choose_member_shards(devices=8, num_members=3,
                                            width=64, model=m)
    assert dk == 1 and "no viable" in why


def test_run_probes_smoke_includes_gather_impl_table():
    """run_probes now carries the devices-dimension transport table; on a
    single device it stays empty (nothing to rendezvous)."""
    m = probes.run_probes(devices=1, smoke=True, reps=1)
    assert m.gather_impl_us == {}
