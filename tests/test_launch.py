"""Launch-layer tests: input specs, step builders, serve loop, dry-run cell
(reduced mesh, in a subprocess), instrumentation."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, get_config, get_shape
from repro.core.instrumentation import OverheadProfiler
from repro.launch import steps as steps_lib
from repro.launch.serve import serve

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("shape_name",
                         ["train_4k", "prefill_32k", "decode_32k"])
def test_input_specs_abstract(arch, shape_name):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    specs = steps_lib.input_specs(cfg, shape)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    if shape.kind == "train":
        assert specs["batch"]["tokens"].shape == (shape.global_batch,
                                                  shape.seq_len)
    if shape.kind == "decode":
        assert specs["batch"]["tokens"].shape == (shape.global_batch, 1)
        assert specs["lengths"].shape == (shape.global_batch,)
        # cache capacity equals the stated context length (attn archs only;
        # SSM caches are O(1) in context — no seq-length dim by design)
        if cfg.family != "ssm":
            kv = [x for x in jax.tree.leaves(specs["caches"])
                  if getattr(x, "ndim", 0) == 5]
            assert any(x.shape[3] == shape.seq_len for x in kv)


def test_step_flops_estimate_orders():
    cfg = get_config("internlm2-1.8b")
    tr = steps_lib.step_flops_estimate(cfg, get_shape("train_4k"))
    pf = steps_lib.step_flops_estimate(cfg, get_shape("prefill_32k"))
    dc = steps_lib.step_flops_estimate(cfg, get_shape("decode_32k"))
    assert tr > pf > dc
    # MoE: active params < total params
    moe = get_config("mixtral-8x7b")
    tr_moe = steps_lib.step_flops_estimate(moe, get_shape("train_4k"))
    assert tr_moe < 6.0 * moe.param_count() * 4096 * 256


def test_serve_loop_reduced():
    cfg = get_config("stablelm-3b").reduced()
    res = serve(cfg, batch=2, prompt_len=12, gen=5, verbose=False)
    assert res.tokens.shape == (2, 5)
    assert (res.tokens >= 0).all() and (res.tokens < cfg.vocab).all()
    assert res.tokens_per_s > 0


def test_serve_greedy_deterministic():
    cfg = get_config("internlm2-1.8b").reduced()
    a = serve(cfg, batch=2, prompt_len=8, gen=4, verbose=False)
    b = serve(cfg, batch=2, prompt_len=8, gen=4, verbose=False)
    np.testing.assert_array_equal(a.tokens, b.tokens)


def test_overhead_profiler_reports():
    prof = OverheadProfiler(devices=4, tasks_per_step=8, flops_per_step=1e9)
    for w in (0.11, 0.1, 0.1, 0.09, 0.1):
        prof.record(w)
    rep = prof.report(skip_warmup=1)
    assert rep.steps == 4
    assert rep.best_wall <= rep.p50_wall <= rep.mean_wall * 1.2
    assert rep.granularity_us == pytest.approx(
        rep.mean_wall * 4 / 8 * 1e6)
    assert rep.sustained_flops_per_s == pytest.approx(1e9 / rep.mean_wall)
    assert rep.step_metg_us is not None


def test_dryrun_cell_on_reduced_mesh():
    """The dry-run builder path end-to-end on a small mesh: lower, compile,
    census — proving the same code path the 512-way run uses."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import jax
        from repro.configs.registry import get_config, get_shape
        from repro.distributed.sharding import ShardingPolicy
        from repro.launch.dryrun import build_cell
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.launch.mesh import make_host_mesh

        cfg = get_config("internlm2-1.8b").reduced()
        mesh = make_host_mesh((4, 2), ("data", "model"))
        for shape_name in ("train_4k", "decode_32k"):
            shape = get_shape(shape_name)
            import dataclasses
            shape = dataclasses.replace(shape, seq_len=64, global_batch=8)
            jitted, args, policy = build_cell(cfg, shape, mesh)
            compiled = jitted.lower(*args).compile()
            census = analyze_hlo(compiled.as_text())
            assert census.flops > 0
            assert census.hbm_bytes > 0
            print("OK", shape_name, census.dot_flops)
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=480, env=env)
    assert out.returncode == 0, out.stderr
    assert out.stdout.count("OK") == 2


def test_dryrun_skip_cell_logic():
    from repro.configs.registry import cells

    skips = [(c.name, s.name) for c, s, ok in cells() if not ok]
    assert ("internlm2-1.8b", "long_500k") in skips
    assert ("mamba2-130m", "long_500k") not in skips
    assert ("gemma3-4b", "long_500k") not in skips
    assert ("hymba-1.5b", "long_500k") not in skips
    assert ("mixtral-8x7b", "long_500k") not in skips


def test_serve_reports_clean_run_healthy():
    cfg = get_config("stablelm-3b").reduced()
    res = serve(cfg, batch=2, prompt_len=8, gen=6, verbose=False)
    assert res.healthy
    assert res.flagged_steps == [] and res.poisoned_steps == []
    assert res.report.flagged_steps == 0 and res.report.poisoned_steps == 0


def test_serve_deadline_detector_flags_stalled_step(monkeypatch):
    """A decode step stalling past factor x the observed median must land
    in ServeResult.flagged_steps (and the profiler report), not vanish
    into the wall."""
    import time as time_mod

    from repro.launch import serve as serve_mod

    cfg = get_config("stablelm-3b").reduced()
    real_block = serve_mod.jax.block_until_ready
    calls = {"n": 0}

    def stalling_block(x):
        calls["n"] += 1
        # decode calls block_until_ready once per step (prefill earlier):
        # stall one late step, after the detector's warmup window
        if calls["n"] == 9:
            time_mod.sleep(0.25)
        return real_block(x)

    monkeypatch.setattr(serve_mod.jax, "block_until_ready", stalling_block)
    res = serve(cfg, batch=2, prompt_len=8, gen=12, verbose=False)
    assert len(res.flagged_steps) >= 1
    f = res.flagged_steps[0]
    assert f["wall_us"] > f["deadline_us"] > 0
    assert f["overshoot_us"] > 0
    assert res.report.flagged_steps >= 1
    assert not res.healthy


def test_overhead_report_lines_include_fault_counts():
    prof = OverheadProfiler(devices=1, tasks_per_step=1)
    for w in (0.01, 0.01, 0.01):
        prof.record(w)
    prof.flagged.append(1)
    prof.poisoned.append(2)
    rep = prof.report()
    assert rep.flagged_steps == 1 and rep.poisoned_steps == 1
    assert any("faulted steps" in ln for ln in rep.lines())
