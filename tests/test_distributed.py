"""Multi-device tests. Each test runs in a subprocess with
--xla_force_host_platform_device_count so the main pytest process keeps the
single-CPU device set (dryrun.py owns the 512-device forcing).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_runtimes_agree_on_8_devices():
    run_sub("""
        import numpy as np
        from repro.core import TaskGraph, KernelSpec, get_runtime
        for pattern in ["stencil_1d", "stencil_1d_periodic", "dom", "nearest",
                        "fft", "tree", "all_to_all", "spread",
                        "random_nearest"]:
            g = TaskGraph(steps=5, width=32, pattern=pattern, payload=8,
                          kernel=KernelSpec("compute_bound", 8), radius=2)
            ref = get_runtime("fused").execute(g)
            for name in ["bsp", "bsp_scan", "overlap"]:
                rt = get_runtime(name)
                ok, _ = rt.supports(g)
                if not ok: continue
                out = rt.execute(g)
                err = float(np.abs(out - ref).max())
                assert err < 1e-5, (pattern, name, err)
        print("ALL OK")
    """)


def test_pallas_step_multi_device_matches_fused():
    """pallas_step across real (forced-host) devices: every halo pattern,
    steps_per_launch in {1, 4, 8}, vs the fused oracle. W=16 on 4 devices
    gives B=4, so S=8 with r=1 (and any S with r=2) needs deep halos past
    the block — the multi-hop ring exchange path — and T=10 with S=4/8
    exercises the masked-tail launch. B=4 never keeps an interior, so the
    (default-on) pipeline gates itself off and launch counts stay serial."""
    run_sub("""
        import numpy as np
        from repro.core import TaskGraph, KernelSpec, get_runtime
        for pattern, radius in [("stencil_1d", 1), ("stencil_1d_periodic", 1),
                                ("dom", 1), ("nearest", 2),
                                ("random_nearest", 2), ("no_comm", 1)]:
            g = TaskGraph(steps=10, width=16, pattern=pattern, payload=8,
                          kernel=KernelSpec("compute_bound", 8),
                          radius=radius, seed=7)
            ref = get_runtime("fused").execute(g)
            for S in (1, 4, 8):
                rt = get_runtime("pallas_step", steps_per_launch=S)
                ok, why = rt.supports(g)
                assert ok, (pattern, S, why)
                out = rt.execute(g)
                err = float(np.abs(out - ref).max())
                assert err < 1e-5, (pattern, S, err)
                assert rt.dispatches_per_run(g) == 1 + -(-9 // S)
        print("ALL OK")
    """, devices=4)


def test_halo_async_exchange_parity_multi_device():
    """exchange_halos_start/join == the sync exchange_halos == a numpy
    roll oracle, for depths below a block, exactly a block, past a block
    (multi-hop), and past the whole ring (wrap), under shard_map on 4
    devices. The fused single-collective edge transport must move the
    same bits as the per-direction ppermute transport."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.compat import shard_map
        from repro.core.runtimes import _halo

        D, B, Pay = 4, 6, 5
        W = D * B
        mesh = Mesh(np.array(jax.devices()), ("shard",))
        x = np.arange(W * Pay, dtype=np.float32).reshape(W, Pay)

        def run(fn):
            f = jax.jit(shard_map(fn, mesh=mesh, check_vma=False,
                                  in_specs=P("shard"),
                                  out_specs=(P("shard"), P("shard"))))
            l, r = f(jax.device_put(x, NamedSharding(mesh, P("shard"))))
            return np.asarray(l), np.asarray(r)

        for r in (2, 6, 7, 13, 29):  # r<B, r==B, multi-hop, wrap, 5x wrap
            def sync(local, r=r):
                return _halo.exchange_halos(local, r, D, "shard")

            def started(local, r=r):
                return _halo.exchange_halos_join(
                    _halo.exchange_halos_start(local, r, D, "shard"))

            sl, sr = run(sync)
            al, ar = run(started)
            assert np.array_equal(sl, al) and np.array_equal(sr, ar), r
            # oracle: rows immediately left/right of each block, mod W
            wl = np.stack([x[(np.arange(d * B - r, d * B)) % W]
                           for d in range(D)]).reshape(D * r, Pay)
            wr = np.stack([x[(np.arange((d + 1) * B, (d + 1) * B + r)) % W]
                           for d in range(D)]).reshape(D * r, Pay)
            assert np.array_equal(sl, wl) and np.array_equal(sr, wr), r

        # edge transport parity: fused all-gather vs per-direction ppermute
        for r in (1, 3, 6):
            def edges(local, r=r, impl="xla"):
                h = _halo.exchange_edges_start(
                    local[:r], local[B - r:], D, "shard", impl=impl)
                return _halo.exchange_halos_join(h)

            xl, xr = run(lambda l, r=r: edges(l, r, "xla"))
            pl_, pr = run(lambda l, r=r: edges(l, r, "ppermute"))
            assert np.array_equal(xl, pl_) and np.array_equal(xr, pr), r
        print("ALL OK")
    """, devices=4)


def test_stride_exchange_oracle_multi_device():
    """exchange_stride_start/join == the sync spelling == a numpy oracle
    (partner block of stride bs on device d = global rows of block d XOR
    bs), for single strides, the far-side stride D-1, and a multi-stride
    start served by ONE fused collective; both transports must move the
    same bits. gather_global likewise against a roll-free global oracle."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.compat import shard_map
        from repro.core.runtimes import _halo

        D, B, Pay = 4, 5, 3
        W = D * B
        mesh = Mesh(np.array(jax.devices()), ("shard",))
        x = np.arange(W * Pay, dtype=np.float32).reshape(W, Pay)

        def run(fn, n_out):
            f = jax.jit(shard_map(fn, mesh=mesh, check_vma=False,
                                  in_specs=P("shard"),
                                  out_specs=(P("shard"),) * n_out))
            outs = f(jax.device_put(x, NamedSharding(mesh, P("shard"))))
            return [np.asarray(o) for o in outs]

        def oracle(bs):  # stacked partner blocks in device order
            return np.concatenate([x[(d ^ bs) * B:(d ^ bs) * B + B]
                                   for d in range(D)])

        for strides in [(1,), (2,), (3,), (1, 2, 3)]:
            def sync(local, ss=strides, impl="xla"):
                return _halo.exchange_stride(local, ss, D, "shard",
                                             impl=impl)

            def started(local, ss=strides):
                return _halo.exchange_stride_join(
                    _halo.exchange_stride_start(local, ss, D, "shard"))

            got = run(lambda l, ss=strides: sync(l, ss), len(strides))
            asy = run(lambda l, ss=strides: started(l, ss), len(strides))
            ppm = run(lambda l, ss=strides: sync(l, ss, "ppermute"),
                      len(strides))
            for j, bs in enumerate(strides):
                want = oracle(bs)
                assert np.array_equal(got[j], want), (strides, bs, "xla")
                assert np.array_equal(asy[j], want), (strides, bs, "async")
                assert np.array_equal(ppm[j], want), (strides, bs, "ppermute")

        # out-of-range strides fail loudly (0 = self, D = off the mesh)
        for bad in (0, D):
            try:
                _halo.exchange_stride_start(jnp.ones((B, Pay)), (bad,), D,
                                            "shard")
                raise AssertionError(f"stride {bad} accepted")
            except ValueError:
                pass

        # gather_global: the full global-order state on EVERY device;
        # out_specs P("shard") stacks each device's (W, Pay) result, so
        # the oracle is the global state tiled D times. Both transports
        # must match it bit-for-bit.
        for impl in ("xla", "ppermute"):
            f = jax.jit(shard_map(
                lambda l, impl=impl: (_halo.gather_global(
                    l, D, "shard", impl=impl),),
                mesh=mesh, check_vma=False, in_specs=P("shard"),
                out_specs=(P("shard"),)))
            out = np.asarray(f(jax.device_put(
                x, NamedSharding(mesh, P("shard"))))[0])
            assert np.array_equal(out, np.concatenate([x] * D)), impl
        print("ALL OK")
    """, devices=4)


def test_stride_exchange_single_device():
    """One device: every butterfly stride is in-block (no exchange), the
    primitive rejects any requested stride (there is no valid bs in
    [1, 1)), and gather_global is the identity — the degenerate cases the
    stride plan relies on."""
    run_sub("""
        import numpy as np, jax.numpy as jnp
        from repro.core.runtimes import _halo
        x = jnp.arange(12.0).reshape(6, 2)
        assert np.array_equal(np.asarray(_halo.gather_global(x, 1)), x)
        try:
            _halo.exchange_stride_start(x, (1,), 1, "shard")
            raise AssertionError("stride 1 accepted on 1 device")
        except ValueError:
            pass
        # non-power-of-two device counts are rejected loudly (d XOR bs
        # would leave the mesh; the transports would otherwise diverge)
        try:
            _halo.exchange_stride_start(x, (4,), 6, "shard")
            raise AssertionError("non-pow2 device count accepted")
        except ValueError as e:
            assert "power-of-two" in str(e)
        from repro.core import TaskGraph, KernelSpec, get_runtime
        g = TaskGraph(steps=6, width=16, payload=8, pattern="fft",
                      kernel=KernelSpec("compute_bound", 8))
        ref = get_runtime("fused").execute(g)
        out = get_runtime("pallas_step").execute(g)
        assert np.array_equal(np.asarray(out), np.asarray(ref))
        print("ALL OK")
    """, devices=1)


def test_pallas_step_butterfly_global_multi_device():
    """Acceptance on 4 devices: fft/tree BIT-identical to fused at S in
    {1, 8} (stride plan per-step, all-gather plan blocked with per-depth
    tables); spread/all_to_all allclose at S in {1, 4}; launch accounting
    matches the executed plan; both transports bit-identical."""
    run_sub("""
        import numpy as np
        from repro.core import TaskGraph, KernelSpec, get_runtime
        for pattern in ("fft", "tree"):
            g = TaskGraph(steps=10, width=16, payload=8, pattern=pattern,
                          kernel=KernelSpec("compute_bound", 8), seed=7)
            ref = get_runtime("fused").execute(g)
            for S in (1, 8):
                rt = get_runtime("pallas_step", steps_per_launch=S)
                out = rt.execute(g)
                assert np.array_equal(out, ref), (pattern, S, "bits differ")
                want = 10 if S == 1 else 1 + -(-9 // 8)
                assert rt.dispatches_per_run(g) == want, (pattern, S)
        for pattern, kw in (("spread", dict(fanout=3)), ("all_to_all", {})):
            g = TaskGraph(steps=10, width=16, payload=8, pattern=pattern,
                          kernel=KernelSpec("compute_bound", 8), seed=7,
                          **kw)
            ref = get_runtime("fused").execute(g)
            for S in (1, 4):
                out = get_runtime("pallas_step",
                                  steps_per_launch=S).execute(g)
                err = float(np.abs(out - ref).max())
                assert err < 1e-5, (pattern, S, err)
        g = TaskGraph(steps=10, width=16, payload=8, pattern="fft",
                      kernel=KernelSpec("compute_bound", 8), seed=7)
        a = get_runtime("pallas_step").execute(g)
        b = get_runtime("pallas_step", halo_impl="ppermute").execute(g)
        assert np.array_equal(a, b)
        # mixed-plan tuple ensemble across devices
        from repro.core import GraphEnsemble
        members = [
            TaskGraph(steps=t, width=16, payload=8, pattern=p, fanout=3,
                      kernel=KernelSpec("compute_bound", 8), seed=k)
            for k, (p, t) in enumerate(
                (("stencil_1d", 6), ("fft", 4), ("spread", 10)))
        ]
        ens = GraphEnsemble(members)
        outs = get_runtime("pallas_step").execute_ensemble(ens)
        for k, (g, out) in enumerate(zip(members, outs)):
            ref = get_runtime("fused").execute(g)
            err = float(np.abs(out - ref).max())
            assert err < 1e-5, (k, err)
        print("ALL OK")
    """, devices=4)


def test_pallas_step_pipelined_multi_device():
    """The software-pipelined schedule on 4 devices: W=128 keeps a real
    interior (B=32 > 2*S*r for S=3 r=1/2 and S=8 r=1), so the pipelined
    path engages, its deep exchange rides under the interior launch, and
    every pattern — including dom's asymmetric and random_nearest's
    per-row edge masks — stays bit-identical to the pipeline=False
    ablation and allclose to fused. S=8 with r=2 (depth 16 = B/2) checks
    the structural fallback still answers correctly."""
    run_sub("""
        import numpy as np
        from repro.core import TaskGraph, KernelSpec, get_runtime
        for pattern, radius in [("stencil_1d", 1), ("stencil_1d_periodic", 1),
                                ("dom", 1), ("nearest", 2),
                                ("random_nearest", 2)]:
            g = TaskGraph(steps=10, width=128, pattern=pattern, payload=8,
                          kernel=KernelSpec("compute_bound", 8),
                          radius=radius, seed=7)
            ref = get_runtime("fused").execute(g)
            for S in (1, 3, 8):
                outs = {}
                for pipe in (True, False):
                    rt = get_runtime("pallas_step", steps_per_launch=S,
                                     pipeline=pipe)
                    out = rt.execute(g)
                    err = float(np.abs(out - ref).max())
                    assert err < 1e-5, (pattern, S, pipe, err)
                    outs[pipe] = out
                assert np.array_equal(outs[True], outs[False]), (pattern, S)
        # transport ablation stays bit-identical across devices too
        g = TaskGraph(steps=10, width=128, pattern="stencil_1d", payload=8,
                      kernel=KernelSpec("compute_bound", 8), seed=7)
        a = get_runtime("pallas_step", steps_per_launch=4).execute(g)
        b = get_runtime("pallas_step", steps_per_launch=4,
                        halo_impl="ppermute").execute(g)
        assert np.array_equal(a, b)
        print("ALL OK")
    """, devices=4)


def test_pallas_step_multi_device_blocked_ensemble():
    """Stacked hetero-steps ensemble on 4 devices with deep exchanges: one
    launch cadence, members frozen mid-launch, each matches fused. W=16
    (B=4) exercises the serial fallback, W=128 (B=32) the pipelined
    schedule — whose boundary launch batches both sides of all K members."""
    run_sub("""
        import numpy as np
        from repro.core import (GraphEnsemble, TaskGraph, KernelSpec,
                                get_runtime)
        for width in (16, 128):
            members = [TaskGraph(steps=t, width=width, payload=8,
                                 pattern="stencil_1d",
                                 kernel=KernelSpec("compute_bound", 8), seed=k)
                       for k, t in enumerate((3, 10, 6))]
            ens = GraphEnsemble(members)
            for S in (1, 4):
                for pipe in (True, False):
                    rt = get_runtime("pallas_step", steps_per_launch=S,
                                     pipeline=pipe)
                    outs = rt.execute_ensemble(ens)
                    for k, (g, out) in enumerate(zip(members, outs)):
                        ref = get_runtime("fused").execute(g)
                        err = float(np.abs(out - ref).max())
                        assert err < 1e-5, (width, S, pipe, k, err)
        print("ALL OK")
    """, devices=4)


def test_overlap_schedule_has_collective_compute_overlap():
    """The lowered HLO of the overlap runtime must not serialize the halo
    exchange after all compute: interior FMA work is independent of the
    ppermute (checked structurally: both appear in the scan body)."""
    run_sub("""
        from repro.core import TaskGraph, KernelSpec, get_runtime
        import jax
        g = TaskGraph(steps=4, width=64, pattern="stencil_1d", payload=8,
                      kernel=KernelSpec("compute_bound", 16))
        rt = get_runtime("overlap")
        fn = rt.build(g)
        import jax.numpy as jnp
        from repro.core.task_kernels import initial_state
        x = initial_state(g.width, g.payload)
        txt = jax.jit(lambda v: fn(v)).lower(x).as_text()
        assert ("collective_permute" in txt) or ("collective-permute" in txt)
        print("OK")
    """)


def test_train_step_on_2x2_mesh_runs_and_matches_single():
    """Loss on a (data=2, model=2) mesh == single-device loss (SPMD is
    semantics-preserving)."""
    run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs.registry import get_config, get_shape
        from repro.distributed.api import sharding_context
        from repro.distributed.sharding import ShardingPolicy
        from repro.launch import steps as S
        from repro.launch.mesh import make_host_mesh
        from repro.models.model import Model
        from repro.optim.optimizer import AdamW
        from repro.data.pipeline import SyntheticTokenPipeline

        cfg = get_config("internlm2-1.8b").reduced()
        shape = get_shape("train_4k")
        model, opt = Model(cfg), AdamW()
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        pipe = SyntheticTokenPipeline(cfg, shape, batch_override=4,
                                      seq_override=32)
        batch = pipe.batch_at(0)
        step = S.make_train_step(model, opt)

        # single device
        p1, o1, m1 = jax.jit(step)(params, opt_state, batch)

        # 2x2 mesh
        mesh = make_host_mesh((2, 2), ("data", "model"))
        policy = ShardingPolicy.for_step(cfg, shape, mesh)
        def wrapped(p, o, b):
            with sharding_context(mesh, policy.rules):
                return step(p, o, b)
        pm = jax.device_put(params, policy.param_shardings(params))
        om = jax.device_put(opt_state, opt.state_shardings(policy, params))
        bm = {k: jax.device_put(v, policy.batch_shardings(batch)[k])
              for k, v in batch.items()}
        p2, o2, m2 = jax.jit(wrapped)(pm, om, bm)

        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert abs(l1 - l2) / max(abs(l1), 1e-9) < 1e-4, (l1, l2)
        # params after one step match too
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-3)
        print("OK", l1, l2)
    """, devices=4)


def test_sequence_parallel_decode_matches_local():
    run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.distributed.collectives import (
            sequence_parallel_decode_attention)
        from repro.kernels import ops
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh((4,), ("model",))
        B, Hq, Hkv, S, D = 2, 8, 2, 64, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q = jax.random.normal(ks[0], (B, Hq, D))
        kc = jax.random.normal(ks[1], (B, Hkv, S, D))
        vc = jax.random.normal(ks[2], (B, Hkv, S, D))
        lengths = jnp.array([50, 64], jnp.int32)
        # GQA flash-decode expects q grouped under kv heads; replicate layout
        qk = q.reshape(B, Hkv, Hq // Hkv, D).reshape(B, Hq, D)
        want = ops.decode_attention(qk, kc, vc, lengths, use_kernel=False)
        got = sequence_parallel_decode_attention(
            qk, kc, vc, lengths, mesh=mesh, seq_axes="model",
            use_kernel=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        # windowed too
        want_w = ops.decode_attention(qk, kc, vc, lengths, window=16,
                                      use_kernel=False)
        got_w = sequence_parallel_decode_attention(
            qk, kc, vc, lengths, mesh=mesh, seq_axes="model", window=16,
            use_kernel=False)
        np.testing.assert_allclose(np.asarray(got_w), np.asarray(want_w),
                                   rtol=1e-4, atol=1e-4)
        print("OK")
    """, devices=4)


def test_pipeline_parallel_equals_sequential():
    run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_forward
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh((4,), ("stage",))
        S, M, mb, d = 4, 8, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        w = jax.random.normal(ks[0], (S, d, d)) * (1.0 / np.sqrt(d))
        x = jax.random.normal(ks[1], (M, mb, d))

        def stage_fn(wi, h):
            return jnp.tanh(h @ wi)

        got = pipeline_forward(stage_fn, w, x, mesh=mesh, axis="stage")
        # sequential reference
        h = x
        for s in range(S):
            h = jnp.tanh(h @ w[s])
        np.testing.assert_allclose(np.asarray(got), np.asarray(h),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """, devices=4)


def test_grad_compression_int8_cross_pod():
    run_sub("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.optim.grad_compression import cross_pod_mean_int8
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh((2, 2), ("pod", "data"))
        g = jax.random.normal(jax.random.PRNGKey(0), (2, 64))  # per-pod grads
        ef = jnp.zeros((2, 64))
        key = jax.random.PRNGKey(1)

        def local(gs, efs, k):
            out, new_ef = cross_pod_mean_int8(gs[0], efs[0], k, axis="pod")
            return out[None], new_ef[None]

        fn = jax.jit(shard_map(
            local, mesh=mesh,
            in_specs=(P("pod"), P("pod"), P()), out_specs=(P("pod"), P("pod")),
        ))
        out, new_ef = fn(g, ef, key)
        want = jnp.mean(g, axis=0)
        got0 = np.asarray(out[0])
        # int8 quantization error bounded by scale
        scale = float(jnp.max(jnp.abs(g)) / 127.0)
        assert np.abs(got0 - np.asarray(want)).max() < 2 * scale
        # error feedback: ef' carries the residual => repeated rounds unbiased
        accum = np.zeros(64); ef_now = ef
        for i in range(64):
            out, ef_now = fn(g, ef_now, jax.random.fold_in(key, i))
            accum += np.asarray(out[0])
        accum /= 64
        assert np.abs(accum - np.asarray(want)).max() < 0.5 * scale
        print("OK")
    """, devices=4)


def test_spec_resolution_divisibility_guard():
    run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.distributed.api import ShardingRules, sharding_context, \
            spec_for
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh((4,), ("model",))
        rules = ShardingRules({"heads": "model", "ff": "model"})
        with sharding_context(mesh, rules):
            # 25 heads don't divide 4 -> replicated; 32 does -> sharded
            assert spec_for((25, 8), ("heads", None)) == P()
            assert spec_for((32, 8), ("heads", None)) == P("model")
        print("OK")
    """, devices=4)


def test_hierarchical_multipod_train_reduced():
    """Reduced multi-pod mesh (2,2,2): train step runs; grads flow over pod
    axis; loss finite."""
    run_sub("""
        import jax, numpy as np
        from repro.configs.registry import get_config, get_shape
        from repro.launch.train import train
        from repro.launch.mesh import make_host_mesh

        cfg = get_config("internlm2-1.8b").reduced()
        shape = get_shape("train_4k")
        mesh = make_host_mesh((2, 2, 2), ("pod", "data", "model"))
        res = train(cfg, shape, steps=3, batch=8, seq=16, mesh=mesh,
                    verbose=False, profile=False)
        assert res.steps_run == 3
        assert np.isfinite(res.final_loss)
        print("OK", res.final_loss)
    """, devices=8)


def test_resilient_ensemble_recovery_on_4_devices():
    """The PR-8 acceptance criterion at real (forced-host) device count:
    every fault class injected into the resilient executor on a 4-device
    mesh recovers bit-identically — transport/launch/straggler against the
    clean run, member death against the truncated-steps oracle."""
    run_sub("""
        import dataclasses, numpy as np
        from repro.core import GraphEnsemble, KernelSpec, TaskGraph, \\
            get_runtime
        from repro.resilience import (FaultPlan, FaultSpec, run_resilient)

        def mk(steps, seed):
            return TaskGraph(steps=steps, width=16, pattern="stencil_1d",
                             payload=16, radius=1, seed=seed,
                             kernel=KernelSpec("compute_bound", 4))

        ens = GraphEnsemble((mk(13, 0), mk(9, 1)))
        rt = get_runtime("pallas_step", steps_per_launch=4)
        clean = [np.asarray(o) for o in rt.execute_ensemble(ens)]
        for spec in [FaultSpec("transport", 1, times=2),
                     FaultSpec("launch", 1, mode="raise"),
                     FaultSpec("launch", 2, mode="poison"),
                     FaultSpec("straggler", 1, delay_s=0.001)]:
            res = run_resilient(rt, ens, plan=FaultPlan((spec,)))
            for got, ref in zip(res.outputs, clean):
                assert np.array_equal(got, ref), spec
        res = run_resilient(
            rt, ens, plan=FaultPlan((FaultSpec("member", 1, member=1),)))
        frozen = res.evicted[1]
        oracle = rt.execute_ensemble(GraphEnsemble(
            (mk(13, 0), dataclasses.replace(mk(9, 1), steps=frozen))))
        for got, ref in zip(res.outputs, oracle):
            assert np.array_equal(got, np.asarray(ref))
        print("OK frozen@", frozen)
    """, devices=4)


def test_gather_transports_match_monolithic_oracle_16_devices():
    """PR 9: the chunked hierarchical gather at D=16 (chunk group 4, a
    real two-stage split) is bit-identical to the monolithic all-gather
    AND to the numpy global-order oracle — gathers move exact row copies,
    so any reordering in the segment/stride stages would show as an exact
    mismatch here, not a tolerance failure."""
    run_sub("""
        import numpy as np
        import jax
        import jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.core.runtimes import _halo

        D = 16
        mesh = Mesh(np.array(jax.devices()[:D]), ("shard",))
        W, payload = 64, 3
        x = jnp.arange(W * payload, dtype=jnp.float32).reshape(W, payload)
        oracle = np.asarray(x)
        assert _halo.gather_chunk_group(D) == 4
        outs = {}
        for impl in ("xla", "ppermute", "chunked"):
            fn = jax.jit(shard_map(
                lambda l, impl=impl: _halo.gather_global(
                    l, D, "shard", impl=impl),
                mesh=mesh, in_specs=P("shard"), out_specs=P(None),
                check_vma=False))
            out = np.asarray(fn(x))
            assert out.shape == oracle.shape, impl
            assert (out == oracle).all(), impl
            outs[impl] = out
        assert (outs["chunked"] == outs["xla"]).all()
        print("OK")
    """, devices=16)


def test_pallas_step_deep_halo_multihop_8_devices():
    """PR 9: W=32 on 8 devices gives B=4, so S=8 with r=1 (and S=4 with
    r=2) needs halo depth past a whole neighbor block — the multi-hop
    ring path — at a device count where a hop crosses real (forced-host)
    device boundaries twice."""
    run_sub("""
        import numpy as np
        import jax
        from repro.core import TaskGraph, KernelSpec, get_runtime

        devs = jax.devices()[:8]
        for pattern, radius, S in [("stencil_1d", 1, 8), ("nearest", 2, 4)]:
            g = TaskGraph(steps=16, width=32, payload=8, pattern=pattern,
                          radius=radius,
                          kernel=KernelSpec("compute_bound", 4))
            ref = get_runtime("fused").execute(g)
            rt = get_runtime("pallas_step", devices=devs,
                             steps_per_launch=S)
            ok, why = rt.supports(g)
            assert ok, why
            out = rt.execute(g)
            np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6,
                                       err_msg=(pattern, S))
        print("OK")
    """, devices=8)


@pytest.mark.parametrize("devices,dk", [(8, 2), (16, 4)])
def test_pallas_step_member_sharded_bit_identical(devices, dk):
    """PR 9 tentpole: the K-sharded stacked ensemble on the 2D (row,
    member) mesh — D devices as (Dr, Dk) — is bit-identical to the
    replicated baseline on Dr devices (same per-device block width, so
    identical arithmetic), through both the clean run AND a resilient run
    with one member evicted mid-flight (the PR 8 act-mask semantics must
    survive the member shard)."""
    run_sub(f"""
        import numpy as np
        import jax
        from repro.core import (TaskGraph, KernelSpec, GraphEnsemble,
                                get_runtime)
        from repro.resilience.engine import run_resilient
        from repro.resilience.faults import (FaultPlan, FaultSpec,
                                             FAULT_MEMBER)

        D, dk = {devices}, {dk}
        Dr = D // dk
        devs = jax.devices()
        members = [TaskGraph(steps=8, width=4 * Dr, payload=8,
                             pattern="stencil_1d", radius=1, seed=k,
                             kernel=KernelSpec("compute_bound", 2))
                   for k in range(2 * dk)]
        ens = GraphEnsemble(members)
        rep = get_runtime("pallas_step", devices=devs[:Dr],
                          steps_per_launch=2)
        ksh = get_runtime("pallas_step", devices=devs[:D],
                          steps_per_launch=2, member_shards=dk)
        ok, why = ksh.supports_ensemble(ens)
        assert ok, why
        for u, v in zip(rep.execute_ensemble(ens),
                        ksh.execute_ensemble(ens)):
            u, v = np.asarray(u), np.asarray(v)
            assert u.shape == v.shape and (u == v).all()
        plan = FaultPlan((FaultSpec(FAULT_MEMBER, 2, member=1),))
        f_rep = run_resilient(rep, ens, plan=plan)
        f_ksh = run_resilient(ksh, ens, plan=plan)
        assert f_rep.evicted == f_ksh.evicted
        for u, v in zip(f_rep.outputs, f_ksh.outputs):
            u, v = np.asarray(u), np.asarray(v)
            assert u.shape == v.shape and (u == v).all()
        print("OK")
    """, devices=devices)


def test_member_shards_guard_names_fallback():
    """The 2D mesh builder and the runtime's member_shards resolution
    reject a non-dividing Dk LOUDLY, naming member_shards=1 as the
    fallback (mirroring exchange_stride_start's non-pow2 rejection) —
    never an opaque reshape error from inside shard_map."""
    run_sub("""
        import jax
        from repro.core import TaskGraph, KernelSpec, GraphEnsemble, get_runtime
        from repro.launch.mesh import make_row_member_mesh

        devs = jax.devices()[:8]
        try:
            make_row_member_mesh(devs, 3)
            raise SystemExit("expected ValueError for Dk=3 over 8 devices")
        except ValueError as e:
            assert "member_shards=1" in str(e), e
        members = [TaskGraph(steps=4, width=32, payload=8,
                             pattern="stencil_1d", radius=1, seed=k,
                             kernel=KernelSpec("compute_bound", 1))
                   for k in range(4)]
        try:
            get_runtime("pallas_step", devices=devs,
                        member_shards=3).execute_ensemble(
                            GraphEnsemble(members))
            raise SystemExit("expected ValueError for member_shards=3, K=4")
        except ValueError as e:
            assert "member_shards=1" in str(e), e
        print("OK")
    """, devices=8)
