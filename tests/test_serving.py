"""Serving fabric, packer, chunk-group resolver, and detector boundary.

Covers the PR's bugfix satellites alongside the tentpole:
  * ``choose_gather_chunk_group``: measured grouping probes overrule the
    sqrt(D) analytic rule; precedence explicit > env > measured >
    analytic; non-divisor overrides fail loudly.
  * ``choose_gather_impl`` ignores "chunked:g{G}" grouping rows (they
    rank the group, not the transport — previously they shadowed
    "chunked" in the impl ranking).
  * ``DeadlineDetector.note_recompile_boundary``: the first wall after a
    membership change is neither folded into the calibration median nor
    flagged as a straggler.
  * ``stacking_verdict`` + the ``schedule.resolve`` degradation record
    when an ensemble falls off the stacked fast path.
  * packer cohort keys / admission order / static packing.
  * the fabric end-to-end on the virtual LaunchClock: mixed streams ->
    >= 2 stacked cohorts, mid-run re-admission with zero recompiles,
    deadline eviction, bit-identity throughout.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import GraphEnsemble, KernelSpec, TaskGraph, get_runtime
from repro.kernels import probes, schedule
from repro.kernels.probes import CostModel
from repro.obs import Tracer
from repro.resilience.detect import DeadlineDetector
from repro.serving import (
    LaunchClock,
    ServingFabric,
    cohort_key,
    make_request,
    order_key,
    pack,
)

WIDTH = 8


def _graph(pattern="stencil_1d", steps=5, width=WIDTH, payload=16,
           radius=1, seed=0):
    return TaskGraph(steps=steps, width=width, pattern=pattern,
                     payload=payload, kernel=KernelSpec("compute_bound", 4),
                     radius=radius, seed=seed)


def _measured_grouping_model(*, best=8):
    walls = {"chunked:g4": 50.0, "chunked:g8": 50.0, "chunked:g16": 90.0}
    walls[f"chunked:g{best}"] = 30.0
    impl = {k: {32: {64: v}} for k, v in walls.items()}
    impl["chunked"] = {32: {64: 40.0}}
    impl["xla"] = {32: {64: 60.0}}
    return CostModel(source="measured", exchange_row_steps=1.0,
                     gather_impl_us=impl, devices=32, platform="cpu")


# ------------------------------------------------- chunk-group resolver --


def test_chunk_group_analytic_fallback():
    from repro.core.runtimes import _halo

    g, reason = schedule.choose_gather_chunk_group(devices=32, width=64)
    assert g == _halo.gather_chunk_group(32)
    assert 32 % g == 0
    assert "analytic" in reason and "sqrt" in reason


def test_chunk_group_measured_overrules_analytic():
    m = _measured_grouping_model(best=8)
    g, reason = schedule.choose_gather_chunk_group(
        devices=32, width=64, model=m)
    assert g == 8
    assert "measured" in reason and "g8=30.0us" in reason
    # ties break toward the smaller group deterministically
    tie = _measured_grouping_model(best=4)
    tie.gather_impl_us["chunked:g8"][32][64] = 30.0
    g2, _ = schedule.choose_gather_chunk_group(devices=32, width=64,
                                               model=tie)
    assert g2 == 4


def test_chunk_group_needs_two_candidates():
    m = dataclasses.replace(
        _measured_grouping_model(),
        gather_impl_us={"chunked:g8": {32: {64: 30.0}},
                        "chunked": {32: {64: 40.0}}})
    from repro.core.runtimes import _halo

    g, reason = schedule.choose_gather_chunk_group(devices=32, width=64,
                                                   model=m)
    assert g == _halo.gather_chunk_group(32)  # one row cannot rank
    assert "analytic" in reason


def test_chunk_group_precedence(monkeypatch):
    m = _measured_grouping_model(best=8)
    monkeypatch.setenv("REPRO_GATHER_CHUNK_GROUP", "4")
    g, reason = schedule.choose_gather_chunk_group(
        devices=32, width=64, model=m)
    assert (g, "env" in reason) == (4, True)  # env beats measured
    g, reason = schedule.choose_gather_chunk_group(
        devices=32, width=64, model=m, explicit=16)
    assert (g, "explicit" in reason) == (16, True)  # explicit beats env
    monkeypatch.setenv("REPRO_GATHER_CHUNK_GROUP", "5")
    with pytest.raises(ValueError, match="does not divide"):
        schedule.choose_gather_chunk_group(devices=32, width=64)
    with pytest.raises(ValueError, match="does not divide"):
        schedule.choose_gather_chunk_group(devices=32, explicit=7)


def test_gather_impl_ranking_ignores_grouping_rows():
    """Grouping rows rank G, not the transport: before the fix
    "chunked:g8"'s 30us would win the impl ranking and gather_global
    would be handed a transport name it cannot dispatch."""
    m = _measured_grouping_model(best=8)
    impl, reason = schedule.choose_gather_impl(width=64, devices=32,
                                               model=m)
    assert impl == "chunked"  # 40us beats xla's 60us; g-rows excluded
    assert "chunked:g" not in reason


def test_chunk_group_candidates():
    assert probes._chunk_group_candidates(32) == (2, 4, 8, 16)
    assert probes._chunk_group_candidates(4) == (2,)
    assert probes._chunk_group_candidates(2) == ()


# ------------------------------------------------ detector boundary skip --


def test_detector_skips_recompile_boundary_wall():
    det = DeadlineDetector(factor=3.0, warmup=3)
    det.note_recompile_boundary()
    assert det.observe(1e6) is None  # compile wall: not folded, not flagged
    assert det.boundary_skips == 1
    for _ in range(3):
        assert det.observe(300.0) is None
    # median calibrated from the clean walls only: 1e6 would have wrecked it
    assert det.deadline_us() == pytest.approx(900.0, rel=0.01)
    hit = det.observe(1e6)
    assert hit is not None and hit.wall_us == 1e6


def test_detector_boundary_skip_with_measured_expectation():
    det = DeadlineDetector(factor=2.0, expected_us=400.0)
    det.note_recompile_boundary()
    assert det.observe(5e5) is None  # priced deadline exists, still skipped
    assert det.boundary_skips == 1
    assert det.observe(5e5) is not None  # next breach is real


def test_detector_boundary_flag_is_one_shot():
    det = DeadlineDetector(factor=2.0, expected_us=100.0)
    det.note_recompile_boundary()
    det.note_recompile_boundary()  # idempotent: still one skip
    assert det.observe(1e5) is None
    assert det.observe(1e5) is not None
    assert det.boundary_skips == 1


# --------------------------------------- stacking verdict + trace record --


def test_stacking_verdict_names_the_off_plan_member():
    rt = get_runtime("pallas_step", steps_per_launch=2)
    ok, reason = rt.stacking_verdict(
        GraphEnsemble((_graph(), _graph(seed=7))))
    assert ok and "stacked" in reason
    ok, reason = rt.stacking_verdict(
        GraphEnsemble((_graph(), _graph(pattern="all_to_all"))))
    assert not ok
    assert "member 1" in reason and "all_to_all" in reason
    ok, reason = rt.stacking_verdict(
        GraphEnsemble((_graph(), _graph(width=2 * WIDTH))))
    assert not ok and "width" in reason


def test_degradation_emits_schedule_resolve_record():
    tr = Tracer()
    rt = get_runtime("pallas_step", steps_per_launch=2, trace=tr)
    ens = GraphEnsemble((_graph(), _graph(pattern="all_to_all")))
    rt.build_ensemble_launches(ens)
    recs = [s for s in tr.spans
            if s.name == "schedule.resolve"
            and s.attrs.get("stacked") is False]
    assert recs, "falling off the stacked fast path must leave a record"
    assert "off the stacked fast path" in recs[-1].attrs["reason"]
    assert recs[-1].attrs["members"] == 2


def test_stacked_ensemble_leaves_no_degradation_record():
    tr = Tracer()
    rt = get_runtime("pallas_step", steps_per_launch=2, trace=tr)
    rt.build_ensemble_launches(GraphEnsemble((_graph(), _graph(seed=3))))
    assert not [s for s in tr.spans if s.name == "schedule.resolve"
                and s.attrs.get("stacked") is False]


# ----------------------------------------------------------------- packer --


def test_cohort_key_partitions_by_operand_identity():
    rt = get_runtime("pallas_step", steps_per_launch=2)
    base = cohort_key(rt, _graph())
    assert cohort_key(rt, _graph(steps=11, seed=9)) == base  # only state
    assert cohort_key(rt, _graph(width=2 * WIDTH)) != base
    assert cohort_key(rt, _graph(pattern="nearest", radius=2)) != base
    assert cohort_key(rt, _graph(pattern="all_to_all")) != base
    # seed-structured patterns bake the seed into the tables themselves
    assert (cohort_key(rt, _graph(pattern="random_nearest", seed=1))
            != cohort_key(rt, _graph(pattern="random_nearest", seed=2)))


def test_order_key_priority_then_deadline():
    hi = make_request(0, steps=5, priority=2, arrival_s=9.0)
    soon = make_request(1, steps=5, deadline_s=3.0)
    late = make_request(2, steps=5, deadline_s=30.0)
    plain = make_request(3, steps=5)
    assert sorted([plain, late, soon, hi], key=order_key) == [
        hi, soon, late, plain]


def test_pack_routes_mixed_stream_into_separate_cohorts():
    rt = get_runtime("pallas_step", steps_per_launch=2)
    reqs = [make_request(0, steps=5),
            make_request(1, steps=9, seed=4),
            make_request(2, steps=5, pattern="all_to_all"),
            make_request(3, steps=5, width=2 * WIDTH),
            make_request(4, steps=7, seed=8)]
    cohorts = pack(rt, reqs, max_slots=2)
    rids = sorted(sorted(r.rid for r in c) for c in cohorts)
    # three stencil requests -> one full + one spill cohort; a2a and the
    # wide stencil each isolate. Never one degraded 5-tuple.
    assert rids == [[0, 1], [2], [3], [4]]
    with pytest.raises(ValueError):
        pack(rt, reqs, max_slots=0)


# ----------------------------------------------------------------- fabric --


def _serve(reqs, *, slots, steps_per_launch=2, **kw):
    rt = get_runtime("pallas_step", steps_per_launch=steps_per_launch)
    fabric = ServingFabric(rt, max_slots=slots, verify=True,
                           clock=LaunchClock(), **kw)
    return fabric.serve(reqs)


def test_fabric_mixed_stream_end_to_end():
    reqs = [
        make_request(0, steps=9, seed=1),
        make_request(1, steps=5, seed=2, arrival_s=0.0),
        make_request(2, steps=7, seed=3, arrival_s=1.0),
        make_request(3, steps=5, seed=4, arrival_s=1.0),
        make_request(4, steps=4, pattern="all_to_all", arrival_s=2.0),
        make_request(5, steps=6, pattern="nearest", radius=2,
                     arrival_s=2.0, seed=5),
    ]
    rep = _serve(reqs, slots=2)
    assert [o.status for o in rep.outcomes].count("completed") == 6
    assert rep.bit_identical is True
    stacked = [c for c in rep.cohorts if c.kind == "stacked"]
    assert len(stacked) >= 2  # stencil cohort + nearest cohort
    churn = max(c.membership_changes for c in stacked)
    admitted = sum(c.admitted_mid_run for c in stacked)
    assert churn >= 2 and admitted >= 2  # retire -> re-admit, twice
    assert all((c.recompiles or 0) == 0 for c in rep.cohorts)
    assert any(c.kind != "stacked" for c in rep.cohorts)  # a2a stepwise
    # mid-run admissions recorded on the outcomes themselves
    mid = [o for o in rep.outcomes if o.admitted_mid_run]
    assert len(mid) >= 2
    assert all(o.effective_steps == o.graph.steps for o in rep.outcomes)


def test_fabric_deadline_eviction_is_bit_exact():
    # rid 1's explicit deadline (LaunchClock units = launches) expires
    # mid-cohort: it must be evicted at a boundary, freeze at the
    # truncated horizon, and still match the truncated serial oracle.
    reqs = [make_request(0, steps=9, seed=1),
            make_request(1, steps=9, seed=2, deadline_s=2.0)]
    rep = _serve(reqs, slots=2)
    by_rid = {o.rid: o for o in rep.outcomes}
    assert by_rid[1].status == "deadline_evicted"
    assert by_rid[1].effective_steps < 9
    assert by_rid[0].status == "completed"
    assert rep.bit_identical is True
    assert sum(c.deadline_evictions for c in rep.cohorts) == 1


def test_fabric_readmission_reuses_freed_slot_without_recompile():
    # one founder pair; rid 2 arrives later and must land in the slot
    # rid 1 (shorter) frees, inside the same cohort, no recompile.
    reqs = [make_request(0, steps=13, seed=1),
            make_request(1, steps=3, seed=2),
            make_request(2, steps=5, seed=3, arrival_s=3.0)]
    rep = _serve(reqs, slots=2)
    assert len(rep.cohorts) == 1
    c = rep.cohorts[0]
    assert c.kind == "stacked" and c.requests == 3
    assert c.admitted_mid_run == 1 and (c.recompiles or 0) == 0
    assert c.membership_changes >= 1
    assert rep.bit_identical is True
    mid = {o.rid: o for o in rep.outcomes}[2]
    assert mid.admitted_mid_run and mid.slot == 1


def test_fabric_rejects_duplicate_rids():
    rt = get_runtime("pallas_step", steps_per_launch=2)
    fabric = ServingFabric(rt, max_slots=2, clock=LaunchClock())
    with pytest.raises(ValueError, match="rid"):
        fabric.serve([make_request(0, steps=3),
                      make_request(0, steps=4)])


def test_probe_gather_grouping_rows_schema():
    """probe_gather_impl_us stores grouping anatomy under "chunked:g{G}"
    keys in the existing cache schema; explicit chunk_groups filter to
    proper divisors and singletons are dropped (cannot rank)."""
    curves = probes.probe_gather_impl_us(
        1, payload=4, widths=(8,), device_counts=(1,), reps=1,
        impls=("xla",), chunk_groups="auto")
    assert "xla" in curves
    assert not any(":" in k for k in curves)  # 1 device: nothing to group
    rt = CostModel(source="measured", exchange_row_steps=1.0,
                   gather_impl_us={k: {1: dict(v[1])}
                                   for k, v in curves.items()})
    assert rt.gather_walls_at(8, 1)  # round-trips through the query path
