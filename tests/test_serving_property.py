"""Property-based serving suite: ANY packer schedule is bit-exact.

The serving extension of test_chaos_property.py's eviction oracle: for
any drawn (pattern x slots x steps_per_launch x request schedule) —
staggered arrivals, priorities, explicit deadlines that may or may not
expire mid-cohort — the fabric's continuous-batching run (retirements
freeing act-mask slots, queued requests re-admitted mid-run via
``admit_fn``) must reproduce each request's SERIAL execution bit for bit.
The oracle is the same-K uniform ensemble truncated to the request's
effective horizon — exactly the convention the chaos suite's member
eviction check established — and the fabric's ``verify=True`` path
asserts it per request; the property test asserts the aggregate never
degrades to "close enough" float noise for any schedule.

Runs on the virtual LaunchClock (time = launch count) so schedules are
deterministic and hypothesis shrinking is meaningful. Shapes stay small:
every drawn case compiles its cohort launch plans plus oracle ensembles.

The multi-device leg runs the fabric on 4 forced-host devices in a
subprocess (test_distributed.py's pattern) and also pins the chunked
gather's forced-grouping bit-identity, since serving rows ride the same
gather transports.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import get_runtime
from repro.serving import LaunchClock, ServingFabric, make_request

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WIDTH = 8
PATTERNS = ("stencil_1d", "nearest")

#: (steps, arrival in launch units, priority, deadline offset or None)
REQ = st.tuples(st.integers(3, 11), st.integers(0, 6), st.integers(0, 2),
                st.sampled_from((None, 3.0, 9.0)))


@given(pattern=st.sampled_from(PATTERNS),
       slots=st.integers(2, 3),
       spl=st.sampled_from((1, 4)),
       drawn=st.lists(REQ, min_size=3, max_size=6))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_any_packer_schedule_is_bit_identical(pattern, slots, spl, drawn):
    radius = 2 if pattern == "nearest" else 1
    reqs = [make_request(
        rid, steps=steps, width=WIDTH, pattern=pattern, radius=radius,
        seed=17 * rid + 1, arrival_s=float(arrival),
        deadline_s=float(arrival) + dl if dl is not None else None,
        priority=priority)
        for rid, (steps, arrival, priority, dl) in enumerate(drawn)]
    rt = get_runtime("pallas_step", steps_per_launch=spl)
    fabric = ServingFabric(rt, max_slots=slots, verify=True,
                           clock=LaunchClock())
    rep = fabric.serve(reqs)
    assert len(rep.outcomes) == len(reqs)
    # EVERY outcome — completed or deadline-evicted at its frozen
    # horizon — matches its serial same-K oracle exactly
    for o in rep.outcomes:
        assert o.bit_identical is True, (o.rid, o.status, o.effective_steps)
    assert all((c.recompiles or 0) == 0 for c in rep.cohorts)
    for o in rep.outcomes:
        if o.status == "completed":
            assert o.effective_steps == reqs[o.rid].graph.steps
        else:
            assert o.status == "deadline_evicted"
            assert o.effective_steps <= reqs[o.rid].graph.steps


def run_sub(code: str, devices: int = 4, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_fabric_on_four_devices():
    """The full serving loop — packing, mid-run re-admission, deadline
    pricing — on a real 4-device mesh, bit-identity asserted in-process
    by verify=True; plus forced chunk groupings of the hierarchical
    gather staying exact (every G | D is the same rows, only the
    rendezvous anatomy differs)."""
    run_sub("""
        import numpy as np
        from repro.core import get_runtime
        from repro.core.runtimes import _halo
        from repro.serving import LaunchClock, ServingFabric, make_request
        import jax, jax.numpy as jnp

        devs = jax.devices()[:4]
        rt = get_runtime("pallas_step", devices=devs, steps_per_launch=2)
        reqs = [make_request(0, steps=9, width=16, seed=1),
                make_request(1, steps=5, width=16, seed=2),
                make_request(2, steps=7, width=16, seed=3, arrival_s=1.0),
                make_request(3, steps=5, width=16, pattern="nearest",
                             radius=2, seed=4, arrival_s=1.0)]
        rep = ServingFabric(rt, max_slots=2, verify=True,
                            clock=LaunchClock()).serve(reqs)
        assert rep.bit_identical is True, [
            (o.rid, o.bit_identical) for o in rep.outcomes]
        stacked = [c for c in rep.cohorts if c.kind == "stacked"]
        assert len(stacked) == 2, [c.kind for c in rep.cohorts]
        assert sum(c.admitted_mid_run for c in stacked) >= 1
        assert all((c.recompiles or 0) == 0 for c in rep.cohorts)

        # forced chunk groupings are bit-identical to the monolithic path
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        mesh = Mesh(np.array(devs), ("shard",))
        x = jnp.arange(8 * 3, dtype=jnp.float32).reshape(8, 3)
        ref = np.asarray(x)
        for g in (1, 2, 4):  # 1 and 4 degrade to the monolithic path
            fn = jax.jit(shard_map(
                lambda l, g=g: _halo.gather_global(
                    l, 4, "shard", impl="chunked", chunk_group=g),
                mesh=mesh, in_specs=P("shard"), out_specs=P(None),
                check_vma=False))
            assert np.array_equal(np.asarray(fn(x)), ref), g
        print("SERVE-4D OK")
    """)
