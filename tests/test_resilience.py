"""Fault-tolerant ensemble runtime (repro.resilience).

The contract under test, per DESIGN.md §11:

  * injection is declarative, seeded, and zero-cost when disarmed;
  * every recovery path is BIT-IDENTICAL — transport retries and launch
    replays reproduce the fault-free outputs exactly; member eviction
    reproduces the truncated-steps hetero-ensemble oracle exactly;
  * deadlines come from the measured cost model when one exists and from
    the run's own clean walls otherwise, and detection only reports.

Single device here; the 4-device subprocess version lives in
test_distributed.py, and the fuzzed version in test_chaos_property.py.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import GraphEnsemble, KernelSpec, TaskGraph, get_runtime
from repro.core.runtimes import _halo
from repro.resilience import (
    FAULT_LAUNCH,
    FAULT_MEMBER,
    FAULT_STRAGGLER,
    FAULT_TRANSPORT,
    DeadlineDetector,
    FaultPlan,
    FaultSpec,
    FaultState,
    RecoveryPolicy,
    TransientTransportFault,
    UnrecoverableFault,
    armed,
    install_chaos_impls,
    run_resilient,
    transport_site,
)
from repro.resilience import faults as faults_mod


def graph(steps=13, seed=0, pattern="stencil_1d", width=8):
    return TaskGraph(steps=steps, width=width, pattern=pattern, payload=16,
                     kernel=KernelSpec("compute_bound", 4), radius=1,
                     seed=seed)


def ensemble(pattern="stencil_1d"):
    return GraphEnsemble((graph(13, 0, pattern), graph(9, 1, pattern)))


def runtime(**opts):
    return get_runtime("pallas_step", **opts)


# ---------------------------------------------------------------- plans


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("cosmic_ray", 0)
    with pytest.raises(ValueError, match="launch index"):
        FaultSpec(FAULT_LAUNCH, -1)
    with pytest.raises(ValueError, match="unknown launch fault mode"):
        FaultSpec(FAULT_LAUNCH, 0, mode="segfault")
    with pytest.raises(ValueError, match="duplicate fault site"):
        FaultPlan((FaultSpec(FAULT_LAUNCH, 2), FaultSpec(FAULT_LAUNCH, 2)))
    with pytest.raises(ValueError, match="die twice"):
        FaultPlan((FaultSpec(FAULT_MEMBER, 0, member=1),
                   FaultSpec(FAULT_MEMBER, 3, member=1)))


def test_fault_plan_random_is_deterministic_and_valid():
    a = FaultPlan.random(7, num_launches=20, num_members=3, rate=0.5)
    b = FaultPlan.random(7, num_launches=20, num_members=3, rate=0.5)
    assert a == b
    assert a.specs  # rate 0.5 over 60 sites: must draw something
    assert FaultPlan.random(8, num_launches=20, num_members=3,
                            rate=0.5) != a
    # every generated spec satisfies the plan invariants by construction
    FaultPlan(specs=a.specs)


def test_fault_plan_json_roundtrip():
    plan = FaultPlan.random(3, num_launches=10, num_members=2, rate=0.4,
                            kinds=(FAULT_TRANSPORT, FAULT_LAUNCH,
                                   FAULT_MEMBER, FAULT_STRAGGLER))
    assert FaultPlan.from_dict(plan.to_dict()) == plan


def test_fault_state_consumption():
    plan = FaultPlan((FaultSpec(FAULT_TRANSPORT, 1, times=2),
                      FaultSpec(FAULT_LAUNCH, 3, mode="poison")))
    st = FaultState(plan)
    assert st.transport_should_fail(1)
    assert st.transport_should_fail(1)
    assert not st.transport_should_fail(1)  # healed after `times`
    assert not st.transport_should_fail(0)
    assert st.peek(FAULT_LAUNCH, 3).mode == "poison"
    assert st.take(FAULT_LAUNCH, 3) is not None
    assert st.take(FAULT_LAUNCH, 3) is None  # one-shot


# ------------------------------------------------- chaos transport impls


def test_install_chaos_impls_registers_wrappers():
    names = install_chaos_impls()
    assert "chaos+xla" in names
    for registry in _halo.TRANSPORT_REGISTRIES.values():
        assert "chaos+xla" in registry
    # idempotent
    assert install_chaos_impls() == names


def test_register_transport_impl_refuses_shadowing():
    with pytest.raises(ValueError, match="already registered"):
        _halo.register_transport_impl("halo", "xla", lambda *a, **k: None)
    with pytest.raises(ValueError, match="unknown transport registry"):
        _halo.register_transport_impl("warp", "x", lambda *a, **k: None)


def test_chaos_impl_raises_only_while_armed():
    install_chaos_impls()
    start = _halo.HALO_ASYNC_IMPLS["chaos+xla"]
    plan = FaultPlan((FaultSpec(FAULT_TRANSPORT, 5, times=1),))
    # disarmed: delegates straight to the base impl (here: crashes on the
    # wrong arg count, but does NOT raise an injected fault)
    with pytest.raises(TypeError):
        start()
    with armed(FaultState(plan)), transport_site(5):
        with pytest.raises(TransientTransportFault):
            start()
    # the site consumed its single failure: next call delegates again
    with armed(FaultState(plan)), transport_site(4):
        with pytest.raises(TypeError):
            start()


def test_armed_stack_restores_on_exit():
    st = FaultState(FaultPlan((FaultSpec(FAULT_LAUNCH, 0),)))
    assert faults_mod.armed_state() is None
    with armed(st):
        assert faults_mod.armed_state() is st
    assert faults_mod.armed_state() is None


# ------------------------------------------------------ engine recovery


def test_resilient_clean_matches_execute_ensemble():
    ens = ensemble()
    rt = runtime(steps_per_launch=4)
    want = rt.execute_ensemble(ens)
    res = run_resilient(rt, ens)
    assert res.launches == rt.build_ensemble_launches(ens).num_launches
    assert not res.events
    for got, ref in zip(res.outputs, want):
        np.testing.assert_array_equal(got, np.asarray(ref))


@pytest.mark.parametrize("spec", [
    FaultSpec(FAULT_TRANSPORT, 1, times=3),
    FaultSpec(FAULT_LAUNCH, 1, mode="raise"),
    FaultSpec(FAULT_LAUNCH, 2, mode="poison"),
    FaultSpec(FAULT_STRAGGLER, 1, delay_s=0.001),
], ids=["transport", "raise", "poison", "straggler"])
def test_recovery_bit_identical_per_class(spec):
    ens = ensemble()
    rt = runtime(steps_per_launch=4)
    want = [np.asarray(o) for o in rt.execute_ensemble(ens)]
    res = run_resilient(rt, ens, plan=FaultPlan((spec,)))
    for got, ref in zip(res.outputs, want):
        np.testing.assert_array_equal(got, ref)
    if spec.kind == FAULT_TRANSPORT:
        assert res.retries == spec.times
    if spec.kind == FAULT_LAUNCH:
        assert res.replays == 1
        assert any(e.mode == spec.mode for e in res.events)


@pytest.mark.parametrize("pattern", ["stencil_1d", "tree", "all_to_all"])
def test_recovery_across_plan_kinds(pattern):
    """Stacked (halo) and stepwise (stride/allgather) launch plans both
    recover bit-identically from a mixed plan."""
    ens = ensemble(pattern)
    rt = runtime(steps_per_launch=4)
    want = [np.asarray(o) for o in rt.execute_ensemble(ens)]
    plan = FaultPlan((FaultSpec(FAULT_TRANSPORT, 0, times=1),
                      FaultSpec(FAULT_LAUNCH, 1, mode="raise")))
    res = run_resilient(rt, ens, plan=plan)
    for got, ref in zip(res.outputs, want):
        np.testing.assert_array_equal(got, ref)
    assert res.retries == 1 and res.replays == 1


def test_eviction_matches_truncated_oracle():
    ens = ensemble()
    rt = runtime(steps_per_launch=4)
    res = run_resilient(
        rt, ens, plan=FaultPlan((FaultSpec(FAULT_MEMBER, 1, member=1),)))
    frozen = res.evicted[1]
    # the dead member froze at the last pre-fault launch boundary
    assert frozen == min(9, 1 + 1 * 4)
    oracle = rt.execute_ensemble(GraphEnsemble(
        (graph(13, 0), dataclasses.replace(graph(9, 1), steps=frozen))))
    for got, ref in zip(res.outputs, oracle):
        np.testing.assert_array_equal(got, np.asarray(ref))


def test_eviction_at_launch_zero_freezes_init():
    ens = ensemble()
    rt = runtime(steps_per_launch=4)
    res = run_resilient(
        rt, ens, plan=FaultPlan((FaultSpec(FAULT_MEMBER, 0, member=0),)))
    assert res.evicted[0] == 1  # nothing past the t=0 init survives
    oracle = rt.execute_ensemble(GraphEnsemble(
        (dataclasses.replace(graph(13, 0), steps=1), graph(9, 1))))
    for got, ref in zip(res.outputs, oracle):
        np.testing.assert_array_equal(got, np.asarray(ref))


def test_readmission_matches_fresh_member_oracle():
    ens = ensemble()
    rt = runtime(steps_per_launch=4)
    res = run_resilient(
        rt, ens, plan=FaultPlan((FaultSpec(FAULT_MEMBER, 0, member=1),)),
        policy=RecoveryPolicy(readmit=True))
    info = res.readmitted[1]
    assert info["launch"] == 1
    oracle = rt.execute_ensemble(GraphEnsemble((
        graph(13, 0),
        dataclasses.replace(graph(9, 1), steps=info["steps"],
                            seed=info["seed"]))))
    for got, ref in zip(res.outputs, oracle):
        np.testing.assert_array_equal(got, np.asarray(ref))


def test_transport_budget_exhaustion_raises():
    ens = ensemble()
    rt = runtime(steps_per_launch=4)
    plan = FaultPlan((FaultSpec(FAULT_TRANSPORT, 0, times=50),))
    policy = RecoveryPolicy(max_transport_retries=2,
                            backoff_base_s=1e-4, backoff_cap_s=1e-3)
    with pytest.raises(UnrecoverableFault, match="still failing"):
        run_resilient(rt, ens, plan=plan, policy=policy)


def test_resilient_emits_fault_tracer_records():
    from repro.obs import Tracer
    from repro.obs.tracer import CAT_FAULT

    ens = ensemble()
    rt = runtime(steps_per_launch=4)
    tr = Tracer()
    plan = FaultPlan((FaultSpec(FAULT_TRANSPORT, 1, times=1),))
    run_resilient(rt, ens, plan=plan, tracer=tr)
    fault_spans = [s for s in tr.spans if s.category == CAT_FAULT]
    names = {s.name for s in fault_spans}
    assert "transport_fault" in names
    assert "backoff" in names  # the backoff sleep is a real (timed) span
    assert any(s.end_us > s.start_us for s in fault_spans
               if s.name == "backoff")


def test_unsupported_backend_names_the_fallback():
    rt = get_runtime("fused")
    with pytest.raises(NotImplementedError, match="run_with_restarts"):
        rt.build_ensemble_launches(ensemble())


# ----------------------------------------------------------- detection


def test_detector_self_calibrates_from_clean_walls():
    det = DeadlineDetector(factor=4.0, warmup=3, min_deadline_us=1.0)
    assert det.deadline_us() is None
    for _ in range(3):
        assert det.observe(100.0) is None
    assert det.deadline_us() == pytest.approx(400.0)
    d = det.observe(1000.0)
    assert d is not None and d.overshoot_us == pytest.approx(600.0)
    # the flagged wall must NOT drag the median toward itself
    assert det.deadline_us() == pytest.approx(400.0)
    assert det.source == "observed"


def test_detector_prefers_measured_expectation():
    det = DeadlineDetector(factor=2.0, expected_us=50.0,
                           min_deadline_us=1.0)
    assert det.deadline_us() == pytest.approx(100.0)  # armed from launch 0
    assert det.observe(99.0) is None
    assert det.observe(101.0) is not None
    assert det.source == "measured"
    with pytest.raises(ValueError, match="factor"):
        DeadlineDetector(factor=1.0)


def test_deadline_resolver_math():
    from repro.kernels.probes import CostModel
    from repro.kernels.schedule import (expected_launch_wall_us,
                                        launch_deadline_us)

    measured = CostModel(source="measured", exchange_row_steps=100.0,
                         launch_us=50.0, row_step_us=0.5,
                         halo_exchange_us={"xla": 20.0})
    exp = expected_launch_wall_us(rows=8, steps_per_launch=4,
                                  model=measured, impl="xla")
    assert exp == pytest.approx(50.0 + 8 * 4 * 0.5 + 20.0)
    assert launch_deadline_us(rows=8, steps_per_launch=4, model=measured,
                              impl="xla", factor=10.0) == \
        pytest.approx(10.0 * exp)
    # the analytic model carries no absolute microseconds: unpriceable
    analytic = CostModel(source="analytic", exchange_row_steps=600.0)
    assert expected_launch_wall_us(rows=8, steps_per_launch=4,
                                   model=analytic) is None
