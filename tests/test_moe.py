"""MoE grouped-dispatch invariants (hypothesis property tests).

The grouped dispatch (EXPERIMENTS.md §Perf #1) must preserve the routing
semantics: with ample capacity no token is dropped, the combine is the
gate-weighted sum of expert outputs, and identity experts reconstruct the
input exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import ModelConfig
from repro.models.moe import _dispatch_groups, moe_fwd, moe_init


def make_cfg(E=4, K=2, d=16, ff=8, cf=8.0):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=d, n_heads=2,
        n_kv_heads=2, d_ff=ff, vocab=32, n_experts=E, top_k=K,
        d_ff_expert=ff, capacity_factor=cf, dtype="float32",
        param_dtype="float32",
    )


def identity_params(cfg):
    """Experts that pass tokens through: silu(x@I)*(x@I)@down ... too
    nonlinear — instead use gate=0 bias trick: silu(0)=0 → out 0. We use
    near-linear small weights and compare against a dense reference
    computed with the same weights instead."""
    return moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)


def dense_reference(p, x, cfg):
    """Route every token to its top-k experts WITHOUT capacity logic."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = xt @ p["router"]
    top_v, top_e = jax.lax.top_k(logits, cfg.top_k)
    gates = jax.nn.softmax(top_v, axis=-1)
    out = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xt @ p["gate"][e]) * (xt @ p["up"][e])
        y_e = h @ p["down"][e]
        for k in range(cfg.top_k):
            w = jnp.where(top_e[:, k] == e, gates[:, k], 0.0)
            out = out + y_e * w[:, None]
    return out.reshape(B, S, D)


@given(seed=st.integers(0, 50), B=st.sampled_from([1, 2, 4]),
       S=st.sampled_from([4, 8]))
@settings(max_examples=20, deadline=None)
def test_property_no_drops_with_ample_capacity(seed, B, S):
    """capacity_factor >= E guarantees zero drops -> grouped MoE == dense
    per-token routing reference."""
    cfg = make_cfg(cf=8.0)
    p = identity_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (B, S, cfg.d_model))
    got, _ = moe_fwd(p, x, cfg, mode="train")
    want = dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_capacity_drops_reduce_output_norm():
    """Tiny capacity drops tokens -> output norm strictly below no-drop."""
    cfg_tight = make_cfg(cf=0.25)
    cfg_ample = make_cfg(cf=8.0)
    p = identity_params(cfg_ample)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, cfg_ample.d_model))
    out_t, _ = moe_fwd(p, x, cfg_tight, mode="train")
    out_a, _ = moe_fwd(p, x, cfg_ample, mode="train")
    assert float(jnp.linalg.norm(out_t)) < float(jnp.linalg.norm(out_a))


def test_decode_mode_never_drops():
    cfg = make_cfg(cf=0.01)  # absurdly tight train capacity
    p = identity_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 1, cfg.d_model))
    got, _ = moe_fwd(p, x, cfg, mode="decode")
    want = dense_reference(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_dispatch_groups_resolution():
    cfg = make_cfg()
    # no mesh context -> 1 group
    assert _dispatch_groups(cfg, 64, "train") == 1
    assert _dispatch_groups(cfg, 64, "decode") == 1


def test_grouping_invariance_outside_mesh():
    """Same tokens, different (manufactured) group counts give identical
    results when capacity is ample — grouping is a layout choice, not a
    semantic one."""
    cfg = make_cfg(cf=8.0)
    p = identity_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 8, cfg.d_model))
    base, _ = moe_fwd(p, x, cfg, mode="train")
    # reshaping batch (4,8) -> (2,16) changes N-per-group layout paths
    x2 = x.reshape(2, 16, cfg.d_model)
    alt, _ = moe_fwd(p, x2, cfg, mode="train")
    np.testing.assert_allclose(np.asarray(alt.reshape(4, 8, -1)),
                               np.asarray(base), rtol=1e-4, atol=1e-4)


def test_aux_loss_uniform_routing_lower_than_skewed():
    cfg = make_cfg(E=4, K=1)
    p = identity_params(cfg)
    # craft router weights: skewed = all tokens to expert 0
    p_skew = dict(p)
    router = np.zeros((cfg.d_model, 4), np.float32)
    router[:, 0] = 1.0
    p_skew["router"] = jnp.asarray(router)
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(6), (2, 32, cfg.d_model)))
    _, aux_skew = moe_fwd(p_skew, x, cfg, mode="train")
    _, aux_rand = moe_fwd(p, x, cfg, mode="train")
    assert float(aux_skew) > float(aux_rand)
