"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.bodies import memory_bound_pallas
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_chunk_pallas
from repro.kernels.taskbench_compute import taskbench_compute_pallas
from repro.kernels import schedule
from repro.kernels.taskbench_step import (
    WEIGHT_DTYPE,
    finalize_weights,
    prepare_step_operands,
    taskbench_step_pallas,
)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------- taskbench


@pytest.mark.parametrize("rows,payload", [(4, 16), (32, 64), (100, 130),
                                          (7, 5), (256, 128)])
@pytest.mark.parametrize("iters", [0, 1, 7, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_taskbench_compute_sweep(rows, payload, iters, dtype):
    x = jax.random.uniform(jax.random.PRNGKey(0), (rows, payload),
                           jnp.float32).astype(dtype)
    got = taskbench_compute_pallas(x, iters, interpret=True)
    want = ref.taskbench_compute_ref(x, iters)
    assert got.shape == x.shape and got.dtype == x.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **tol(dtype))


def test_taskbench_block_rows_invariance():
    x = jax.random.uniform(jax.random.PRNGKey(1), (64, 96))
    a = taskbench_compute_pallas(x, 9, block_rows=8, interpret=True)
    b = taskbench_compute_pallas(x, 9, block_rows=64, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@pytest.mark.parametrize("rows,payload", [(4, 16), (33, 70), (100, 130)])
@pytest.mark.parametrize("iters,scratch", [(0, 64), (3, 64), (7, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_taskbench_memory_sweep(rows, payload, iters, scratch, dtype):
    """memory_bound scratch-sweep body: Pallas vs jnp oracle."""
    x = jax.random.uniform(jax.random.PRNGKey(15), (rows, payload),
                           jnp.float32, 0.1, 1.0).astype(dtype)
    got = memory_bound_pallas(x, iters, scratch, interpret=True)
    want = ref.taskbench_memory_ref(x, iters, scratch)
    assert got.shape == x.shape and got.dtype == x.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **tol(dtype))


# ------------------------------------------------- fused-timestep megakernel


def _random_step_operands(key, K, S, W, D, zero_dep_rows=True):
    """Padded (idx, wgt) with random dep sets (incl. some zero-dep rows)."""
    rng = np.random.default_rng(key)
    idxs, wgts = [], []
    for k in range(K):
        dep_lists = []
        for p in range(W):
            n = int(rng.integers(0, D + 1))
            if zero_dep_rows and p % 5 == 0:
                n = 0
            dep_lists.append(list(rng.integers(0, S, n)))
        i, w = prepare_step_operands(dep_lists, W, list(range(min(W, S))) +
                                     [0] * max(0, W - S))
        pad = D - i.shape[1]
        idxs.append(np.pad(i, ((0, 0), (0, pad))))
        wgts.append(np.pad(w, ((0, 0), (0, pad))))
    return jnp.asarray(np.stack(idxs)), jnp.asarray(np.stack(wgts))


@pytest.mark.parametrize("K", [1, 4])
@pytest.mark.parametrize("S,W,payload,D", [
    (16, 16, 64, 3),    # square, aligned payload
    (20, 16, 13, 5),    # halo-extended src, ragged payload
    (7, 7, 130, 2),     # ragged rows, payload > one lane
])
@pytest.mark.parametrize("kind,iters", [("compute_bound", 8),
                                        ("memory_bound", 3), ("empty", 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_taskbench_step_parity_sweep(K, S, W, payload, D, kind, iters, dtype):
    """The megakernel (interpret) vs the pure-jnp step oracle: all kernel
    kinds x dtypes x ragged shapes x ensemble K."""
    src = jax.random.uniform(jax.random.PRNGKey(16), (K, S, payload),
                             jnp.float32, 0.1, 1.0).astype(dtype)
    idx, wgt = _random_step_operands(17, K, S, W, D)
    got = taskbench_step_pallas(src, idx, wgt, kind=kind, iterations=iters,
                                scratch=50, interpret=True)
    want = ref.taskbench_step_ref(src, idx, wgt, kind=kind, iterations=iters,
                                  scratch=50)
    assert got.shape == (K, W, payload) and got.dtype == src.dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **tol(dtype))


def test_taskbench_step_combine_modes_agree():
    """gather vs onehot must be numerically interchangeable."""
    K, S, W, P, D = 2, 12, 12, 24, 4
    src = jax.random.uniform(jax.random.PRNGKey(18), (K, S, P),
                             jnp.float32, 0.1, 1.0)
    idx, wgt = _random_step_operands(19, K, S, W, D)
    outs = [
        taskbench_step_pallas(src, idx, wgt, kind="compute_bound",
                              iterations=5, combine=mode, interpret=True)
        for mode in ("gather", "onehot")
    ]
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]),
                               rtol=1e-5, atol=1e-6)


def test_taskbench_step_window_matches_gather():
    """Window mode (shifted-slice FMAs) == gather mode on the same stencil."""
    K, B, H, P = 2, 16, 1, 10
    S = B + 2 * H
    src = jax.random.uniform(jax.random.PRNGKey(20), (K, S, P),
                             jnp.float32, 0.1, 1.0)
    # stencil window: every row averages offsets {-1, 0, +1}
    wgt_win = jnp.full((K, B, 2 * H + 1), 1.0 / 3.0, jnp.float32)
    idx_win = jnp.zeros((K, B, 2 * H + 1), jnp.int32)
    got = taskbench_step_pallas(src, idx_win, wgt_win, kind="compute_bound",
                                iterations=4, combine="window", interpret=True)
    # same dataflow via explicit gather operands
    rows = jnp.arange(B)
    idx_g = jnp.stack([rows, rows + 1, rows + 2], axis=1)[None].repeat(K, 0)
    want = taskbench_step_pallas(src, idx_g.astype(jnp.int32), wgt_win,
                                 kind="compute_bound", iterations=4,
                                 combine="gather", interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_taskbench_step_block_rows_invariance():
    K, S, W, P, D = 1, 32, 32, 16, 3
    src = jax.random.uniform(jax.random.PRNGKey(21), (K, S, P),
                             jnp.float32, 0.1, 1.0)
    idx, wgt = _random_step_operands(22, K, S, W, D)
    a = taskbench_step_pallas(src, idx, wgt, iterations=6, block_rows=8,
                              interpret=True)
    b = taskbench_step_pallas(src, idx, wgt, iterations=6, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


# ------------------------------------------ temporal-blocked megakernel


def _periodic_ext(state, depth):
    """Deep-halo extend a (K, W, P) state periodically (1-device wrap)."""
    K, W, P = state.shape
    ids = (np.arange(-depth, W + depth)) % W
    return state[:, ids, :]


def _stencil_window_weights(W, halo):
    """Per-global-row mean-over-{-1,0,1} weights, full (W, 2h+1) table."""
    return np.full((W, 2 * halo + 1), 1.0 / (2 * halo + 1), np.float32)


@pytest.mark.parametrize("K", [1, 3])
@pytest.mark.parametrize("S", [2, 5])
@pytest.mark.parametrize("combine", ["window", "gather", "onehot"])
@pytest.mark.parametrize("kind,iters", [("compute_bound", 3),
                                        ("memory_bound", 2), ("empty", 0)])
def test_taskbench_step_blocked_matches_iterated_single(K, S, combine,
                                                        kind, iters):
    """steps_per_launch=S on a depth-S*h extended buffer == S invocations
    of the single-step kernel, for every combine mode and kernel kind."""
    W, P, h = 12, 10, 1
    state = jax.random.uniform(jax.random.PRNGKey(30), (K, W, P),
                               jnp.float32, 0.1, 1.0)
    wfull = _stencil_window_weights(W, h)

    # reference: iterate the S=1 kernel (old contract) S times
    ref = state
    wgt1 = jnp.asarray(np.broadcast_to(wfull, (K, W, 3)).copy())
    rows = jnp.arange(W)
    idx1 = jnp.stack([rows, rows + 1, rows + 2], 1)[None].repeat(K, 0)
    for _ in range(S):
        ext = jnp.asarray(_periodic_ext(np.asarray(ref), h))
        ref = taskbench_step_pallas(
            ext, idx1.astype(jnp.int32), wgt1, kind=kind, iterations=iters,
            scratch=30, combine="gather", interpret=True)

    # blocked: square (K, M, *) operands
    depth = S * h
    M = W + 2 * depth
    gids = (np.arange(-depth, W + depth)) % W
    wext = jnp.asarray(np.broadcast_to(wfull[gids], (K, M, 3)).copy())
    rel = np.tile(np.array([-1, 0, 1], np.int32), (M, 1))
    iabs = np.clip(rel + np.arange(M)[:, None], 0, M - 1).astype(np.int32)
    iabs = jnp.asarray(np.broadcast_to(iabs, (K, M, 3)).copy())
    act = jnp.ones((K, S), jnp.float32)
    ext = jnp.asarray(_periodic_ext(np.asarray(state), depth))
    out = taskbench_step_pallas(
        ext, iabs, wext, act, kind=kind, iterations=iters, scratch=30,
        combine=combine, steps_per_launch=S, interpret=True)
    got = out[:, depth:depth + W]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_taskbench_step_blocked_act_mask_freezes_depths():
    """act encodes per-member inner-step horizons: member k with m active
    depths must equal iterating the single-step kernel m times."""
    K, W, P, h, S = 3, 8, 6, 1, 4
    state = jax.random.uniform(jax.random.PRNGKey(31), (K, W, P),
                               jnp.float32, 0.1, 1.0)
    wfull = _stencil_window_weights(W, h)
    depth = S * h
    M = W + 2 * depth
    gids = (np.arange(-depth, W + depth)) % W
    wext = jnp.asarray(np.broadcast_to(wfull[gids], (K, M, 3)).copy())
    idx = jnp.zeros((K, 1, 1), jnp.int32)
    # member k executes k+1 of the 4 depths
    act = jnp.asarray((np.arange(S)[None, :]
                       < np.arange(1, K + 1)[:, None]).astype(np.float32))
    ext = jnp.asarray(_periodic_ext(np.asarray(state), depth))
    out = taskbench_step_pallas(
        ext, idx, wext, act, kind="compute_bound", iterations=2,
        combine="window", steps_per_launch=S, interpret=True)
    got = out[:, depth:depth + W]

    wgt1 = jnp.asarray(wfull)[None]
    rows = jnp.arange(W)
    idx1 = jnp.stack([rows, rows + 1, rows + 2], 1)[None].astype(jnp.int32)
    for k in range(K):
        ref = state[k:k + 1]
        for _ in range(k + 1):
            ext1 = jnp.asarray(_periodic_ext(np.asarray(ref), h))
            ref = taskbench_step_pallas(
                ext1, idx1, wgt1, kind="compute_bound", iterations=2,
                combine="gather", interpret=True)
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref[0]),
                                   rtol=1e-5, atol=1e-6,
                                   err_msg=f"member {k}")


def test_taskbench_step_blocked_requires_act_and_square_operands():
    src = jnp.ones((1, 10, 4))
    wgt = jnp.ones((1, 10, 3)) / 3
    idx = jnp.zeros((1, 10, 3), jnp.int32)
    with pytest.raises(ValueError, match="act"):
        taskbench_step_pallas(src, idx, wgt, steps_per_launch=3,
                              interpret=True)
    act = jnp.ones((1, 3), jnp.float32)
    with pytest.raises(ValueError, match="square"):
        taskbench_step_pallas(src, idx, jnp.ones((1, 8, 3)) / 3, act,
                              steps_per_launch=3, interpret=True)


def test_taskbench_step_pair_combine_matches_gather():
    """pair mode ([x | partner] halves, elementwise (a+b)*0.5) must be
    bit-identical to gathering {i, W+i} at weight 0.5 from the same
    stacked buffer — the stride plan's gather-free butterfly lowering."""
    K, W, P = 2, 8, 6
    x = jax.random.uniform(jax.random.PRNGKey(40), (K, W, P),
                           jnp.float32, 0.1, 1.0)
    partner = x[:, ::-1]  # any permutation works; the kernel just pairs
    src = jnp.concatenate([x, partner], axis=1)  # (K, 2W, P)
    dummy_i = jnp.zeros((K, 1, 1), jnp.int32)
    dummy_w = jnp.zeros((K, W, 1), jnp.float32)
    got = taskbench_step_pallas(src, dummy_i, dummy_w, kind="compute_bound",
                                iterations=3, combine="pair", interpret=True)
    rows = jnp.arange(W)
    idx = jnp.broadcast_to(jnp.stack([rows, W + rows], 1), (K, W, 2))
    wgt = jnp.full((K, W, 2), 0.5, jnp.float32)
    want = taskbench_step_pallas(src, idx.astype(jnp.int32), wgt,
                                 kind="compute_bound", iterations=3,
                                 combine="gather", interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # contract violations fail loudly
    with pytest.raises(ValueError, match="pair"):
        taskbench_step_pallas(x, dummy_i, dummy_w, combine="pair",
                              interpret=True)  # src not [x | partner]
    act = jnp.ones((K, 2), jnp.float32)
    with pytest.raises(ValueError, match="per-step"):
        taskbench_step_pallas(src, dummy_i, dummy_w, act, combine="pair",
                              steps_per_launch=2, interpret=True)


# -------------------------------------- time-varying per-depth tables


@pytest.mark.parametrize("combine", ["gather", "onehot"])
def test_taskbench_step_blocked_time_varying_tables(combine):
    """(K, S, M, D) tables — one per inner depth — must equal iterating
    the single-step kernel with each depth's own table (the butterfly /
    rotation contract: XOR stride 2^d at depth d here). The working
    buffer is exactly closed under every table (global rows), so there is
    no valid-span shrink and the whole buffer is exact; weights of 0.5
    keep the check bitwise."""
    K, W, P, S = 2, 8, 6, 3
    state = jax.random.uniform(jax.random.PRNGKey(32), (K, W, P),
                               jnp.float32, 0.1, 1.0)
    rows = np.arange(W, dtype=np.int32)
    tabs = np.stack([np.stack([rows, rows ^ (1 << d)], 1)
                     for d in range(S)])  # (S, W, 2)
    idx = np.broadcast_to(tabs, (K, S, W, 2)).copy()
    wgt = np.full((K, S, W, 2), 0.5, np.float32)
    act = jnp.ones((K, S), jnp.float32)
    out = taskbench_step_pallas(
        state, jnp.asarray(idx), jnp.asarray(wgt), act,
        kind="compute_bound", iterations=3, combine=combine,
        steps_per_launch=S, interpret=True)
    ref = state
    for d in range(S):
        ref = taskbench_step_pallas(
            ref, jnp.asarray(idx[:, d]), jnp.asarray(wgt[:, d]),
            kind="compute_bound", iterations=3, combine=combine,
            interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_taskbench_step_time_varying_act_mask_freezes_depths():
    """The act machinery is UNCHANGED under time-varying tables: member k
    executing only m depths equals iterating the per-depth tables m
    times."""
    K, W, P, S = 3, 8, 4, 3
    state = jax.random.uniform(jax.random.PRNGKey(33), (K, W, P),
                               jnp.float32, 0.1, 1.0)
    rows = np.arange(W, dtype=np.int32)
    tabs = np.stack([np.stack([rows, rows ^ (1 << d)], 1)
                     for d in range(S)])
    idx = jnp.asarray(np.broadcast_to(tabs, (K, S, W, 2)).copy())
    wgt = jnp.full((K, S, W, 2), 0.5, jnp.float32)
    act = jnp.asarray((np.arange(S)[None, :]
                       < np.arange(1, K + 1)[:, None]).astype(np.float32))
    out = taskbench_step_pallas(
        state, idx, wgt, act, kind="compute_bound", iterations=2,
        combine="onehot", steps_per_launch=S, interpret=True)
    for k in range(K):
        ref = state[k:k + 1]
        for d in range(k + 1):
            ref = taskbench_step_pallas(
                ref, idx[k:k + 1, d], wgt[k:k + 1, d],
                kind="compute_bound", iterations=2, combine="onehot",
                interpret=True)
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref[0]),
                                      err_msg=f"member {k}")


def test_taskbench_step_time_varying_validation():
    src = jnp.ones((1, 8, 4))
    idx4 = jnp.zeros((1, 3, 8, 2), jnp.int32)
    wgt4 = jnp.full((1, 3, 8, 2), 0.5)
    act = jnp.ones((1, 3), jnp.float32)
    # window mode has no time-varying form
    with pytest.raises(ValueError, match="window"):
        taskbench_step_pallas(src, idx4, wgt4, act, combine="window",
                              steps_per_launch=3, interpret=True)
    # depth axis must match steps_per_launch
    with pytest.raises(ValueError, match="time-varying"):
        taskbench_step_pallas(src, idx4, wgt4, jnp.ones((1, 2)),
                              combine="onehot", steps_per_launch=2,
                              interpret=True)
    # 4-D tables make no sense on the single-step path
    with pytest.raises(ValueError, match="steps_per_launch"):
        taskbench_step_pallas(src, idx4, wgt4, combine="onehot",
                              interpret=True)


# ------------------------------------------ pipelined phase entry points


@pytest.mark.parametrize("tail", [0, 2])
def test_taskbench_phase_split_matches_full_blocked(tail):
    """interior + boundary entry points == the one-buffer blocked launch:
    stitching [left_out | interior | right_out] must be bit-identical to
    slicing the owned rows out of the full deep-halo kernel, including a
    masked tail (the hetero/final-launch case)."""
    from repro.kernels.taskbench_step import (taskbench_step_boundary,
                                              taskbench_step_interior)
    K, W, P, h, S = 2, 24, 6, 1, 4
    depth = S * h
    state = jax.random.uniform(jax.random.PRNGKey(32), (K, W, P),
                               jnp.float32, 0.1, 1.0)
    wfull = _stencil_window_weights(W, h)
    gids = (np.arange(-depth, W + depth)) % W
    wext = jnp.asarray(np.broadcast_to(wfull[gids], (K, W + 2 * depth, 3)).copy())
    idx = jnp.zeros((K, 1, 1), jnp.int32)
    act = jnp.asarray(np.broadcast_to(
        (np.arange(S) < S - tail).astype(np.float32), (K, S)).copy())
    kw = dict(kind="compute_bound", iterations=2, combine="window",
              steps_per_launch=S, interpret=True)

    ext = jnp.asarray(_periodic_ext(np.asarray(state), depth))
    full = taskbench_step_pallas(ext, idx, wext, act, **kw)[:, depth:depth + W]

    hl, hr = ext[:, :depth], ext[:, W + depth:]
    left = jnp.concatenate([hl, state[:, :2 * depth]], axis=1)
    right = jnp.concatenate([state[:, W - 2 * depth:], hr], axis=1)
    w_bnd = jnp.concatenate(
        [wext[:, :3 * depth], wext[:, W - depth:]], axis=1)
    blo, bro = taskbench_step_boundary(
        left, right, idx, w_bnd, act, depth=depth, **kw)
    mid = taskbench_step_interior(
        state, idx, wext[:, depth:depth + W], act, depth=depth, **kw)
    got = jnp.concatenate([blo, mid, bro], axis=1)
    assert np.array_equal(np.asarray(got), np.asarray(full)), \
        f"phase split changed bits (tail={tail})"


def test_taskbench_phase_entry_points_validate_shapes():
    from repro.kernels.taskbench_step import (taskbench_step_boundary,
                                              taskbench_step_interior)
    act = jnp.ones((1, 2), jnp.float32)
    idx = jnp.zeros((1, 1, 1), jnp.int32)
    with pytest.raises(ValueError, match="interior"):
        taskbench_step_interior(jnp.ones((1, 8, 4)), idx,
                                jnp.ones((1, 8, 3)), act, depth=4,
                                combine="window", steps_per_launch=2,
                                interpret=True)
    with pytest.raises(ValueError, match="boundary"):
        taskbench_step_boundary(jnp.ones((1, 8, 4)), jnp.ones((1, 6, 4)),
                                idx, jnp.ones((1, 12, 3)), act, depth=2,
                                combine="window", steps_per_launch=2,
                                interpret=True)


# ----------------------------------------------------------- schedule tuner


def test_schedule_choose_respects_vmem_budget():
    # a tiny budget forces shallow launches; a huge one allows the deepest
    tiny = schedule.choose_steps_per_launch(
        block=1024, radius=8, payload=512, vmem_budget=1 << 20)
    huge = schedule.choose_steps_per_launch(
        block=1024, radius=8, payload=512, vmem_budget=1 << 30)
    assert 1 <= tiny < huge <= max(schedule.CANDIDATES)
    # working-set model is monotone in S
    sizes = [schedule.blocked_working_set_bytes(256, 2, s, 64)
             for s in (1, 2, 4, 8)]
    assert sizes == sorted(sizes)


def test_schedule_accounts_for_combine_mode_intermediates():
    """gather/onehot carry bigger working sets than window, so 'auto' must
    pick shallower (or equal) depths for them at the same budget."""
    kw = dict(block=1024, radius=8, payload=512, vmem_budget=64 << 20)
    win = schedule.choose_steps_per_launch(combine="window", **kw)
    gat = schedule.choose_steps_per_launch(combine="gather", **kw)
    one = schedule.choose_steps_per_launch(combine="onehot", **kw)
    assert one <= gat <= win
    assert one < win  # the onehot expansion must actually bite
    for s in (1, 4):
        base = schedule.blocked_working_set_bytes(1024, 8, s, 512)
        assert schedule.blocked_working_set_bytes(
            1024, 8, s, 512, combine="gather") > base
        assert schedule.blocked_working_set_bytes(
            1024, 8, s, 512, combine="onehot") > base


def test_schedule_caps_depth_at_combine_steps():
    assert schedule.choose_steps_per_launch(
        block=64, radius=1, payload=64, total_steps=5) <= 4
    assert schedule.resolve_steps_per_launch(
        16, block=64, radius=1, payload=64, total_steps=5) == 4


def test_schedule_resolve_values():
    kw = dict(block=64, radius=1, payload=64, total_steps=100)
    assert schedule.resolve_steps_per_launch(None, **kw) == 1
    assert schedule.resolve_steps_per_launch(1, **kw) == 1
    assert schedule.resolve_steps_per_launch(8, **kw) == 8
    auto = schedule.resolve_steps_per_launch("auto", **kw)
    assert auto == schedule.choose_steps_per_launch(**kw)
    with pytest.raises(ValueError):
        schedule.resolve_steps_per_launch(-2, **kw)


def test_schedule_accounts_for_act_and_idx_operands():
    """The VMEM model charges the act mask (S f32s even at radius 0, where
    the buffer itself is S-invariant) and, for the non-window combines, the
    per-row int32 idx table on top of gather's row intermediate."""
    for s in (1, 2, 4, 8):
        assert (schedule.blocked_working_set_bytes(64, 0, s + 1, 64)
                - schedule.blocked_working_set_bytes(64, 0, s, 64)) == 4
    m = 256 + 2 * 4 * 2
    window = 2 * 2 + 1
    base = schedule.blocked_working_set_bytes(256, 2, 4, 64)
    gat = schedule.blocked_working_set_bytes(256, 2, 4, 64, combine="gather")
    gathered_rows = m * window * 128 * 4  # the (m, window, payload) gather
    assert gat - base - gathered_rows == m * window * 4  # idx table itself


def test_schedule_pipeline_working_set_and_covering():
    """Pipelined residency = max(interior, boundary program) + double-
    buffered halo slots — smaller than the monolithic serial buffer at
    wide blocks; empty-interior shapes fall back to serial accounting.
    The covering rule admits S=8 at block 256 (r=1) but rejects S=16
    (boundary work outgrows the exchange) and tiny blocks (nothing to
    hide under), and 'auto' follows it."""
    serial = schedule.blocked_working_set_bytes(1024, 8, 8, 512)
    piped = schedule.blocked_working_set_bytes(1024, 8, 8, 512,
                                               pipeline=True)
    assert piped < serial
    assert schedule.blocked_working_set_bytes(
        64, 8, 8, 512, pipeline=True) == schedule.blocked_working_set_bytes(
        64, 8, 8, 512)  # block 64 <= 2*64: no interior, serial layout
    assert schedule.pipeline_interior_covers_exchange(256, 1, 8)
    assert not schedule.pipeline_interior_covers_exchange(256, 1, 16)
    assert not schedule.pipeline_interior_covers_exchange(64, 1, 8)
    kw = dict(block=256, radius=1, payload=64, total_steps=200)
    assert schedule.choose_steps_per_launch(**kw) == 16
    assert schedule.choose_steps_per_launch(pipeline=True, **kw) == 8
    # no covering candidate -> fall back to the deepest fitting depth
    assert schedule.choose_steps_per_launch(
        block=64, radius=1, payload=64, total_steps=200, pipeline=True) == 16


def test_schedule_auto_budgets_the_schedule_it_executes():
    """A pipeline=True pick whose interior does NOT cover the exchange
    runs the SERIAL schedule, so the fallback depth must be validated
    against the serial (monolithic-buffer) sizing — not the smaller
    pipelined one (it once wasn't: block=224/r=2/payload=1024/gather
    picked S=2 whose serial working set overflowed the default budget)."""
    for combine in ("window", "gather", "onehot"):
        for radius in (1, 2, 4, 8):
            for block in (32, 64, 224, 256, 1024):
                for payload in (64, 256, 1024):
                    s = schedule.choose_steps_per_launch(
                        block=block, radius=radius, payload=payload,
                        combine=combine, pipeline=True)
                    if s <= 1:  # S=1 is the per-step path: no blocked buffer
                        continue
                    cov = schedule.pipeline_interior_covers_exchange(
                        block, radius, s)
                    ws = schedule.blocked_working_set_bytes(
                        block, radius, s, payload, combine=combine,
                        pipeline=cov)
                    assert ws <= schedule.DEFAULT_VMEM_BUDGET, \
                        (combine, radius, block, payload, s)


def test_schedule_gathered_working_set_accounting():
    """The all-gather plan's budget charges the full-width buffer AND the
    time-varying per-depth tables (S stacked (W, D) idx+wgt pairs — the
    operands the halo budget never carried)."""
    base = schedule.gathered_working_set_bytes(256, 2, 4, 64)
    deeper = schedule.gathered_working_set_bytes(256, 2, 8, 64)
    # exactly 4 more (W, D) int32+f32 tables plus 4 act floats
    assert deeper - base == 4 * 256 * 2 * 8 + 4 * 4
    static = schedule.gathered_working_set_bytes(256, 2, 8, 64,
                                                 time_varying=False)
    assert static < deeper  # static tables: one depth's tables, any S
    # combine intermediates: onehot holds the (W, W) matrix + its
    # (W, D, W) expansion; gather the (W, D, Pp) gathered rows
    one = schedule.gathered_working_set_bytes(256, 2, 4, 64)
    gat = schedule.gathered_working_set_bytes(256, 2, 4, 64,
                                              combine="gather")
    assert one - gat == (256 * 256 * 4 + 256 * 2 * 256 * 4
                         - 256 * 2 * 128 * 4)


def test_schedule_gathered_pays_off_rule():
    """Replication S*(W - B) must stay under the saved exchanges
    (S-1)*X: one device (W == B) always pays, wide replication never."""
    assert schedule.gathered_pays_off(512, 512, 16)  # 1 device: free
    assert schedule.gathered_pays_off(512, 128, 8)   # 3072 <= 3584
    assert not schedule.gathered_pays_off(1024, 256, 8)  # 6144 > 3584
    assert not schedule.gathered_pays_off(512, 128, 1)  # S=1 saves nothing


def test_schedule_gathered_choose_and_resolve():
    kw = dict(width=64, block=16, max_deps=2, payload=8)
    s = schedule.choose_steps_per_launch_gathered(total_steps=50, **kw)
    assert s > 1
    assert schedule.resolve_steps_per_launch_gathered(
        "auto", total_steps=50, **kw) == s
    assert schedule.resolve_steps_per_launch_gathered(None, **kw) == 1
    assert schedule.resolve_steps_per_launch_gathered(1, **kw) == 1
    # explicit depths clamp to the combine-step count
    assert schedule.resolve_steps_per_launch_gathered(
        8, total_steps=5, **kw) == 4
    with pytest.raises(ValueError):
        schedule.resolve_steps_per_launch_gathered(-1, **kw)
    # a pattern that can never pay (replication too wide at every S)
    assert schedule.choose_steps_per_launch_gathered(
        width=4096, block=32, max_deps=2, payload=8, total_steps=50) == 1


def test_schedule_exchange_row_steps_env_override(monkeypatch):
    """ROADMAP's per-platform re-calibration knob: the exchange-cost
    constant is env-overridable and consulted LIVE by every covering /
    pays-off rule — no reimport, invalid values fail loudly."""
    monkeypatch.delenv("REPRO_PIPELINE_EXCHANGE_ROW_STEPS", raising=False)
    assert schedule.exchange_row_steps() == \
        schedule.PIPELINE_EXCHANGE_ROW_STEPS
    assert schedule.gathered_pays_off(512, 128, 8)
    assert schedule.pipeline_interior_covers_exchange(256, 1, 8)
    monkeypatch.setenv("REPRO_PIPELINE_EXCHANGE_ROW_STEPS", "64")
    assert schedule.exchange_row_steps() == 64
    assert not schedule.gathered_pays_off(512, 128, 8)  # 3072 > 7*64
    assert not schedule.pipeline_interior_covers_exchange(256, 1, 8)
    monkeypatch.setenv("REPRO_PIPELINE_EXCHANGE_ROW_STEPS", "100000")
    assert schedule.gathered_pays_off(1024, 256, 8)
    for bad in ("0", "-5", "many"):
        monkeypatch.setenv("REPRO_PIPELINE_EXCHANGE_ROW_STEPS", bad)
        with pytest.raises(ValueError):
            schedule.exchange_row_steps()


def test_finalize_weights_single_rounding():
    """The one weight-precision policy: f64 accumulation, one f32 round."""
    acc = np.array([[1.0 / 3.0 + 1.0 / 3.0 + 1.0 / 3.0]], np.float64)
    out = finalize_weights(acc)
    assert out.dtype == WEIGHT_DTYPE
    np.testing.assert_array_equal(
        out, np.asarray(acc, np.float64).astype(np.float32))
    # prepare_step_operands flows through the same policy
    _, wgt = prepare_step_operands([[0, 1, 2]], 1, [0])
    assert wgt.dtype == WEIGHT_DTYPE


def test_prepare_step_operands_self_pads_and_normalizes():
    idx, wgt = prepare_step_operands([[1, 2], [0], [], [3, 3]], 4,
                                     [0, 1, 2, 3])
    np.testing.assert_array_equal(idx, [[1, 2], [0, 0], [2, 0], [3, 3]])
    np.testing.assert_allclose(wgt, [[0.5, 0.5], [1.0, 0.0], [1.0, 0.0],
                                     [0.5, 0.5]])
    assert wgt.sum(axis=1).tolist() == [1.0, 1.0, 1.0, 1.0]


# ----------------------------------------------------------------- rmsnorm


@pytest.mark.parametrize("rows,d", [(8, 64), (33, 100), (5, 1536), (128, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, d, dtype):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (rows, d), jnp.float32).astype(dtype)
    w = jax.random.uniform(jax.random.PRNGKey(3), (d,), jnp.float32,
                           0.5, 1.5).astype(dtype)
    got = rmsnorm_pallas(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **tol(dtype))


# --------------------------------------------------------------- attention


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Sk,D", [
    (1, 4, 4, 32, 32, 32),     # MHA
    (2, 8, 2, 64, 64, 16),     # GQA 4:1
    (1, 2, 1, 40, 72, 64),     # ragged lengths (padding paths)
    (1, 4, 2, 128, 128, 128),  # hardware-aligned
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Sk, D, causal, window):
    if not causal and Sq != Sk:
        pytest.skip("non-causal ragged not used (cross-attn is Sq!=Sk but "
                    "handled below)")
    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(keys[0], (B, Hq, Sq, D), jnp.float32)
    k = jax.random.normal(keys[1], (B, Hkv, Sk, D), jnp.float32)
    v = jax.random.normal(keys[2], (B, Hkv, Sk, D), jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 blk_q=32, blk_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_cross_no_causal():
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(keys[0], (2, 4, 48, 32))
    k = jax.random.normal(keys[1], (2, 2, 80, 32))
    v = jax.random.normal(keys[2], (2, 2, 80, 32))
    got = flash_attention_pallas(q, k, v, causal=False, blk_q=16, blk_k=32,
                                 interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    keys = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(keys[0], (1, 2, 64, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(keys[1], (1, 2, 64, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(keys[2], (1, 2, 64, 64)).astype(jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, interpret=True)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


# ----------------------------------------------------- chunked attention


@pytest.mark.parametrize("Sq,Sk,blk", [(64, 64, 16), (48, 80, 32),
                                       (128, 128, 128), (100, 36, 16)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24), (False, 0)])
def test_chunked_attention_matches_dense(Sq, Sk, blk, causal, window):
    B, Hq, Hkv, D = 2, 4, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(21), 3)
    q = jax.random.normal(keys[0], (B, Hq, Sq, D))
    k = jax.random.normal(keys[1], (B, Hkv, Sk, D))
    v = jax.random.normal(keys[2], (B, Hkv, Sk, D))
    got = ref.chunked_attention_ref(q, k, v, causal=causal, window=window,
                                    blk=blk)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_chunked_attention_gradients_match_dense():
    """The chunked path is the TRAIN implementation for long sequences — its
    gradients must match the dense oracle's."""
    B, Hq, Hkv, S, D = 1, 2, 1, 64, 16
    keys = jax.random.split(jax.random.PRNGKey(22), 3)
    q = jax.random.normal(keys[0], (B, Hq, S, D))
    k = jax.random.normal(keys[1], (B, Hkv, S, D))
    v = jax.random.normal(keys[2], (B, Hkv, S, D))

    def loss_chunked(q, k, v):
        return jnp.sum(ref.chunked_attention_ref(q, k, v, blk=16) ** 2)

    def loss_dense(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v) ** 2)

    g1 = jax.grad(loss_chunked, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


def test_chunked_attention_q_offset():
    """q_offset shifts causal/window masks (cached decode prefill chunks)."""
    B, H, S, D = 1, 2, 32, 8
    keys = jax.random.split(jax.random.PRNGKey(23), 3)
    q = jax.random.normal(keys[0], (B, H, 8, D))
    k = jax.random.normal(keys[1], (B, H, S, D))
    v = jax.random.normal(keys[2], (B, H, S, D))
    got = ref.chunked_attention_ref(q, k, v, q_offset=24, blk=8)
    want = ref.attention_ref(q, k, v, q_offset=24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------- decode attention


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (2, 4, 4, 64, 32),
    (3, 8, 2, 100, 64),
    (1, 4, 1, 513, 128),
])
@pytest.mark.parametrize("window", [0, 32])
def test_decode_attention_sweep(B, Hq, Hkv, S, D, window):
    keys = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(keys[0], (B, Hq, D))
    kc = jax.random.normal(keys[1], (B, Hkv, S, D))
    vc = jax.random.normal(keys[2], (B, Hkv, S, D))
    lengths = jax.random.randint(keys[3], (B,), 1, S + 1, jnp.int32)
    got, m, l = decode_attention_pallas(q, kc, vc, lengths, window=window,
                                        blk_s=64, interpret=True)
    want, m_ref, l_ref = ref.decode_attention_ref(
        q, kc, vc, lengths, window=window, return_stats=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # softmax stats must match too (they feed the cross-shard combine)
    np.testing.assert_allclose(np.asarray(m), np.asarray(m_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(l), np.asarray(l_ref),
                               rtol=1e-4, atol=1e-4)


def test_decode_attention_zero_length_is_safe():
    B, Hq, Hkv, S, D = 2, 2, 2, 32, 16
    keys = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(keys[0], (B, Hq, D))
    kc = jax.random.normal(keys[1], (B, Hkv, S, D))
    vc = jax.random.normal(keys[2], (B, Hkv, S, D))
    lengths = jnp.array([0, 5], jnp.int32)
    got, m, l = decode_attention_pallas(q, kc, vc, lengths, interpret=True)
    assert np.isfinite(np.asarray(got)).all()
    assert float(l[0].sum()) == 0.0  # fully-masked row signals empty


# ----------------------------------------------------------------------- SSD


@pytest.mark.parametrize("BC,H,G,T,P,N", [
    (2, 2, 1, 16, 8, 8),
    (3, 4, 2, 32, 64, 16),
    (1, 2, 2, 128, 64, 128),
])
def test_ssd_chunk_sweep(BC, H, G, T, P, N):
    keys = jax.random.split(jax.random.PRNGKey(9), 5)
    x = jax.random.normal(keys[0], (BC, H, T, P))
    b = jax.random.normal(keys[1], (BC, G, T, N)) * 0.3
    c = jax.random.normal(keys[2], (BC, G, T, N)) * 0.3
    dta = -jax.random.uniform(keys[3], (BC, H, T), minval=0.01, maxval=0.3)
    dt = jax.random.uniform(keys[4], (BC, H, T), minval=0.1, maxval=1.0)
    y, s = ssd_chunk_pallas(x, b, c, dta, dt, interpret=True)
    y_ref, s_ref = ref.ssd_chunk_ref(x, b, c, dta, dt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_equals_sequential(chunk):
    """Chunked SSD (the paper-of-the-arch's core identity) == token-by-token
    recurrence, for any chunk size."""
    B, S, H, G, P, N = 2, 64, 2, 1, 8, 8
    keys = jax.random.split(jax.random.PRNGKey(10), 5)
    x = jax.random.normal(keys[0], (B, S, H, P))
    b = jax.random.normal(keys[1], (B, S, G, N)) * 0.3
    c = jax.random.normal(keys[2], (B, S, G, N)) * 0.3
    dta = -jax.random.uniform(keys[3], (B, S, H), minval=0.01, maxval=0.3)
    dt = jax.random.uniform(keys[4], (B, S, H), minval=0.1, maxval=1.0)
    y, s = ops.ssd(x, b, c, dta, dt, chunk=chunk, use_kernel=True)
    y_ref, s_ref = ref.ssd_sequential_ref(x, b, c, dta, dt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=2e-3, atol=2e-3)


def test_ssd_decode_step_matches_sequential():
    """Running ssd_decode_step token-by-token == full-sequence oracle."""
    B, S, H, G, P, N = 1, 16, 2, 1, 8, 8
    keys = jax.random.split(jax.random.PRNGKey(11), 5)
    x = jax.random.normal(keys[0], (B, S, H, P))
    b = jax.random.normal(keys[1], (B, S, G, N)) * 0.3
    c = jax.random.normal(keys[2], (B, S, G, N)) * 0.3
    dta = -jax.random.uniform(keys[3], (B, S, H), minval=0.01, maxval=0.3)
    dt = jax.random.uniform(keys[4], (B, S, H), minval=0.1, maxval=1.0)
    y_ref, s_ref = ref.ssd_sequential_ref(x, b, c, dta, dt)

    state = jnp.zeros((B, H, N, P), jnp.float32)
    ys = []
    for t in range(S):
        state, y = ops.ssd_decode_step(
            state, x[:, t], b[:, t], c[:, t], dta[:, t], dt[:, t])
        ys.append(y)
    y_seq = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(s_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_init_state_carries():
    """ops.ssd with init_state == running the two halves back to back."""
    B, S, H, G, P, N = 1, 32, 2, 1, 8, 8
    keys = jax.random.split(jax.random.PRNGKey(12), 5)
    x = jax.random.normal(keys[0], (B, S, H, P))
    b = jax.random.normal(keys[1], (B, S, G, N)) * 0.3
    c = jax.random.normal(keys[2], (B, S, G, N)) * 0.3
    dta = -jax.random.uniform(keys[3], (B, S, H), minval=0.01, maxval=0.3)
    dt = jax.random.uniform(keys[4], (B, S, H), minval=0.1, maxval=1.0)
    y_full, s_full = ops.ssd(x, b, c, dta, dt, chunk=16)
    h = S // 2
    y1, s1 = ops.ssd(x[:, :h], b[:, :h], c[:, :h], dta[:, :h], dt[:, :h],
                     chunk=16)
    y2, s2 = ops.ssd(x[:, h:], b[:, h:], c[:, h:], dta[:, h:], dt[:, h:],
                     chunk=16, init_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------- ops wrappers


def test_ops_dispatch_kernel_vs_ref_paths():
    x = jax.random.normal(jax.random.PRNGKey(13), (16, 32))
    w = jnp.ones((32,))
    a = ops.rmsnorm(x, w, use_kernel=True)
    b = ops.rmsnorm(x, w, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_ops_taskbench_nd_shapes():
    x = jax.random.uniform(jax.random.PRNGKey(14), (3, 5, 7))
    got = ops.taskbench_compute(x, 5)
    want = ref.taskbench_compute_ref(x, 5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
