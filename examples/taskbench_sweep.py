"""Pattern x backend sweep: every dependence pattern under every runtime.

Shows the full Task Bench surface the framework implements: 11 dependence
patterns (stencil, FFT butterflies, tree reductions, all-to-all, random
graphs, ...) executed by 5 interchangeable runtime backends, with
bit-compatible results (asserted here — the system's core invariant) and
per-backend overhead characteristics (printed). A second sweep runs a
mixed-pattern GraphEnsemble (Task Bench's `-and` composition) concurrently
on every backend and asserts per-member equivalence.

  PYTHONPATH=src python examples/taskbench_sweep.py
"""
import numpy as np

from repro.core import PATTERNS, GraphEnsemble, KernelSpec, TaskGraph, \
    available_runtimes, get_runtime


def main():
    print(f"patterns: {', '.join(PATTERNS)}")
    print(f"backends: {', '.join(available_runtimes())}\n")

    header = f"{'pattern':22s}" + "".join(
        f"{b:>12s}" for b in available_runtimes())
    print(header)
    print("-" * len(header))

    for pattern in PATTERNS:
        graph = TaskGraph(
            steps=10, width=16, pattern=pattern, payload=32,
            kernel=KernelSpec("compute_bound", 256), radius=2,
        )
        ref = None
        cells = []
        for backend in available_runtimes():
            rt = get_runtime(backend)
            ok, _ = rt.supports(graph)
            if not ok:
                cells.append(f"{'—':>12s}")
                continue
            sample, stats = rt.measure(graph, reps=2, warmup=1)
            out = rt.execute(graph)
            if ref is None:
                ref = out
            else:
                err = float(np.abs(out - ref).max())
                assert err < 1e-5, (pattern, backend, err)
            cells.append(f"{sample.wall_time * 1e3:>10.1f}ms")
        print(f"{pattern:22s}" + "".join(cells))

    print("\nAll backends produced identical final states per pattern "
          "(asserted).")

    # ---- concurrent multi-graph ensemble (Task Bench `-and`, paper §6.2)
    ensemble = GraphEnsemble([
        TaskGraph(steps=10, width=16, pattern="stencil_1d", payload=32,
                  kernel=KernelSpec("compute_bound", 256), seed=0),
        TaskGraph(steps=10, width=16, pattern="nearest", payload=32,
                  kernel=KernelSpec("compute_bound", 64), radius=2, seed=1),
        TaskGraph(steps=10, width=16, pattern="fft", payload=32,
                  kernel=KernelSpec("compute_bound", 16), seed=2),
    ])
    print(f"\nensemble: {ensemble.describe()}")
    refs = [get_runtime("fused").execute(g) for g in ensemble]
    for backend in available_runtimes():
        rt = get_runtime(backend)
        ok, why = rt.supports_ensemble(ensemble)
        if not ok:
            print(f"  {backend:12s} — ({why.split(':')[-1].strip()})")
            continue
        sample, stats = rt.measure_ensemble(ensemble, reps=2, warmup=1)
        outs = rt.execute_ensemble(ensemble)
        for k, (out, ref) in enumerate(zip(outs, refs)):
            err = float(np.abs(out - ref).max())
            assert err < 1e-5, (backend, k, err)
        print(f"  {backend:12s} {sample.wall_time * 1e3:8.1f}ms "
              f"({stats.dispatches} dispatches, K={len(ensemble)} graphs "
              f"concurrent)")
    print("Per-member states match single-graph fused on every backend "
          "(asserted).")


if __name__ == "__main__":
    main()
