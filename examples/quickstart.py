"""Quickstart: the paper's methodology in ~40 lines of public API.

Builds a Task Bench stencil graph, runs it under three execution strategies
(the "runtime systems under test"), sweeps task granularity, and prints each
strategy's METG — the minimum effective task granularity at 50% efficiency,
the paper's headline metric.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    KernelSpec,
    TaskGraph,
    compute_metg,
    default_grain_schedule,
    get_runtime,
)


def main():
    print("Task Bench in JAX — quickstart\n")

    backends = ["fused", "bsp_scan", "serialized"]
    grains = default_grain_schedule(1, 1 << 14, points_per_decade=2)

    for backend in backends:
        rt = get_runtime(backend)
        samples = []
        for grain in grains:
            graph = TaskGraph(
                steps=20,
                width=16,
                pattern="stencil_1d",
                kernel=KernelSpec("compute_bound", iterations=grain),
                payload=64,
            )
            sample, _ = rt.measure(graph, reps=2, warmup=1)
            samples.append(sample)
        result = compute_metg(samples)
        print(f"  {backend:12s} {result}")

    print(
        "\nReading: `fused` (whole graph in one XLA program) tolerates the "
        "finest grains;\n`serialized` (one dispatch per task, the AMT "
        "task-spawn analogue) needs the\ncoarsest — the paper's Fig 1b "
        "ordering."
    )


if __name__ == "__main__":
    main()
