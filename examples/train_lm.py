"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the real framework stack — config system, synthetic data pipeline,
AdamW, checkpointing (with an injected failure + restart at step 120 to
demonstrate fault tolerance), and the OverheadProfiler that applies the
paper's METG methodology to the production loop.

The model is mamba2-130m at a narrowed width (so a few hundred steps fit
this container's single CPU core); pass --full for the real 130M config.

  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--full]
"""
import argparse
import dataclasses

from repro.configs.registry import get_config, get_shape
from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="true 130M-param config (slow on 1 CPU core)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config("mamba2-130m")
    if not args.full:
        # ~8M params: same family/depth structure, narrowed width
        cfg = dataclasses.replace(
            cfg, n_layers=8, d_model=256, ssm_state=32, ssm_head_dim=32,
            vocab=8192, dtype="float32", param_dtype="float32")
    print(f"config: {cfg.name} ({cfg.param_count()/1e6:.1f}M params, "
          f"{cfg.n_layers}L d={cfg.d_model})")

    shape = get_shape("train_4k")
    res = train(
        cfg, shape,
        steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=100,
        fail_at=(120,),  # fault-tolerance drill: crash once, restart
        lr=1e-3, log_every=25,
    )
    first = sum(res.losses[:10]) / max(len(res.losses[:10]), 1)
    last = sum(res.losses[-10:]) / max(len(res.losses[-10:]), 1)
    print(f"\nloss {first:.3f} -> {last:.3f} over {res.steps_run} steps "
          f"({res.restarts} injected restart(s) survived)")
    assert last < first, "training should reduce loss"


if __name__ == "__main__":
    main()
