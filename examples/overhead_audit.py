"""Overhead audit: the paper's methodology applied to the training loop.

Trains the same reduced model three ways and compares their step-level
overhead profile — the production-loop analogue of the paper's
runtime-system comparison:

  1 jit step, batch  8  (coarse grain — overhead amortized)
  1 jit step, batch  1  (fine grain — dispatch overhead visible)
  8 microbatch dispatches per step (the `serialized` failure mode)

Each variant runs with a span tracer attached (repro.obs): the data feed
records under ``dispatch`` and the device step under ``compute.interior``,
so every report ends with the per-category wall breakdown — the same
decomposition the benchmarks derive, here for a training loop.

  PYTHONPATH=src python examples/overhead_audit.py
"""
import time

import jax

from repro.configs.registry import get_config, get_shape
from repro.core.instrumentation import OverheadProfiler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.launch import steps as steps_lib
from repro.models.model import Model
from repro.obs import Tracer
from repro.optim.optimizer import AdamW


def run_variant(label, cfg, batch, seq, steps, microbatches=1):
    model, opt = Model(cfg), AdamW()
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    shape = get_shape("train_4k")
    pipe = SyntheticTokenPipeline(cfg, shape, batch_override=batch,
                                  seq_override=seq)
    step = jax.jit(steps_lib.make_train_step(model, opt))

    tracer = Tracer()
    prof = OverheadProfiler(devices=1, tasks_per_step=microbatches,
                            tokens_per_step=batch * seq, tracer=tracer)
    mb = batch // microbatches
    for i in range(steps):
        t0 = time.perf_counter()
        with tracer.span("data_feed", "dispatch", step=i):
            data = pipe.batch_at(i)
        with tracer.span("train_step", "compute.interior", step=i,
                         microbatches=microbatches):
            if microbatches == 1:
                params, opt_state, m = step(params, opt_state, data)
            else:
                for j in range(microbatches):
                    sl = {k: v[j * mb:(j + 1) * mb]
                          for k, v in data.items()}
                    params, opt_state, m = step(params, opt_state, sl)
            jax.block_until_ready(m["loss"])
        prof.record(time.perf_counter() - t0)
    rep = prof.report()
    print(f"\n--- {label} ---")
    for line in rep.lines():
        print("  " + line)
    return rep


def main():
    cfg = get_config("internlm2-1.8b").reduced()
    a = run_variant("batch 8, fused step", cfg, batch=8, seq=64, steps=12)
    b = run_variant("batch 1, fused step", cfg, batch=1, seq=64, steps=12)
    c = run_variant("batch 8, 8 microbatch dispatches", cfg, batch=8,
                    seq=64, steps=12, microbatches=8)
    # total dispatch overhead per step = dispatches x per-dispatch latency
    share_a = 1 * a.dispatch_overhead / a.mean_wall
    share_c = 8 * c.dispatch_overhead / c.mean_wall
    print(f"\ndispatch-overhead share of step: fused {share_a*100:.2f}% vs "
          f"8-way microbatched {share_c*100:.2f}%")
    print("Reading: smaller per-dispatch work -> dispatch overhead takes a "
          "larger step share\n(the paper's fine-grain regime); fusing work "
          "into one dispatch restores efficiency.")
    assert share_c >= share_a
    # the traced view must agree that each variant spends SOME wall on the
    # feed and the bulk on compute
    for rep in (a, b, c):
        cats = rep.category_fractions
        assert cats and cats["compute.interior"] > cats["dispatch"] >= 0.0


if __name__ == "__main__":
    main()
