"""Batched serving example: prefill + token-by-token decode with KV caches.

Serves a reduced gemma3-family model (5:1 local:global attention, QK-norm,
tied embeddings) and a reduced mamba2 (attention-free, O(1) decode state)
side by side, showing the same serve path handling both cache disciplines,
and reports per-token overhead via the paper's granularity methodology.

  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.configs.registry import get_config
from repro.launch.serve import serve


def main():
    for arch in ("gemma3-4b", "mamba2-130m"):
        cfg = get_config(arch).reduced()
        print(f"=== {arch} (reduced: {cfg.param_count()/1e3:.0f}K params) ===")
        res = serve(cfg, batch=4, prompt_len=24, gen=12)
        print(f"tokens[0]: {res.tokens[0].tolist()}\n")


if __name__ == "__main__":
    main()
