"""Version-compat shims for the jax API surface this repo uses.

The code targets current jax but must also run on 0.4.x (this container
pins jax 0.4.37). Differences papered over here:

  * ``jax.shard_map`` is ``jax.experimental.shard_map.shard_map`` on 0.4.x.
  * ``jax.lax.pcast`` (varying-manual-axes re-marking) does not exist on
    0.4.x — there is no VMA type system there, so identity is correct.
  * ``jax.sharding.AxisType`` / ``jax.make_mesh(..., axis_types=...)`` are
    handled in ``repro.launch.mesh`` (the only place meshes are built with
    explicit axis types).
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def shard_map(f, **kwargs):
        # newer jax renamed check_rep -> check_vma (the VMA type system);
        # translate so callers can uniformly pass check_vma.
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _experimental_shard_map(f, **kwargs)


def pcast_varying(x, axis_name):
    """Re-mark a shard-invariant value as varying over ``axis_name``.

    Newer jax's shard_map tracks varying-manual-axes types, so e.g. psum
    outputs must be pcast back to "varying" before joining a scan carry.
    Old jax has no VMA typing and needs nothing.
    """
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axis_name, to="varying")
    return x
