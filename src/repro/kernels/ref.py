"""Pure-jnp oracles for every Pallas kernel.

These are the normative semantics: tests sweep shapes/dtypes and assert the
Pallas kernels (interpret mode on CPU) match these within tolerance. They are
also the differentiable implementations the training path uses (the Pallas
kernels here are forward-only).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.bodies import FMA_A, FMA_B

NEG_INF = -1e30


# ------------------------------------------------------------ taskbench FMA


def taskbench_compute_ref(x: jax.Array, iterations: int) -> jax.Array:
    a = jnp.asarray(FMA_A, x.dtype)
    b = jnp.asarray(FMA_B, x.dtype)

    def body(_, v):
        return a * v + b

    return jax.lax.fori_loop(0, iterations, body, x)


def taskbench_memory_ref(x: jax.Array, iterations: int, scratch: int) -> jax.Array:
    """Memory-bound scratch sweep, written INDEPENDENTLY of kernels.bodies.

    The shared body math in bodies.py is used by both the runtime reference
    path and the Pallas kernels; this oracle re-derives the semantics from
    scratch (expand payload to a (scratch,) working set, roll + add per
    iteration, mean-reduce back) so the parity tests can still catch a
    regression in the shared implementation.
    """
    if iterations == 0:
        return x
    lead, payload = x.shape[:-1], x.shape[-1]
    reps = (scratch + payload - 1) // payload
    buf = jnp.concatenate([x] * reps, axis=-1)[..., :scratch]

    def body(_, b):
        return jnp.roll(b, 1, axis=-1) + jnp.asarray(1e-6, b.dtype)

    buf = jax.lax.fori_loop(0, iterations, body, buf)
    buf = jnp.pad(buf, [(0, 0)] * len(lead) + [(0, reps * payload - scratch)])
    return buf.reshape(lead + (reps, payload)).mean(axis=-2)


def taskbench_step_ref(
    src: jax.Array,
    idx: jax.Array,
    wgt: jax.Array,
    *,
    kind: str = "compute_bound",
    iterations: int = 16,
    scratch: int = 2048,
) -> jax.Array:
    """Oracle for the fused-timestep megakernel (taskbench_step.py).

    src: (K, S, payload); idx/wgt: (K, W, D) pre-normalized dependency
    slots (see taskbench_step.prepare_step_operands). Gather + weighted-sum
    combine in f32, then the grain-size body, per ensemble member. Built on
    the ref-local bodies above, not kernels.bodies, so it stays an
    independent check of the shared body math.
    """

    def one(s, i, w):
        x = (s[i].astype(jnp.float32) * w[..., None]).sum(axis=1).astype(s.dtype)
        if kind == "empty" or iterations == 0:
            return x
        if kind == "compute_bound":
            return taskbench_compute_ref(x, iterations)
        if kind == "memory_bound":
            return taskbench_memory_ref(x, iterations, scratch)
        raise ValueError(f"unknown kernel kind {kind!r}")

    return jax.vmap(one)(src, idx, wgt)


# ----------------------------------------------------------------- rmsnorm


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------- attention


def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    sm_scale: Optional[float] = None,
    q_offset: int = 0,  # global position of q row 0 (for cached decode)
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(D)
    kx = jnp.repeat(k, G, axis=1)
    vx = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * sm_scale
    qi = q_offset + jnp.arange(Sq)[:, None]
    kj = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kj <= qi
    if window > 0:
        mask &= (qi - kj) < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None], p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)).astype(q.dtype)


def chunked_attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    sm_scale: Optional[float] = None,
    q_offset: int = 0,
    blk: int = 1024,
) -> jax.Array:
    """Flash attention in pure jnp: lax.scan over key blocks with an online
    softmax, body rematerialized (jax.checkpoint) so fwd AND bwd memory are
    O(Sq x blk), never O(Sq x Sk).

    This is the differentiable flash implementation the training path uses
    and the implementation of record for dry-run compiles: interpret-mode
    Pallas lowers to a grid-sized while loop whose HLO misrepresents the
    kernel's true cost, while this lowering has the same FLOPs/bytes shape a
    real fused kernel has (see DESIGN.md §8). Matches attention_ref exactly
    (tests/test_kernels.py).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    G = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(D)
    pad_k = (-Sk) % blk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nk = (Sk + pad_k) // blk
    qf = q.astype(jnp.float32) * sm_scale
    qi = q_offset + jnp.arange(Sq)[:, None]  # (Sq, 1)

    # scan xs: k/v blocks stacked on a leading axis
    kb = k.reshape(B, Hkv, nk, blk, D).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hkv, nk, blk, D).transpose(2, 0, 1, 3, 4)

    def body(carry, xs):
        acc, m, l = carry  # (B,Hq,Sq,D), (B,Hq,Sq), (B,Hq,Sq)
        j, kj, vj = xs  # (), (B,Hkv,blk,D), (B,Hkv,blk,D)
        kg = jnp.repeat(kj.astype(jnp.float32), G, axis=1)
        vg = jnp.repeat(vj.astype(jnp.float32), G, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kg)  # (B,Hq,Sq,blk)
        kpos = j * blk + jnp.arange(blk)[None, :]  # (1, blk)
        mask = kpos < Sk
        if causal:
            mask = mask & (kpos <= qi)
        if window > 0:
            mask = mask & (qi - kpos < window)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vg)
        return (acc, m_new, l_new), None

    init = (
        jnp.zeros((B, Hq, Sq, D), jnp.float32),
        jnp.full((B, Hq, Sq), NEG_INF, jnp.float32),
        jnp.zeros((B, Hq, Sq), jnp.float32),
    )
    (acc, m, l), _ = jax.lax.scan(
        jax.checkpoint(body), init, (jnp.arange(nk), kb, vb))
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,        # (B, Hq, D)
    k_cache: jax.Array,  # (B, Hkv, S, D)
    v_cache: jax.Array,  # (B, Hkv, S, D)
    lengths: jax.Array,  # (B,)
    *,
    sm_scale: Optional[float] = None,
    window: int = 0,
    return_stats: bool = False,
):
    B, Hq, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    G = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(D)
    kx = jnp.repeat(k_cache, G, axis=1)
    vx = jnp.repeat(v_cache, G, axis=1)
    s = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * sm_scale
    valid = jnp.arange(S)[None, :] < lengths[:, None]  # (B, S)
    if window > 0:
        valid = jnp.logical_and(valid, jnp.arange(S)[None, :] >= lengths[:, None] - window)
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    m = s.max(axis=-1)  # (B, Hq)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, :], p, 0.0)
    l = p.sum(axis=-1)  # (B, Hq)
    lsafe = jnp.where(l == 0.0, 1.0, l)
    o = (jnp.einsum("bhs,bhsd->bhd", p, vx.astype(jnp.float32))
         / lsafe[..., None]).astype(q.dtype)
    if return_stats:
        return o, m, l
    return o


# --------------------------------------------------------------------- SSD


def ssd_chunk_ref(
    x: jax.Array,    # (BC, H, T, P)
    b: jax.Array,    # (BC, G, T, N)
    c: jax.Array,    # (BC, G, T, N)
    dta: jax.Array,  # (BC, H, T)
    dt: jax.Array,   # (BC, H, T)
) -> Tuple[jax.Array, jax.Array]:
    """Intra-chunk SSD terms; semantics documented in ssd_scan.py."""
    BC, H, T, P = x.shape
    G = b.shape[1]
    ratio = H // G
    bh = jnp.repeat(b, ratio, axis=1).astype(jnp.float32)  # (BC, H, T, N)
    ch = jnp.repeat(c, ratio, axis=1).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    a = jnp.cumsum(dta.astype(jnp.float32), axis=-1)  # (BC, H, T)

    logl = a[..., :, None] - a[..., None, :]  # (BC, H, T, T)
    causal = jnp.tril(jnp.ones((T, T), bool))
    L = jnp.where(causal, jnp.exp(logl), 0.0)
    scores = jnp.einsum("bhin,bhjn->bhij", ch, bh) * L
    y = jnp.einsum("bhij,bhjp->bhip", scores, xf * dt[..., None])

    decay_to_end = jnp.exp(a[..., -1:] - a)  # (BC, H, T)
    bw = bh * (decay_to_end * dt)[..., None]  # (BC, H, T, N)
    state = jnp.einsum("bhtn,bhtp->bhnp", bw, xf)
    return y.astype(x.dtype), state


def ssd_sequential_ref(
    x: jax.Array,    # (B, S, H, P)
    b: jax.Array,    # (B, S, G, N)
    c: jax.Array,    # (B, S, G, N)
    dta: jax.Array,  # (B, S, H)
    dt: jax.Array,   # (B, S, H)
    init_state: Optional[jax.Array] = None,  # (B, H, N, P)
) -> Tuple[jax.Array, jax.Array]:
    """Token-by-token recurrence — the ground-truth oracle for chunked SSD.

      S_t = exp(dtA_t) S_{t-1} + dt_t * B_t (outer) x_t ;   y_t = C_t . S_t
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    ratio = H // G
    bh = jnp.repeat(b, ratio, axis=2).astype(jnp.float32)
    ch = jnp.repeat(c, ratio, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if init_state is None:
        init_state = jnp.zeros((B, H, N, P), jnp.float32)

    def step(state, inp):
        xt, bt, ct, dtat, dtt = inp  # (B,H,P) (B,H,N) (B,H,N) (B,H) (B,H)
        decay = jnp.exp(dtat)[..., None, None]  # (B,H,1,1)
        state = decay * state + jnp.einsum("bhn,bhp->bhnp", bt * dtt[..., None], xt)
        y = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, y

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(bh, 1, 0),
        jnp.moveaxis(ch, 1, 0),
        jnp.moveaxis(dta.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
    )
    final, ys = jax.lax.scan(step, init_state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
