"""Public jit'd wrappers over the Pallas kernels.

Every op auto-selects interpret mode off-TPU (this container is CPU-only, so
kernels execute their Python bodies for validation; on a real TPU the same
call sites lower to Mosaic). ``use_kernel=False`` falls back to the jnp
reference — the training path uses references (differentiable), inference
paths use kernels.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bodies import memory_bound_pallas
from repro.kernels.decode_attention import decode_attention_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.ssd_scan import ssd_chunk_pallas
from repro.kernels.taskbench_compute import taskbench_compute_pallas
from repro.kernels.taskbench_step import (
    taskbench_step_boundary,
    taskbench_step_interior,
    taskbench_step_pallas,
)


@functools.cache
def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def taskbench_compute(x: jax.Array, iterations: int) -> jax.Array:
    """Iterated-FMA task body; accepts (..., payload)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = taskbench_compute_pallas(x2, iterations, interpret=_interpret())
    return out.reshape(shape)


def taskbench_memory(x: jax.Array, iterations: int, scratch: int) -> jax.Array:
    """Scratch-sweep (memory-bound) task body; accepts (..., payload)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = memory_bound_pallas(x2, iterations, scratch, interpret=_interpret())
    return out.reshape(shape)


def taskbench_step(
    src: jax.Array, idx: jax.Array, wgt: jax.Array, act=None, **kw
) -> jax.Array:
    """Fused Task Bench timestep(s) (gather + combine + body) for K graphs.

    See repro.kernels.taskbench_step for the operand contract — including
    the temporal-blocked ``steps_per_launch`` path, which requires the
    (K, S) ``act`` depth mask; this wrapper only auto-selects interpret
    mode off-TPU.
    """
    return taskbench_step_pallas(src, idx, wgt, act,
                                 interpret=_interpret(), **kw)


def taskbench_interior(src, idx, wgt, act, *, depth: int, **kw):
    """Interior phase of a pipelined blocked launch (owned block only;
    returns the (K, B - 2*depth, payload) rows valid after S shrinks).
    See kernels.taskbench_step.taskbench_step_interior."""
    return taskbench_step_interior(src, idx, wgt, act, depth=depth,
                                   interpret=_interpret(), **kw)


def taskbench_boundary(left, right, idx, wgt, act, *, depth: int, **kw):
    """Boundary phase of a pipelined blocked launch (both 3*depth edge
    buffers of all K members in ONE launch; returns the new edge rows).
    See kernels.taskbench_step.taskbench_step_boundary."""
    return taskbench_step_boundary(left, right, idx, wgt, act, depth=depth,
                                   interpret=_interpret(), **kw)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
            use_kernel: bool = True) -> jax.Array:
    if not use_kernel:
        return ref.rmsnorm_ref(x, w, eps)
    shape = x.shape
    out = rmsnorm_pallas(x.reshape(-1, shape[-1]), w, eps=eps,
                         interpret=_interpret())
    return out.reshape(shape)


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int = 0, sm_scale: Optional[float] = None,
    use_kernel: bool = True,
) -> jax.Array:
    if not use_kernel:
        # differentiable paths: dense oracle for short sequences, chunked
        # flash (scan + online softmax + remat) beyond — O(S) memory and a
        # realistic HLO cost shape for dry-run compiles (ref.py docstring)
        if k.shape[2] <= 2048:
            return ref.attention_ref(q, k, v, causal=causal, window=window,
                                     sm_scale=sm_scale)
        return ref.chunked_attention_ref(q, k, v, causal=causal,
                                         window=window, sm_scale=sm_scale)
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, sm_scale=sm_scale,
        interpret=_interpret(),
    )


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, lengths: jax.Array,
    *, sm_scale: Optional[float] = None, window: int = 0,
    return_stats: bool = False, use_kernel: bool = True,
):
    """Returns o (B,Hq,D), or (o, m, l) softmax stats with return_stats=True
    (stats feed the cross-shard lse-combine in sequence-parallel decode)."""
    if not use_kernel:
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths,
                                        sm_scale=sm_scale, window=window,
                                        return_stats=return_stats)
    o, m, l = decode_attention_pallas(q, k_cache, v_cache, lengths,
                                      sm_scale=sm_scale, window=window,
                                      interpret=_interpret())
    if return_stats:
        return o, m, l
    return o


def ssd_chunk(
    x: jax.Array, b: jax.Array, c: jax.Array, dta: jax.Array, dt: jax.Array,
    *, use_kernel: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    if not use_kernel:
        return ref.ssd_chunk_ref(x, b, c, dta, dt)
    return ssd_chunk_pallas(x, b, c, dta, dt, interpret=_interpret())


def ssd(
    x: jax.Array,    # (B, S, H, P)
    b: jax.Array,    # (B, S, G, N)
    c: jax.Array,    # (B, S, G, N)
    dta: jax.Array,  # (B, S, H)   dt * A (negative)
    dt: jax.Array,   # (B, S, H)
    *,
    chunk: int = 128,
    init_state: Optional[jax.Array] = None,  # (B, H, N, P)
    use_kernel: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence SSD: chunked intra-kernel + inter-chunk lax.scan.

    Returns (y: (B,S,H,P), final_state: (B,H,N,P)). Sequence length must be a
    multiple of ``chunk`` (callers pad); equivalence with the sequential
    recurrence is asserted in tests against ref.ssd_sequential_ref.
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    if S % chunk:
        raise ValueError(f"seq {S} not a multiple of chunk {chunk}")
    NC, T = S // chunk, chunk
    ratio = H // G

    # --- reshape into chunks, head-major for the kernel --------------------
    xc = x.reshape(B, NC, T, H, P).transpose(0, 1, 3, 2, 4).reshape(B * NC, H, T, P)
    bc = b.reshape(B, NC, T, G, N).transpose(0, 1, 3, 2, 4).reshape(B * NC, G, T, N)
    cc = c.reshape(B, NC, T, G, N).transpose(0, 1, 3, 2, 4).reshape(B * NC, G, T, N)
    dtac = dta.reshape(B, NC, T, H).transpose(0, 1, 3, 2).reshape(B * NC, H, T)
    dtc = dt.reshape(B, NC, T, H).transpose(0, 1, 3, 2).reshape(B * NC, H, T)

    y_intra, states = ssd_chunk(xc, bc, cc, dtac, dtc, use_kernel=use_kernel)
    y_intra = y_intra.reshape(B, NC, H, T, P)
    states = states.reshape(B, NC, H, N, P)

    # --- inter-chunk recurrence over the NC per-chunk states ---------------
    a_cum = jnp.cumsum(dtac.astype(jnp.float32), axis=-1).reshape(B, NC, H, T)
    chunk_decay = jnp.exp(a_cum[..., -1])  # (B, NC, H)
    ch = jnp.repeat(
        cc.reshape(B, NC, G, T, N), ratio, axis=2
    ).astype(jnp.float32)  # (B, NC, H, T, N)
    decay_in = jnp.exp(a_cum)  # (B, NC, H, T) decay from chunk start to token

    if init_state is None:
        init_state = jnp.zeros((B, H, N, P), jnp.float32)

    def step(carry, inp):
        state_c, decay_c, cm, din = inp
        y_inter = jnp.einsum("bhtn,bhnp->bhtp", cm * din[..., None], carry)
        carry = carry * decay_c[..., None, None] + state_c
        return carry, y_inter

    xs = (
        jnp.moveaxis(states, 1, 0),
        jnp.moveaxis(chunk_decay, 1, 0),
        jnp.moveaxis(ch, 1, 0),
        jnp.moveaxis(decay_in, 1, 0),
    )
    final_state, y_inter = jax.lax.scan(step, init_state, xs)
    y_inter = jnp.moveaxis(y_inter, 0, 1)  # (B, NC, H, T, P)

    y = (y_intra.astype(jnp.float32) + y_inter)
    y = y.transpose(0, 1, 3, 2, 4).reshape(B, S, H, P).astype(x.dtype)
    return y, final_state


def ssd_decode_step(
    state: jax.Array,  # (B, H, N, P)
    xt: jax.Array,     # (B, H, P)
    bt: jax.Array,     # (B, G, N)
    ct: jax.Array,     # (B, G, N)
    dtat: jax.Array,   # (B, H)
    dtt: jax.Array,    # (B, H)
) -> Tuple[jax.Array, jax.Array]:
    """O(1) single-token SSD update (serving path)."""
    H = state.shape[1]
    G = bt.shape[1]
    ratio = H // G
    bh = jnp.repeat(bt, ratio, axis=1).astype(jnp.float32)
    ch = jnp.repeat(ct, ratio, axis=1).astype(jnp.float32)
    decay = jnp.exp(dtat.astype(jnp.float32))[..., None, None]
    state = decay * state + jnp.einsum(
        "bhn,bhp->bhnp", bh * dtt.astype(jnp.float32)[..., None],
        xt.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhnp->bhp", ch, state)
    return state, y.astype(xt.dtype)
