"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel lives in <name>.py (pl.pallas_call + BlockSpec), has a pure-jnp
oracle in ref.py, and a public jit'd wrapper in ops.py that auto-selects
interpret mode off-TPU.
"""
from repro.kernels import ops, ref  # noqa: F401
