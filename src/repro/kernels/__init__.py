"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel lives in <name>.py (pl.pallas_call + BlockSpec), has a pure-jnp
oracle in ref.py, and a public jit'd wrapper in ops.py that auto-selects
interpret mode off-TPU. Scheduling policy (launch depth / plan choice)
lives in schedule.py, priced by the measured-or-analytic cost model in
probes.py (not imported here: probes doubles as the `-m` calibration CLI
and must stay lazy).
"""
from repro.kernels import ops, ref  # noqa: F401
