"""Pallas fused-timestep megakernel for Task Bench graphs.

One ``pallas_call`` executes an ENTIRE Task Bench timestep — gather the
padded dependency slots from the previous-state buffer, combine them
(masked mean), and run the grain-size body — where the ``fused`` backend
emits one gather + one combine + one body op per step. At fine grain the
per-op dispatch cost of that chain is exactly the overhead the paper's METG
measures, so fusing the step control path lowers the repo's measurable
floor (cf. Task Bench SC'20 §6.1: sub-microsecond METG needs a fused
per-task path).

Batching contract: all operands carry a leading K axis — a
``GraphEnsemble``'s K members' combines and bodies batch into the SAME
launch (K is the slowest grid dimension, so member k's row-blocks are
contiguous program instances; see DESIGN.md §4 for why K is an operand axis
and not a vmap).

Inputs (see ``prepare_step_operands`` for how runtimes build idx/wgt):

  src  (K, S, payload)  previous-state rows to gather FROM. S may exceed the
                        output width W (halo-extended local blocks).
  idx  (K, W, D) int32  dependency slot -> src row. Every output row must
                        have >= 1 live slot: rows with no dependencies are
                        self-padded (idx = own row, weight 1), which encodes
                        task_kernels.combine_dependencies' "zero deps keep
                        own state" rule with no in-kernel branch.
  wgt  (K, W, D) f32    pre-normalized combine weights (mask / live-count),
                        so the masked MEAN is a single weighted sum — no
                        in-kernel max/divide/where.

Temporal blocking (``steps_per_launch=S > 1``): the classic deep-halo
stencil trick applied to the whole Task Bench step. Since every
halo-expressible pattern advances at most ``r`` rows of influence per step,
a source buffer extended by ``S*r`` rows per side holds enough remote state
for ``S`` consecutive timesteps — the kernel iterates combine + body ``S``
times on a fixed-size working buffer whose VALID region shrinks by ``r``
rows per inner step, and the caller slices the owned rows (still valid
after ``S`` shrinks) out of the result. One launch and one (deep) halo
exchange then serve ``S`` steps instead of one. Contract differences from
the single-step path:

  * square operands: src (K, M, payload), wgt (K, M, D) — every working row
    carries its OWN combine weights (indexed by its fixed global row id, so
    per-row edge clipping stays exact at every depth), and the output is
    the full (K, M, payload) buffer (caller slices the owned rows).
  * gather/onehot idx entries address the M-row working buffer itself.
  * gather/onehot tables may carry a leading depth axis — (K, S, M, D),
    one table per inner step — for patterns whose dependence sets change
    with t (butterfly strides, spread's rotation); depth d then combines
    with table d. Such launches run on an exactly-closed working buffer
    (the runtime's all-gather plan), so no valid-span shrink applies.
  * a per-depth activity mask ``act`` (K, S) freezes member k at inner step
    d when act[k, d] == 0 (heterogeneous-steps ensembles freeze at launch
    granularity; the final partial launch of any run is a masked tail).
  * the row grid collapses to 1 program per member: inner steps create
    cross-tile dependences, so the whole working buffer stays resident in
    VMEM for all S depths (kernels/schedule.py sizes S to the VMEM budget).

Pipeline phase split (``taskbench_step_interior`` / ``taskbench_step_boundary``):
the same blocked kernel invoked on two disjoint working buffers so the
runtime can overlap the next deep exchange with compute — the interior
entry runs on the owned block alone (its surviving rows touch no halo),
the boundary entry stacks both 3*depth-row edge buffers of all K members
onto the member axis of ONE launch and returns the rows the next exchange
sends. Both reuse the valid-span machinery unchanged; see DESIGN.md §6.

Three combine strategies, selected statically:

  window  for halo-expressible dependence patterns (the pallas_step
          runtime's default): slot j of wgt is the weight of the dependency
          at window offset j - halo, so the combine is a static unrolled
          sum of 2*halo+1 SHIFTED CONTIGUOUS SLICES of src — no gather at
          all, just VPU fused multiply-adds over (rows, payload) tiles.
          idx is ignored (src row = own row + j by construction).
  gather  dependency rows are fancy-indexed out of src (lax.gather) per
          the idx operand — the general path for arbitrary padded dep
          slots.
  onehot  the combine is lifted to a (W, S) one-hot weight matrix applied
          with ``jnp.dot`` — the MXU-friendly fallback for TPUs where a
          row gather does not lower.
  pair    for butterfly patterns (fft/tree): src carries [x | partner]
          halves stacked row-wise (S = 2*W; the runtime's stride plan
          builds the partner half with an XOR layout shuffle or a block
          permute), and the combine is elementwise (x + partner) * 0.5 —
          no gather, no index arithmetic, exact halving (every butterfly
          task has the two deps {p, p XOR 2^k}, so the masked mean IS
          (a + b) / 2 and * 0.5 reproduces it bit-for-bit). idx/wgt are
          ignored (wgt's row count still declares the output width W).

Validated bit-for-bit against ``ref.taskbench_step_ref`` (same value-level
body functions from ``bodies.py``) in interpret mode; see tests/test_kernels.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.bodies import LANE, SUBLANE, apply_body

COMBINE_MODES = ("window", "gather", "onehot", "pair")

#: Combine weights are accumulated host-side in this dtype and rounded ONCE
#: to WEIGHT_DTYPE via finalize_weights — the single precision policy for
#: every operand builder (prepare_step_operands, the runtimes' window /
#: gather builders), so combine modes cannot drift in weight precision.
WEIGHT_ACCUM_DTYPE = np.float64
WEIGHT_DTYPE = np.float32


def finalize_weights(wgt: np.ndarray) -> np.ndarray:
    """Round host-accumulated combine weights once to the kernel dtype."""
    return np.asarray(wgt, WEIGHT_ACCUM_DTYPE).astype(WEIGHT_DTYPE)


def _step_kernel(
    src_ref,
    idx_ref,
    wgt_ref,
    o_ref,
    *,
    kind: str,
    iterations: int,
    scratch: int,
    payload: int,
    combine: str,
    block_rows: int,
    pair_rows: int = 0,
):
    src = src_ref[0]  # (S, Pp)
    idx = idx_ref[0]  # (Wb, D)
    wgt = wgt_ref[0]  # (Wb, D)

    if combine == "pair":
        # src = [x | partner] halves (second half starts at the TRUE
        # unpadded width pair_rows): the combine is elementwise
        # (a + b) * 0.5 — gather-free, and exact halving keeps it
        # bit-identical to the 2-dep masked mean.
        row0 = pl.program_id(1) * block_rows
        srcf = src.astype(jnp.float32)
        n = wgt.shape[0]
        a = jax.lax.dynamic_slice_in_dim(srcf, row0, n, 0)
        b = jax.lax.dynamic_slice_in_dim(srcf, pair_rows + row0, n, 0)
        x = (a + b) * jnp.float32(0.5)
    elif combine == "window":
        # wgt column j weighs the dependency at window offset j - halo:
        # out row w combines src rows [row0 + w .. row0 + w + 2*halo], a
        # static unrolled slice-FMA chain (no gather, no index arithmetic).
        row0 = pl.program_id(1) * block_rows
        srcf = src.astype(jnp.float32)
        x = jnp.zeros((wgt.shape[0], src.shape[1]), jnp.float32)
        for j in range(wgt.shape[1]):
            win = jax.lax.dynamic_slice_in_dim(srcf, row0 + j, wgt.shape[0], 0)
            x = x + win * wgt[:, j][:, None]
    elif combine == "gather":
        gathered = src[idx].astype(jnp.float32)  # (Wb, D, Pp)
        x = (gathered * wgt[..., None]).sum(axis=1)
    else:  # onehot: lift the gather to an MXU matmul
        S = src.shape[0]
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, S), 2)
        C = ((idx[..., None] == col).astype(jnp.float32) * wgt[..., None]).sum(axis=1)
        x = jnp.dot(C, src.astype(jnp.float32), preferred_element_type=jnp.float32)
    o_ref[0] = _apply_body_padded(
        x.astype(src.dtype), kind=kind, iterations=iterations,
        scratch=scratch, payload=payload,
    )


def _apply_body_padded(x, *, kind, iterations, scratch, payload):
    """Body over a lane-padded (rows, Pp) tile, true-payload-aware.

    The memory_bound sweep mixes columns (roll), so it must see the TRUE
    payload slice; other bodies are columnwise and run on the padded tile.
    """
    if kind == "memory_bound" and iterations > 0:
        true = apply_body(x[:, :payload], kind, iterations, scratch)
        return jnp.pad(true, ((0, 0), (0, x.shape[-1] - payload)))
    return apply_body(x, kind, iterations, scratch)


def _blocked_step_kernel(
    src_ref,
    idx_ref,
    wgt_ref,
    act_ref,
    o_ref,
    *,
    kind: str,
    iterations: int,
    scratch: int,
    payload: int,
    combine: str,
    steps_per_launch: int,
    time_varying: bool = False,
):
    """S fused timesteps on one member's deep-halo-extended working buffer.

    The buffer keeps its full M rows at every depth; only the VALID span
    shrinks (by halo rows per side per step). Rows outside the valid span
    compute garbage from clamped windows / zero weights — harmless, because
    a row consumed at depth d+1 sits at least one halo inside the rows valid
    at depth d, and the caller only slices rows valid after all S depths.

    ``time_varying`` (gather/onehot only): idx/wgt carry a leading (S,)
    depth axis — one table per inner step — so patterns whose dependence
    sets change with t (butterfly strides, spread's rotation) can run
    blocked: depth d applies table d. The act-mask freezing is unchanged.
    """
    buf0 = src_ref[0]  # (Mp, Pp) working state, full size at every depth
    act = act_ref[0]  # (S,) 1.0 = this inner step executes
    M = buf0.shape[0]
    if not time_varying:
        wgt = wgt_ref[0]  # (Mp, D) per-row weights, fixed across depths
        #                   (each row's global id never changes, so neither
        #                   do its edge-clipped combine weights)
        halo = (wgt.shape[1] - 1) // 2 if combine == "window" else 0
        if combine == "onehot":
            # idx/wgt are depth-invariant, so the (M, M) one-hot combine
            # matrix is built ONCE per launch, not once per inner step
            idx = idx_ref[0]
            col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, M), 2)
            onehot_C = ((idx[..., None] == col).astype(jnp.float32)
                        * wgt[..., None]).sum(axis=1)

    def depth_step(d, buf):
        srcf = buf.astype(jnp.float32)
        if time_varying:
            # (S, Mp, D) tables: depth d combines with table d
            ti = jax.lax.dynamic_index_in_dim(idx_ref[0], d, 0, keepdims=False)
            tw = jax.lax.dynamic_index_in_dim(wgt_ref[0], d, 0, keepdims=False)
            if combine == "gather":
                x = (srcf[ti] * tw[..., None]).sum(axis=1)
            else:  # onehot, built per depth (the matrix changes with d)
                col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, M), 2)
                C = ((ti[..., None] == col).astype(jnp.float32)
                     * tw[..., None]).sum(axis=1)
                x = jnp.dot(C, srcf, preferred_element_type=jnp.float32)
        elif combine == "window":
            # out row i combines work rows [i .. i + 2*halo] of the +-halo
            # zero-padded buffer: same static slice-FMA chain as the
            # single-step kernel, full-buffer width
            zpad = jnp.zeros((halo, srcf.shape[1]), jnp.float32)
            work = jnp.concatenate([zpad, srcf, zpad], axis=0)
            x = jnp.zeros((M, srcf.shape[1]), jnp.float32)
            for j in range(wgt.shape[1]):
                win = jax.lax.dynamic_slice_in_dim(work, j, M, 0)
                x = x + win * wgt[:, j][:, None]
        elif combine == "gather":
            idx = idx_ref[0]  # (Mp, D) absolute rows of THIS buffer
            gathered = srcf[idx]  # (Mp, D, Pp)
            x = (gathered * wgt[..., None]).sum(axis=1)
        else:  # onehot: lift the self-gather to an MXU matmul
            x = jnp.dot(onehot_C, srcf, preferred_element_type=jnp.float32)
        x = _apply_body_padded(
            x.astype(buf.dtype), kind=kind, iterations=iterations,
            scratch=scratch, payload=payload,
        )
        # masked freeze: inactive depths (a frozen ensemble member, or the
        # tail of the final partial launch) carry the buffer through intact
        return jnp.where(act[d] > 0.5, x, buf)

    # ROLLED loop over depths (the buffer is full-size at every depth
    # precisely so the carry shape is loop-invariant): a rolled loop
    # materializes the buffer between depths, which keeps compile size
    # O(1) in S and stops XLA:CPU from fusing the whole depth chain into
    # one recompute cone (interpret mode would otherwise get slower per
    # step as S grows, inverting the launch-amortization win).
    o_ref[0] = jax.lax.fori_loop(0, steps_per_launch, depth_step, buf0)


def _blocked_call(src, idx, wgt, act, *, kind, iterations, scratch,
                  combine, interpret):
    """pallas_call for the temporal-blocked path: square (K, M, *) operands,
    one program per member (inner steps couple all rows, so no row grid).
    ``wgt.ndim == 4`` selects the time-varying contract: (K, S, M, D)
    idx/wgt tables, one per inner depth (gather/onehot only)."""
    K, M, payload = src.shape
    S = act.shape[1]
    if combine == "pair":
        raise ValueError(
            "pair combine is per-step only (blocked butterfly launches "
            "use gather/onehot with time-varying tables)")
    time_varying = wgt.ndim == 4
    if time_varying:
        if combine == "window":
            raise ValueError(
                "window combine has no time-varying form (halo patterns "
                "have period 1); use gather or onehot")
        if wgt.shape[:3] != (K, S, M):
            raise ValueError(
                f"time-varying tables must be (K, S, M, D) = ({K}, {S}, "
                f"{M}, ...), got {wgt.shape}")
        if idx.shape != wgt.shape:
            raise ValueError(
                f"operand shape mismatch: {idx.shape}/{wgt.shape}")
    else:
        if wgt.shape[:2] != (K, M):
            raise ValueError(
                f"blocked path needs square operands: src {src.shape} vs "
                f"wgt {wgt.shape} (every working row carries its own weights)"
            )
        if combine == "window":
            idx = jnp.zeros((K, 1, 1), jnp.int32)  # semantically unused
        elif idx.shape != wgt.shape:
            raise ValueError(f"operand shape mismatch: {idx.shape}/{wgt.shape}")
    D = wgt.shape[-1]
    if act.shape[0] != K:
        raise ValueError(f"act must be (K, S), got {act.shape} for K={K}")

    lane, sublane = (1, 1) if interpret else (LANE, SUBLANE)
    pad_p = (-payload) % lane
    pad_m = (-M) % sublane
    srcp = jnp.pad(src, ((0, 0), (0, pad_m), (0, pad_p)))
    row_axis = 2 if time_varying else 1
    tab_pad = [(0, 0)] * wgt.ndim
    tab_pad[row_axis] = (0, pad_m)
    idxp = idx if combine == "window" else jnp.pad(idx, tab_pad)
    wgtp = jnp.pad(wgt, tab_pad)
    Mp, Pp = srcp.shape[1], srcp.shape[2]
    if combine == "window":
        idx_block = pl.BlockSpec((1, 1, 1), lambda k: (k, 0, 0))
    elif time_varying:
        idx_block = pl.BlockSpec((1, S, Mp, D), lambda k: (k, 0, 0, 0))
    else:
        idx_block = pl.BlockSpec((1, Mp, D), lambda k: (k, 0, 0))
    wgt_block = (
        pl.BlockSpec((1, S, Mp, D), lambda k: (k, 0, 0, 0))
        if time_varying
        else pl.BlockSpec((1, Mp, D), lambda k: (k, 0, 0))
    )

    out = pl.pallas_call(
        functools.partial(
            _blocked_step_kernel,
            kind=kind,
            iterations=iterations,
            scratch=scratch,
            payload=payload,
            combine=combine,
            steps_per_launch=S,
            time_varying=time_varying,
        ),
        grid=(K,),
        in_specs=[
            pl.BlockSpec((1, Mp, Pp), lambda k: (k, 0, 0)),
            idx_block,
            wgt_block,
            pl.BlockSpec((1, S), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((1, Mp, Pp), lambda k: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((K, Mp, Pp), src.dtype),
        interpret=interpret,
    )(srcp, idxp, wgtp, act)
    return out[:, :M, :payload]


@functools.partial(
    jax.jit,
    static_argnames=(
        "kind", "iterations", "scratch", "block_rows", "combine",
        "steps_per_launch", "interpret",
    ),
)
def taskbench_step_pallas(
    src: jax.Array,
    idx: jax.Array,
    wgt: jax.Array,
    act: jax.Array | None = None,
    *,
    kind: str = "compute_bound",
    iterations: int = 16,
    scratch: int = 2048,
    block_rows: int = 0,
    combine: str = "gather",
    steps_per_launch: int = 1,
    interpret: bool = False,
) -> jax.Array:
    """Fused Task Bench timestep(s) for K graphs.

    ``steps_per_launch=1`` (default): one timestep, (K, W, payload) out.
    ``block_rows=0`` keeps each member's full width in one program (the
    fine-grain default — minimal grid overhead); set it to tile wide graphs
    so the (block_rows, payload) working set fits VMEM.

    ``steps_per_launch=S > 1``: the temporal-blocked path (see module
    docstring) — square (K, M, *) operands on a deep-halo working buffer,
    a required (K, S) ``act`` mask, full (K, M, payload) buffer out
    (caller slices the rows still valid after S halo shrinks);
    ``block_rows`` is ignored (one program per member).
    """
    if combine not in COMBINE_MODES:
        raise ValueError(f"unknown combine mode {combine!r}; known {COMBINE_MODES}")
    if src.ndim != 3 or wgt.ndim not in (3, 4):
        raise ValueError(
            f"expected (K, S, payload)/(K, W, D) operands, got "
            f"{src.shape}/{wgt.shape}"
        )
    if wgt.ndim == 4 and steps_per_launch <= 1:
        raise ValueError(
            "time-varying (K, S, M, D) tables require steps_per_launch > 1")
    if steps_per_launch < 1:
        raise ValueError(f"steps_per_launch must be >= 1, got {steps_per_launch}")
    if steps_per_launch > 1:
        if act is None:
            raise ValueError("steps_per_launch > 1 requires an act mask")
        if act.ndim != 2 or act.shape[1] != steps_per_launch:
            raise ValueError(
                f"act must be (K, {steps_per_launch}), got {act.shape}")
        return _blocked_call(
            src, idx, wgt, act.astype(jnp.float32), kind=kind,
            iterations=iterations, scratch=scratch, combine=combine,
            interpret=interpret,
        )
    K, S, payload = src.shape
    _, W, D = wgt.shape
    if wgt.shape[0] != K:
        raise ValueError(f"operand K mismatch: {src.shape}/{wgt.shape}")
    if combine == "pair" and S != 2 * W:
        raise ValueError(
            f"pair combine needs src rows == 2 * W (the [x | partner] "
            f"halves), got {S} vs W = {W}")
    if combine in ("window", "pair"):
        # idx is semantically unused (window: src row = own row + slot
        # offset; pair: src row = own row and own row + W); feed a
        # 1-element dummy so no dead (K, W, D) block is DMA'd per program
        idx = jnp.zeros((K, 1, 1), jnp.int32)
    elif idx.shape != wgt.shape:
        raise ValueError(f"operand shape mismatch: {idx.shape}/{wgt.shape}")

    # Hardware tiles: payload -> 128-lane multiple, rows -> sublane/block
    # multiples. Padded idx rows gather src row 0 at weight 0, padded src
    # rows are never indexed, padded payload columns stay zero through the
    # (row-wise linear) combine; everything is sliced off on return. The
    # interpreter has no tile constraints, so off-TPU the operands stay
    # unpadded — lane-padding there would double the per-step elementwise
    # work this kernel exists to minimize.
    lane, sublane = (1, 1) if interpret else (LANE, SUBLANE)
    pad_p = (-payload) % lane
    block_rows = block_rows or W + (-W) % sublane
    block_rows = max(sublane, min(block_rows, W + (-W) % sublane))
    pad_w = (-W) % block_rows
    if combine == "window":
        # out row w reads src rows [w .. w + D-1]: padded out rows must
        # still slice in bounds (their weights are zero, values discarded)
        if S < W + D - 1:
            raise ValueError(
                f"window combine needs src rows >= W + D - 1 = {W + D - 1}, "
                f"got {S} (window D = {D} includes the halo)"
            )
        pad_s = max(pad_w, (-S) % sublane)
    elif combine == "pair":
        # padded out rows slice src rows up to W + Wp: keep pad_s >= pad_w
        pad_s = max(pad_w, (-S) % sublane)
    else:
        pad_s = (-S) % sublane
    srcp = jnp.pad(src, ((0, 0), (0, pad_s), (0, pad_p)))
    idxp = (idx if combine in ("window", "pair")
            else jnp.pad(idx, ((0, 0), (0, pad_w), (0, 0))))
    wgtp = jnp.pad(wgt, ((0, 0), (0, pad_w), (0, 0)))
    Sp, Pp = srcp.shape[1], srcp.shape[2]
    Wp = W + pad_w
    idx_block = (
        pl.BlockSpec((1, 1, 1), lambda k, i: (k, 0, 0))
        if combine in ("window", "pair")
        else pl.BlockSpec((1, block_rows, D), lambda k, i: (k, i, 0))
    )

    out = pl.pallas_call(
        functools.partial(
            _step_kernel,
            kind=kind,
            iterations=iterations,
            scratch=scratch,
            payload=payload,
            combine=combine,
            block_rows=block_rows,
            pair_rows=W,
        ),
        grid=(K, Wp // block_rows),
        in_specs=[
            pl.BlockSpec((1, Sp, Pp), lambda k, i: (k, 0, 0)),
            idx_block,
            pl.BlockSpec((1, block_rows, D), lambda k, i: (k, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows, Pp), lambda k, i: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, Wp, Pp), src.dtype),
        interpret=interpret,
    )(srcp, idxp, wgtp)
    return out[:, :W, :payload]


def taskbench_step_interior(
    src: jax.Array,
    idx: jax.Array,
    wgt: jax.Array,
    act: jax.Array,
    *,
    depth: int,
    **kw,
) -> jax.Array:
    """Interior phase of a software-pipelined blocked launch.

    The working buffer is the OWNED (K, B, payload) block alone — no halo
    rows at all. The valid span still shrinks by ``r`` rows per side per
    inner step (the standard blocked contract), so after S steps exactly the
    rows whose S-step light cone never left the block survive: [depth,
    B - depth) with ``depth = S*r``. Those rows are what this entry point
    returns, and by construction they depend on no in-flight halo — the
    property the pipelined runtime exploits to run this launch UNDER the
    next exchange. Requires ``B > 2*depth`` (a nonempty interior); operands
    are per-row tables for the owned rows (wgt (K, B, D)).
    """
    B = src.shape[1]
    if B <= 2 * depth:
        raise ValueError(
            f"interior phase needs block > 2*depth, got {B} <= {2 * depth}")
    out = taskbench_step_pallas(src, idx, wgt, act, **kw)
    return jax.lax.slice_in_dim(out, depth, B - depth, axis=1)


def taskbench_step_boundary(
    left: jax.Array,
    right: jax.Array,
    idx: jax.Array,
    wgt: jax.Array,
    act: jax.Array,
    *,
    depth: int,
    **kw,
) -> Tuple[jax.Array, jax.Array]:
    """Boundary phase of a software-pipelined blocked launch.

    ``left``/``right`` are the two (K, 3*depth, payload) edge working
    buffers — [received halo | first 2*depth owned rows] and [last 2*depth
    owned rows | received halo] — fused ROW-WISE into one (K, 6*depth)
    working buffer so both sides of all K members ride ONE program instance
    per member. The fusion is exact: the left side's surviving rows are
    buffer rows [depth, 2*depth) whose S-step light cone spans buffer rows
    [0, 3*depth - 1], the right side's are rows [4*depth, 5*depth) with
    cone [3*depth, 6*depth - 1] — neither cone crosses the junction at row
    3*depth, so the halves cannot contaminate each other (junction-adjacent
    rows DO mix across it at depth >= 1, but those are garbage rows outside
    both cones). Each side's middle ``depth`` rows are the new edge rows of
    the block — precisely the rows the NEXT launch's exchange must send,
    which is why the pipelined runtime issues that exchange on this entry
    point's outputs. idx/wgt follow the fused buffer layout (rows
    [left..., right...] on the row axis); ``act`` is the member mask
    (K, S), shared by both sides. Returns (left_out, right_out), each
    (K, depth, payload).
    """
    if left.shape != right.shape or left.shape[1] != 3 * depth:
        raise ValueError(
            f"boundary buffers must both be (K, {3 * depth}, payload), got "
            f"{left.shape}/{right.shape}")
    src = jnp.concatenate([left, right], axis=1)
    out = taskbench_step_pallas(src, idx, wgt, act, **kw)
    return (jax.lax.slice_in_dim(out, depth, 2 * depth, axis=1),
            jax.lax.slice_in_dim(out, 4 * depth, 5 * depth, axis=1))


def prepare_step_operands(dep_lists, width: int, self_pos) -> tuple:
    """Host-side build of one member's (idx, wgt) kernel operands.

    Args:
      dep_lists: length-``width`` list; entry p is the sequence of SRC ROW
        positions task p gathers (duplicates allowed — they weigh double,
        matching combine_dependencies). Empty -> self-padded.
      width: number of output rows W.
      self_pos: length-``width`` array of each row's own position in src
        (the zero-dep "keep own state" row).

    Returns:
      idx int32 (W, D), wgt WEIGHT_DTYPE (W, D) with D = max(1, max deps);
      weights pre-normalized to 1/live-count (accumulated in
      WEIGHT_ACCUM_DTYPE, rounded once by finalize_weights — the shared
      precision policy) so the kernel's weighted sum IS the masked mean.
    """
    D = max(1, max((len(d) for d in dep_lists), default=0))
    idx = np.zeros((width, D), dtype=np.int32)
    wgt = np.zeros((width, D), dtype=WEIGHT_ACCUM_DTYPE)
    for p, deps in enumerate(dep_lists):
        if not deps:
            idx[p, 0] = self_pos[p]
            wgt[p, 0] = 1.0
            continue
        w = 1.0 / len(deps)
        for j, q in enumerate(deps):
            idx[p, j] = q
            wgt[p, j] = w
    return idx, finalize_weights(wgt)
