"""Pallas fused-timestep megakernel for Task Bench graphs.

One ``pallas_call`` executes an ENTIRE Task Bench timestep — gather the
padded dependency slots from the previous-state buffer, combine them
(masked mean), and run the grain-size body — where the ``fused`` backend
emits one gather + one combine + one body op per step. At fine grain the
per-op dispatch cost of that chain is exactly the overhead the paper's METG
measures, so fusing the step control path lowers the repo's measurable
floor (cf. Task Bench SC'20 §6.1: sub-microsecond METG needs a fused
per-task path).

Batching contract: all operands carry a leading K axis — a
``GraphEnsemble``'s K members' combines and bodies batch into the SAME
launch (K is the slowest grid dimension, so member k's row-blocks are
contiguous program instances; see DESIGN.md §4 for why K is an operand axis
and not a vmap).

Inputs (see ``prepare_step_operands`` for how runtimes build idx/wgt):

  src  (K, S, payload)  previous-state rows to gather FROM. S may exceed the
                        output width W (halo-extended local blocks).
  idx  (K, W, D) int32  dependency slot -> src row. Every output row must
                        have >= 1 live slot: rows with no dependencies are
                        self-padded (idx = own row, weight 1), which encodes
                        task_kernels.combine_dependencies' "zero deps keep
                        own state" rule with no in-kernel branch.
  wgt  (K, W, D) f32    pre-normalized combine weights (mask / live-count),
                        so the masked MEAN is a single weighted sum — no
                        in-kernel max/divide/where.

Three combine strategies, selected statically:

  window  for halo-expressible dependence patterns (the pallas_step
          runtime's default): slot j of wgt is the weight of the dependency
          at window offset j - halo, so the combine is a static unrolled
          sum of 2*halo+1 SHIFTED CONTIGUOUS SLICES of src — no gather at
          all, just VPU fused multiply-adds over (rows, payload) tiles.
          idx is ignored (src row = own row + j by construction).
  gather  dependency rows are fancy-indexed out of src (lax.gather) per
          the idx operand — the general path for arbitrary padded dep
          slots.
  onehot  the combine is lifted to a (W, S) one-hot weight matrix applied
          with ``jnp.dot`` — the MXU-friendly fallback for TPUs where a
          row gather does not lower.

Validated bit-for-bit against ``ref.taskbench_step_ref`` (same value-level
body functions from ``bodies.py``) in interpret mode; see tests/test_kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.bodies import LANE, SUBLANE, apply_body

COMBINE_MODES = ("window", "gather", "onehot")


def _step_kernel(
    src_ref,
    idx_ref,
    wgt_ref,
    o_ref,
    *,
    kind: str,
    iterations: int,
    scratch: int,
    payload: int,
    combine: str,
    block_rows: int,
):
    src = src_ref[0]  # (S, Pp)
    idx = idx_ref[0]  # (Wb, D)
    wgt = wgt_ref[0]  # (Wb, D)

    if combine == "window":
        # wgt column j weighs the dependency at window offset j - halo:
        # out row w combines src rows [row0 + w .. row0 + w + 2*halo], a
        # static unrolled slice-FMA chain (no gather, no index arithmetic).
        row0 = pl.program_id(1) * block_rows
        srcf = src.astype(jnp.float32)
        x = jnp.zeros((wgt.shape[0], src.shape[1]), jnp.float32)
        for j in range(wgt.shape[1]):
            win = jax.lax.dynamic_slice_in_dim(srcf, row0 + j, wgt.shape[0], 0)
            x = x + win * wgt[:, j][:, None]
    elif combine == "gather":
        gathered = src[idx].astype(jnp.float32)  # (Wb, D, Pp)
        x = (gathered * wgt[..., None]).sum(axis=1)
    else:  # onehot: lift the gather to an MXU matmul
        S = src.shape[0]
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, S), 2)
        C = ((idx[..., None] == col).astype(jnp.float32) * wgt[..., None]).sum(axis=1)
        x = jnp.dot(C, src.astype(jnp.float32), preferred_element_type=jnp.float32)
    x = x.astype(src.dtype)

    if kind == "memory_bound" and iterations > 0:
        # the sweep mixes columns (roll), so it must see the TRUE payload
        true = apply_body(x[:, :payload], kind, iterations, scratch)
        x = jnp.pad(true, ((0, 0), (0, x.shape[-1] - payload)))
    else:
        x = apply_body(x, kind, iterations, scratch)
    o_ref[0] = x


@functools.partial(
    jax.jit,
    static_argnames=(
        "kind", "iterations", "scratch", "block_rows", "combine", "interpret",
    ),
)
def taskbench_step_pallas(
    src: jax.Array,
    idx: jax.Array,
    wgt: jax.Array,
    *,
    kind: str = "compute_bound",
    iterations: int = 16,
    scratch: int = 2048,
    block_rows: int = 0,
    combine: str = "gather",
    interpret: bool = False,
) -> jax.Array:
    """One fused Task Bench timestep for K graphs: (K, W, payload) out.

    ``block_rows=0`` keeps each member's full width in one program (the
    fine-grain default — minimal grid overhead); set it to tile wide graphs
    so the (block_rows, payload) working set fits VMEM.
    """
    if combine not in COMBINE_MODES:
        raise ValueError(f"unknown combine mode {combine!r}; known {COMBINE_MODES}")
    if src.ndim != 3 or wgt.ndim != 3:
        raise ValueError(
            f"expected (K, S, payload)/(K, W, D) operands, got "
            f"{src.shape}/{wgt.shape}"
        )
    K, S, payload = src.shape
    _, W, D = wgt.shape
    if wgt.shape[0] != K:
        raise ValueError(f"operand K mismatch: {src.shape}/{wgt.shape}")
    if combine == "window":
        # idx is semantically unused (src row = own row + slot offset); feed
        # a 1-element dummy so no dead (K, W, D) block is DMA'd per program
        idx = jnp.zeros((K, 1, 1), jnp.int32)
    elif idx.shape != wgt.shape:
        raise ValueError(f"operand shape mismatch: {idx.shape}/{wgt.shape}")

    # Hardware tiles: payload -> 128-lane multiple, rows -> sublane/block
    # multiples. Padded idx rows gather src row 0 at weight 0, padded src
    # rows are never indexed, padded payload columns stay zero through the
    # (row-wise linear) combine; everything is sliced off on return. The
    # interpreter has no tile constraints, so off-TPU the operands stay
    # unpadded — lane-padding there would double the per-step elementwise
    # work this kernel exists to minimize.
    lane, sublane = (1, 1) if interpret else (LANE, SUBLANE)
    pad_p = (-payload) % lane
    block_rows = block_rows or W + (-W) % sublane
    block_rows = max(sublane, min(block_rows, W + (-W) % sublane))
    pad_w = (-W) % block_rows
    if combine == "window":
        # out row w reads src rows [w .. w + D-1]: padded out rows must
        # still slice in bounds (their weights are zero, values discarded)
        if S < W + D - 1:
            raise ValueError(
                f"window combine needs src rows >= W + D - 1 = {W + D - 1}, "
                f"got {S} (window D = {D} includes the halo)"
            )
        pad_s = max(pad_w, (-S) % sublane)
    else:
        pad_s = (-S) % sublane
    srcp = jnp.pad(src, ((0, 0), (0, pad_s), (0, pad_p)))
    idxp = idx if combine == "window" else jnp.pad(idx, ((0, 0), (0, pad_w), (0, 0)))
    wgtp = jnp.pad(wgt, ((0, 0), (0, pad_w), (0, 0)))
    Sp, Pp = srcp.shape[1], srcp.shape[2]
    Wp = W + pad_w
    idx_block = (
        pl.BlockSpec((1, 1, 1), lambda k, i: (k, 0, 0))
        if combine == "window"
        else pl.BlockSpec((1, block_rows, D), lambda k, i: (k, i, 0))
    )

    out = pl.pallas_call(
        functools.partial(
            _step_kernel,
            kind=kind,
            iterations=iterations,
            scratch=scratch,
            payload=payload,
            combine=combine,
            block_rows=block_rows,
        ),
        grid=(K, Wp // block_rows),
        in_specs=[
            pl.BlockSpec((1, Sp, Pp), lambda k, i: (k, 0, 0)),
            idx_block,
            pl.BlockSpec((1, block_rows, D), lambda k, i: (k, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows, Pp), lambda k, i: (k, i, 0)),
        out_shape=jax.ShapeDtypeStruct((K, Wp, Pp), src.dtype),
        interpret=interpret,
    )(srcp, idxp, wgtp)
    return out[:, :W, :payload]


def prepare_step_operands(dep_lists, width: int, self_pos) -> tuple:
    """Host-side build of one member's (idx, wgt) kernel operands.

    Args:
      dep_lists: length-``width`` list; entry p is the sequence of SRC ROW
        positions task p gathers (duplicates allowed — they weigh double,
        matching combine_dependencies). Empty -> self-padded.
      width: number of output rows W.
      self_pos: length-``width`` array of each row's own position in src
        (the zero-dep "keep own state" row).

    Returns:
      idx int32 (W, D), wgt float32 (W, D) with D = max(1, max deps);
      weights pre-normalized to 1/live-count (computed in float64, rounded
      once) so the kernel's weighted sum IS the masked mean.
    """
    D = max(1, max((len(d) for d in dep_lists), default=0))
    idx = np.zeros((width, D), dtype=np.int32)
    wgt = np.zeros((width, D), dtype=np.float64)
    for p, deps in enumerate(dep_lists):
        if not deps:
            idx[p, 0] = self_pos[p]
            wgt[p, 0] = 1.0
            continue
        w = 1.0 / len(deps)
        for j, q in enumerate(deps):
            idx[p, j] = q
            wgt[p, j] = w
    return idx, wgt.astype(np.float32)
