"""Shared grain-size task-body math for the Task Bench kernels.

One definition of each body, written on *values* (not Refs), so the same
function is used by

  * the runtime reference path (``repro.core.task_kernels``),
  * the standalone Pallas body kernels (``taskbench_compute.py`` and
    ``memory_bound_pallas`` below), and
  * the fused-timestep megakernel (``taskbench_step.py``),

so every runtime backend — jnp or Pallas — executes the identical op
sequence. The TEST oracles deliberately do NOT share this module:
``kernels/ref.py`` re-derives the semantics independently so parity tests
can catch a regression here.

This module depends only on jax — it sits at the bottom of the kernel
subsystem so both ``repro.core`` and ``repro.kernels`` may import it without
cycles.

Bodies (see the paper §6.1 and task_kernels.py for the overhead model):

  compute_bound  iterated elementwise FMA x <- A*x + B; |A| < 1 keeps any
                 grain size bounded while staying un-DCE-able.
  memory_bound   bytes-dominated scratch sweep: expand the payload into a
                 (scratch,) working set, read-modify-write it per iteration
                 (roll + add forces a full pass), reduce back to payload.
  empty          identity (pure runtime-overhead probe).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Contraction constants: x converges towards B/(1-A) = 0.2 without ever
# being constant-foldable (A, B are runtime scalars broadcast in).
FMA_A = 0.5
FMA_B = 0.1

LANE = 128
SUBLANE = 8


def fma_body(x: jax.Array, iterations: int) -> jax.Array:
    """Iterated FMA: x <- A*x + B, ``iterations`` times (trace-time loop-free)."""
    a = jnp.asarray(FMA_A, x.dtype)
    b = jnp.asarray(FMA_B, x.dtype)

    def body(_, v):
        return a * v + b

    return jax.lax.fori_loop(0, iterations, body, x)


def memory_sweep_body(x: jax.Array, iterations: int, scratch: int) -> jax.Array:
    """Bytes-dominated body: stream a scratch buffer ``iterations`` times.

    Each point expands its payload into a (scratch,) working set, sweeps it
    (read-modify-write) per iteration, then reduces back to payload size.
    """
    lead = x.shape[:-1]
    payload = x.shape[-1]
    reps = -(-scratch // payload)  # ceil
    buf = jnp.tile(x, lead and (1,) * len(lead) + (reps,) or (reps,))[..., :scratch]

    def body(i, b):
        # rotate + add: forces a full read and write of the buffer
        return jnp.roll(b, 1, axis=-1) + jnp.asarray(1e-6, b.dtype)

    buf = jax.lax.fori_loop(0, iterations, body, buf)
    # reduce back to payload: mean over the scratch window per payload slot
    pad = reps * payload - scratch
    buf = jnp.concatenate([buf, jnp.zeros(lead + (pad,), buf.dtype)], axis=-1)
    return buf.reshape(lead + (reps, payload)).mean(axis=-2)


def apply_body(x: jax.Array, kind: str, iterations: int, scratch: int) -> jax.Array:
    """Value-level body dispatch shared by the Pallas kernels."""
    if kind == "empty" or iterations == 0:
        return x
    if kind == "compute_bound":
        return fma_body(x, iterations)
    if kind == "memory_bound":
        return memory_sweep_body(x, iterations, scratch)
    raise ValueError(f"unknown kernel kind {kind!r}")


# --------------------------------------------------- standalone body kernels


def _memory_kernel(x_ref, o_ref, *, iterations: int, scratch: int, payload: int):
    if iterations == 0:  # same early-out as apply_body: the body is identity
        o_ref[...] = x_ref[...]
        return
    # The sweep mixes columns (roll), so it must run on the TRUE payload
    # slice — lane padding would leak zeros into real columns.
    x = x_ref[...][:, :payload]
    out = memory_sweep_body(x, iterations, scratch)
    o_ref[...] = jnp.pad(out, ((0, 0), (0, o_ref.shape[-1] - payload)))


@functools.partial(
    jax.jit, static_argnames=("iterations", "scratch", "block_rows", "interpret")
)
def memory_bound_pallas(
    x: jax.Array,
    iterations: int,
    scratch: int,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Scratch-sweep body over x: (rows, payload). Returns same shape/dtype.

    Pallas rendition of ``memory_sweep_body`` so ``use_pallas=True`` covers
    the memory-bound kernel kind too. The (block_rows, scratch) working set
    lives in VMEM for the whole sweep; rows are gridded so the working set
    stays under the VMEM budget at any row count.
    """
    if x.ndim != 2:
        raise ValueError(f"expected (rows, payload), got {x.shape}")
    rows, payload = x.shape

    # same policy as taskbench_step: the interpreter has no tile
    # constraints, and lane-padding would inflate the very copy traffic a
    # memory-bound body exists to measure
    lane, sublane = (1, 1) if interpret else (LANE, SUBLANE)
    pad_p = (-payload) % lane
    block_rows = max(sublane, min(block_rows, rows + (-rows) % sublane))
    pad_r = (-rows) % block_rows
    xp = jnp.pad(x, ((0, pad_r), (0, pad_p)))
    rp, pp = xp.shape

    out = pl.pallas_call(
        functools.partial(
            _memory_kernel, iterations=iterations, scratch=scratch, payload=payload
        ),
        grid=(rp // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, pp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, pp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, pp), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:rows, :payload]
