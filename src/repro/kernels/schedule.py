"""Launch-depth auto-tuner for the temporal-blocked megakernel.

Temporal blocking (``taskbench_step.py``, ``steps_per_launch=S``) trades
VMEM residency for launch amortization: the working buffer grows by
``2*S*radius`` rows (deep halo) and must stay resident for all S inner
steps, because inner steps couple every row (no row grid). The right S is
therefore a function of the *shape* — block rows, halo radius, payload —
and the VMEM budget, not a constant. This module owns that policy so the
runtime, the benchmarks, and the tests agree on one sizing rule.

``steps_per_launch`` runtime option values:

  1 / None        single-step launches (the PR-2 behavior; default)
  "auto" / 0      pick the deepest candidate whose working set fits VMEM
  any int > 1     explicit depth, clamped to the graph's combine-step count
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

#: Half of a TPU core's ~16 MiB of VMEM: the working buffer coexists with
#: the weight/idx operands, the +-halo padded copy, and the f32 accumulator.
DEFAULT_VMEM_BUDGET = 8 * 2 ** 20

#: Depths the auto-tuner considers (deepest first). Powers of two keep the
#: benchmark sweep S in {1, 2, 4, 8, 16} aligned with what "auto" can pick.
CANDIDATES = (16, 8, 4, 2, 1)

_LANE = 128  # payload pads to the TPU lane multiple inside the kernel


def blocked_working_set_bytes(
    block: int,
    radius: int,
    steps_per_launch: int,
    payload: int,
    *,
    dtype_bytes: int = 4,
    combine: str = "window",
) -> int:
    """VMEM bytes one member's blocked launch keeps resident.

    M = block + 2*S*radius working rows; every mode holds the src/out
    buffer, a working copy, and the f32 accumulator (~4 row-buffers of
    padded payload) plus the per-row weight table. The non-window combines
    carry mode-specific intermediates on top: gather materializes the
    (M, D, payload) gathered rows; onehot the (M, M) combine matrix and
    its (M, D, M) one-hot expansion (built once per launch).
    """
    m = block + 2 * steps_per_launch * radius
    padded_payload = -(-payload // _LANE) * _LANE
    window = 2 * radius + 1
    buffers = 4 * m * padded_payload * dtype_bytes
    weights = m * window * dtype_bytes
    if combine == "gather":
        buffers += m * window * padded_payload * dtype_bytes
    elif combine == "onehot":
        buffers += m * m * dtype_bytes + m * window * m * dtype_bytes
    return buffers + weights


def choose_steps_per_launch(
    *,
    block: int,
    radius: int,
    payload: int,
    total_steps: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    candidates: Sequence[int] = CANDIDATES,
    combine: str = "window",
) -> int:
    """Deepest candidate S whose blocked working set fits the VMEM budget.

    Also refuses depths that cannot possibly pay off: S is capped at the
    graph's combine-step count (``total_steps - 1``; a launch deeper than
    the remaining steps is all masked tail).
    """
    cap = max(1, total_steps - 1) if total_steps and total_steps > 1 else None
    for s in sorted(set(int(c) for c in candidates), reverse=True):
        if s < 1:
            continue
        if cap is not None and s > cap:
            continue
        if blocked_working_set_bytes(
                block, radius, s, payload, combine=combine) <= vmem_budget:
            return s
    return 1


def resolve_steps_per_launch(
    value: Union[int, str, None],
    *,
    block: int,
    radius: int,
    payload: int,
    total_steps: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    combine: str = "window",
) -> int:
    """Turn the ``steps_per_launch`` runtime option into a concrete S."""
    if value in (None, 1):
        return 1
    if value in ("auto", 0, "0"):
        return choose_steps_per_launch(
            block=block, radius=radius, payload=payload,
            total_steps=total_steps, vmem_budget=vmem_budget,
            combine=combine,
        )
    s = int(value)
    if s < 1:
        raise ValueError(f"steps_per_launch must be >= 1 or 'auto', got {value!r}")
    if total_steps and total_steps > 1:
        s = min(s, total_steps - 1)  # deeper than the run is all masked tail
    return s
