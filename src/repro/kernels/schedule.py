"""Launch-depth auto-tuner for the temporal-blocked megakernel.

Temporal blocking (``taskbench_step.py``, ``steps_per_launch=S``) trades
VMEM residency for launch amortization: the working buffer grows by
``2*S*radius`` rows (deep halo) and must stay resident for all S inner
steps, because inner steps couple every row (no row grid). The right S is
therefore a function of the *shape* — block rows, halo radius, payload —
and the VMEM budget, not a constant. This module owns that policy so the
runtime, the benchmarks, and the tests agree on one sizing rule.

The pipelined schedule (``pallas_step`` option ``pipeline=True``; DESIGN.md
§6) changes both sides of the trade. Residency: each launch splits into an
interior program (``block`` rows, no halo) and a boundary program
(``3*S*radius`` rows), and the double-buffered halo slots (``S*radius``
rows per side, two generations alive across the issue/join window) ride the
scan carry — the budget must hold the LARGER program plus the slots, not
one monolithic ``block + 2*S*radius`` buffer. Depth choice: hiding the
exchange only works if the interior compute is long enough to cover it, so
``"auto"`` prefers the deepest candidate whose interior row-steps also
clear the exchange-cost model below; with no such candidate it falls back
to the plain VMEM-deepest choice (the runtime will then run the serial
schedule wherever the interior is empty).

``steps_per_launch`` runtime option values:

  1 / None        single-step launches (the PR-2 behavior; default)
  "auto" / 0      pick the deepest candidate whose working set fits VMEM
                  (and, when pipelining, whose interior covers the exchange)
  any int > 1     explicit depth, clamped to the graph's combine-step count

Every covers/pays-off rule here is priced against a *cost model*
(``repro.kernels.probes.CostModel``). Resolvers take ``model=``; passing
None resolves the default (env constant > cached probe calibration >
analytic fallback — precedence documented and tested in probes.py /
tests/test_cost_model.py). The model only decides WHICH schedule runs,
never WHAT it computes — numerics are bit-identical across models.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence, Tuple, Union


def is_auto(value: Union[int, str, None]) -> bool:
    """Whether a ``steps_per_launch`` option value delegates the depth
    choice to this tuner. THE one spelling check — the runtime consults it
    too (the tuner's profitability verdict only binds on delegated
    choices), so the accepted spellings can never desync."""
    return value in ("auto", 0, "0")

#: Half of a TPU core's ~16 MiB of VMEM: the working buffer coexists with
#: the weight/idx operands, the +-halo padded copy, and the f32 accumulator.
DEFAULT_VMEM_BUDGET = 8 * 2 ** 20

#: Depths the auto-tuner considers (deepest first). Powers of two keep the
#: benchmark sweep S in {1, 2, 4, 8, 16} aligned with what "auto" can pick.
CANDIDATES = (16, 8, 4, 2, 1)

_LANE = 128  # payload pads to the TPU lane multiple inside the kernel

#: Covering model for the pipeline: one deep ring exchange costs about as
#: much wall as this many row-steps (a row-step = one working row advanced
#: one depth). Calibrated against this container's forced-host devices,
#: where the exchange is rendezvous-dominated (~80-200us vs ~0.1-0.2us per
#: row-step at payload 64): S=8 at block 256 measurably pays, S=16 does
#: not, which brackets the constant. A real-interconnect build re-measures
#: — with `python -m repro.kernels.probes` (the cached measured model
#: replaces this constant wholesale), or, without touching source or
#: cache, via the REPRO_PIPELINE_EXCHANGE_ROW_STEPS environment variable.
#: Used only to rank "auto" candidates — never to forbid an explicit S.
PIPELINE_EXCHANGE_ROW_STEPS = 512

_EXCHANGE_ROW_STEPS_ENV = "REPRO_PIPELINE_EXCHANGE_ROW_STEPS"


def _resolve_model(model):
    """``model=None`` -> the default CostModel (env > cached probes >
    analytic; see probes.default_cost_model). The probes import is lazy
    so this policy module stays importable without touching the cache
    machinery, and so probes.py can import US at module top."""
    if model is None:
        from repro.kernels import probes

        return probes.default_cost_model()
    return model


def exchange_row_steps(model=None):
    """The calibrated exchange cost in row-steps under ``model``.

    With no model this re-resolves the default per call (not cached at
    import) so per-platform re-calibration needs no reimport: set
    ``REPRO_PIPELINE_EXCHANGE_ROW_STEPS``, or drop a probe calibration
    into the cache file, and the next "auto" resolution uses it. Invalid
    env values fail loudly — a silently ignored calibration is worse
    than a crash."""
    return _resolve_model(model).exchange_row_steps


def record_resolution(tracer, *, plan: str, steps_per_launch: int,
                      pipeline: bool, model=None, reason: str = "",
                      **attrs) -> None:
    """Emit one decision record for a completed schedule resolution.

    The record is an instant span carrying everything a trace reader needs
    to audit the tuner's verdict without re-deriving it: the plan kind, the
    chosen S, whether the pipelined form is active, which cost model backed
    the ranking (analytic / env / measured — and its exchange constant),
    and the reason string the resolver produced. Lives here rather than in
    the runtime so every resolver entry point shares one record shape; a
    null or absent tracer makes this a no-op, keeping the resolvers
    cost-free when tracing is off.
    """
    if tracer is None or not getattr(tracer, "enabled", False):
        return
    m = _resolve_model(model)
    tracer.instant(
        "schedule.resolve",
        plan=plan,
        steps_per_launch=int(steps_per_launch),
        pipeline=bool(pipeline),
        cost_model=m.describe(),
        cost_model_source=m.source,
        exchange_row_steps=float(m.exchange_row_steps),
        reason=reason or "structural",
        **attrs,
    )


def _launch_set_bytes(m: int, window: int, padded_payload: int,
                      dtype_bytes: int, combine: str,
                      steps_per_launch: int) -> int:
    """VMEM bytes one blocked pallas program over ``m`` rows keeps resident.

    Every mode holds the src/out buffer, a working copy, and the f32
    accumulator (~4 row-buffers of padded payload), the per-row weight
    table, the per-depth act mask, and — for gather/onehot — the int32
    per-row idx table (same (m, window) shape as the weights; the original
    budget ignored it and the act mask, which let "auto" overcommit on wide
    payloads). The non-window combines carry mode-specific intermediates on
    top: gather materializes the (m, window, payload) gathered rows; onehot
    the (m, m) combine matrix and its (m, window, m) one-hot expansion
    (built once per launch).
    """
    buffers = 4 * m * padded_payload * dtype_bytes
    tables = m * window * dtype_bytes  # per-row combine weights
    tables += steps_per_launch * 4     # act mask (f32 per depth)
    if combine != "window":
        tables += m * window * 4       # per-row idx table (int32)
    if combine == "gather":
        buffers += m * window * padded_payload * dtype_bytes
    elif combine == "onehot":
        buffers += m * m * dtype_bytes + m * window * m * dtype_bytes
    return buffers + tables


def blocked_working_set_bytes(
    block: int,
    radius: int,
    steps_per_launch: int,
    payload: int,
    *,
    dtype_bytes: int = 4,
    combine: str = "window",
    pipeline: bool = False,
) -> int:
    """VMEM bytes one member's blocked launch keeps resident.

    Serial schedule: one program over ``M = block + 2*S*radius`` working
    rows. Pipelined schedule: the interior program (``block`` rows) and the
    boundary program (both 3*S*radius-row edge buffers ROW-FUSED into one
    6*S*radius-row working buffer — taskbench_step_boundary's layout)
    never coexist in VMEM, so the launch cost is their max — but the
    double-buffered halo slots (``S*radius`` rows per side, two
    generations alive across the issue/join window) are resident
    throughout and are charged on top.
    """
    window = 2 * radius + 1
    padded_payload = -(-payload // _LANE) * _LANE
    depth = steps_per_launch * radius
    if pipeline and block > 2 * depth:
        interior = _launch_set_bytes(
            block, window, padded_payload, dtype_bytes, combine,
            steps_per_launch)
        boundary = _launch_set_bytes(
            6 * depth, window, padded_payload, dtype_bytes, combine,
            steps_per_launch)
        halo_slots = 2 * 2 * depth * padded_payload * dtype_bytes
        return max(interior, boundary) + halo_slots
    return _launch_set_bytes(
        block + 2 * depth, window, padded_payload, dtype_bytes, combine,
        steps_per_launch)


def pipeline_interior_covers_exchange(
    block: int, radius: int, steps_per_launch: int, model=None
) -> bool:
    """Whether the pipelined split pays for itself at this (block, S).

    Two conditions, both in row-steps against the model's exchange cost
    X = exchange_row_steps(model) (the analytic constant, its env-var
    override, or a probe-measured exchange/row-step ratio):

      covers:   ``S * (block - 2*S*r) >= X + 2*S*r`` — the interior phase
                must be long enough to hide one deep exchange (latency
                floor plus the exchanged volume). An empty interior can
                cover nothing.
      pays off: ``6 * S**2 * r <= X`` — the boundary phase's extra work per
                launch (a 6*S*r-row buffer advanced S depths) must not
                exceed the exchange it helps hide; past this depth the
                amortized exchange (X/S per step) is already cheaper than
                the split's overhead and the serial schedule wins.
    """
    depth = steps_per_launch * radius
    interior_rows = block - 2 * depth
    if interior_rows <= 0:
        return False
    X = exchange_row_steps(model)
    covers = steps_per_launch * interior_rows >= X + 2 * depth
    pays_off = 6 * steps_per_launch * depth <= X
    return covers and pays_off


def choose_steps_per_launch(
    *,
    block: int,
    radius: int,
    payload: int,
    total_steps: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    candidates: Sequence[int] = CANDIDATES,
    combine: str = "window",
    pipeline: bool = False,
    model=None,
) -> int:
    """Deepest candidate S whose blocked working set fits the VMEM budget.

    Also refuses depths that cannot possibly pay off: S is capped at the
    graph's combine-step count (``total_steps - 1``; a launch deeper than
    the remaining steps is all masked tail). With ``pipeline`` the deepest
    candidate whose interior covers the exchange AND whose pipelined
    working set fits wins; if none covers, the runtime will run the SERIAL
    schedule at whatever depth is returned, so the fallback is the deepest
    candidate that fits the serial sizing — each candidate is budgeted
    against the schedule it would actually execute.
    """
    model = _resolve_model(model)  # once per choice, not per candidate
    cap = max(1, total_steps - 1) if total_steps and total_steps > 1 else None
    best_fit = None
    for s in sorted(set(int(c) for c in candidates), reverse=True):
        if s < 1:
            continue
        if cap is not None and s > cap:
            continue
        if pipeline and pipeline_interior_covers_exchange(
                block, radius, s, model):
            if blocked_working_set_bytes(
                    block, radius, s, payload, combine=combine,
                    pipeline=True) <= vmem_budget:
                return s
            continue  # pipelined at this depth would overflow; go shallower
        if best_fit is None and blocked_working_set_bytes(
                block, radius, s, payload, combine=combine) <= vmem_budget:
            if not pipeline:
                return s
            best_fit = s
    return best_fit if best_fit is not None else 1


def _resolve_depth(value, chooser, total_steps: Optional[int]) -> int:
    """THE ``steps_per_launch`` option shell, shared by every plan's
    resolver: None/1 -> per-step, "auto" -> the plan's chooser, explicit
    ints validated and clamped to the combine-step count (deeper than the
    run is all masked tail). One parser, so the plans' option handling
    can never diverge."""
    if value in (None, 1):
        return 1
    if is_auto(value):
        return chooser()
    s = int(value)
    if s < 1:
        raise ValueError(f"steps_per_launch must be >= 1 or 'auto', got {value!r}")
    if total_steps and total_steps > 1:
        s = min(s, total_steps - 1)
    return s


def resolve_steps_per_launch(
    value: Union[int, str, None],
    *,
    block: int,
    radius: int,
    payload: int,
    total_steps: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    combine: str = "window",
    pipeline: bool = False,
    model=None,
) -> int:
    """Turn the ``steps_per_launch`` runtime option into a concrete S."""
    return _resolve_depth(
        value,
        lambda: choose_steps_per_launch(
            block=block, radius=radius, payload=payload,
            total_steps=total_steps, vmem_budget=vmem_budget,
            combine=combine, pipeline=pipeline, model=model,
        ),
        total_steps,
    )


# ---------------------------------------------------------------------------
# Stride / all-gather plans (pallas_step beyond halo patterns)
#
# Butterfly (fft/tree) and global (spread, all_to_all) patterns have no
# bounded per-step reach, so the deep-halo trade does not apply. Two plans
# replace it (repro.core.runtimes.pallas_step dispatches):
#
#   stride     per-step XOR block exchanges (butterfly). Temporal blocking
#              a stride plan would need the working buffer closed under
#              every stride in the launch window — the XOR-subgroup
#              closure, which for any window containing all of a period's
#              off-block strides IS the full gather — so the stride plan
#              is per-step BY CONSTRUCTION: steps_per_launch resolves to 1
#              and blocked requests route to the all-gather plan instead.
#   allgather  one full-state gather per launch; every row of the gathered
#              buffer advances exactly (no valid-span shrink), time-varying
#              (S, W, D) tables drive the per-depth combine. Blocking here
#              trades replicated compute (each device advances all W rows,
#              not its B) for 1/S as many collectives — profitable exactly
#              when the replication stays under the exchanges saved
#              (``gathered_pays_off``).


#: Widths at or below this run the all-gather plan by default (the
#: ``gather_width_cap`` runtime option overrides per run). Beyond it the
#: gathered working set — and for all_to_all the (W, D, W) one-hot
#: expansion — outgrows the VMEM story this tuner is honest about.
DEFAULT_GATHER_WIDTH_CAP = 512


def gathered_working_set_bytes(
    width: int,
    max_deps: int,
    steps_per_launch: int,
    payload: int,
    *,
    dtype_bytes: int = 4,
    combine: str = "onehot",
    time_varying: bool = True,
) -> int:
    """VMEM bytes one member's gathered (all-gather plan) launch holds.

    The working buffer is the FULL width (every row advances), so ``m = W``
    in the shared per-launch model; time-varying launches additionally
    hold all S per-depth idx/wgt tables — (S, W, D) int32 + float32, the
    operands the halo budget never had to carry — plus the per-depth
    one-hot expansion for the onehot combine.
    """
    padded_payload = -(-payload // _LANE) * _LANE
    buffers = 4 * width * padded_payload * dtype_bytes
    table_depths = steps_per_launch if time_varying else 1
    tables = table_depths * width * max_deps * (4 + dtype_bytes)
    tables += steps_per_launch * 4  # act mask
    if combine == "gather":
        buffers += width * max_deps * padded_payload * dtype_bytes
    else:  # onehot: (W, W) combine matrix + its (W, D, W) expansion
        buffers += width * width * dtype_bytes
        buffers += width * max_deps * width * dtype_bytes
    return buffers + tables


def gathered_pays_off(width: int, block: int, steps_per_launch: int,
                      model=None) -> bool:
    """Whether a blocked gathered launch beats per-step gathers at this S.

    Per launch the plan saves S - 1 collectives (one gather instead of S),
    worth ``(S-1) * X`` row-steps against the model's exchange cost
    X = exchange_row_steps(model); it pays ``S * (W - B)`` replicated
    row-steps (each device advances the full W-row buffer for S depths
    instead of its own B rows once per step). Deeper is better only while
    the replication stays under the saving. On one device W == B:
    replication is free and any depth pays (blocking is then pure launch
    amortization).
    """
    if steps_per_launch <= 1:
        return False
    return (steps_per_launch * (width - block)
            <= (steps_per_launch - 1) * exchange_row_steps(model))


def choose_steps_per_launch_gathered(
    *,
    width: int,
    block: int,
    max_deps: int,
    payload: int,
    total_steps: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    candidates: Sequence[int] = CANDIDATES,
    combine: str = "onehot",
    time_varying: bool = True,
    model=None,
) -> int:
    """Deepest candidate S that pays off AND fits for the gathered plan.

    Same shape as ``choose_steps_per_launch``: capped at the graph's
    combine-step count, deepest-first over CANDIDATES; a depth must clear
    both the replication pays-off rule and the gathered VMEM budget.
    ``time_varying`` must mirror what the launch will actually hold
    (period-1 patterns carry ONE static table pair, not S) so the budget
    never charges tables that don't exist. No candidate clearing both ->
    1 (the per-step schedule; for butterfly that is the stride plan)."""
    model = _resolve_model(model)
    cap = max(1, total_steps - 1) if total_steps and total_steps > 1 else None
    for s in sorted(set(int(c) for c in candidates), reverse=True):
        if s <= 1:
            continue
        if cap is not None and s > cap:
            continue
        if not gathered_pays_off(width, block, s, model):
            continue
        if gathered_working_set_bytes(
                width, max_deps, s, payload, combine=combine,
                time_varying=time_varying) <= vmem_budget:
            return s
    return 1


def resolve_steps_per_launch_gathered(
    value: Union[int, str, None],
    *,
    width: int,
    block: int,
    max_deps: int,
    payload: int,
    total_steps: Optional[int] = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    combine: str = "onehot",
    time_varying: bool = True,
    model=None,
) -> int:
    """``steps_per_launch`` -> concrete S for the all-gather plan.

    Explicit depths are the user's ablation choice (clamped to the
    combine-step count via the shared ``_resolve_depth`` shell); "auto"
    delegates to ``choose_steps_per_launch_gathered``."""
    return _resolve_depth(
        value,
        lambda: choose_steps_per_launch_gathered(
            width=width, block=block, max_deps=max_deps, payload=payload,
            total_steps=total_steps, vmem_budget=vmem_budget,
            combine=combine, time_varying=time_varying, model=model,
        ),
        total_steps,
    )


def gathered_beats_strides(
    *,
    width: int,
    block: int,
    steps_per_launch: int,
    off_block_strides: int,
    period: int,
    model,
    impl: str = "xla",
) -> Tuple[bool, str]:
    """Rank the butterfly plans: blocked ALLGATHER vs per-step STRIDE.

    The depth rules above only needed a RATIO (exchange cost in
    row-steps); ranking two different plans needs ABSOLUTE walls, which
    only a measured model carries — the analytic fallback always answers
    (False, why): the stride plan stays, exactly the pre-measurement
    behavior. Per-timestep amortized walls, in microseconds:

      stride:    ``launch + (off/period) * stride_us`` — one launch per
                 step; an XOR block exchange only on the off-block slots
                 of the period (in-block pairings are local shuffles).
      allgather: ``(launch + gather_us(W)) / S + (W - B) * row_step_us``
                 — one launch + one full gather amortized over S steps,
                 paid for with replicated compute (every device advances
                 all W rows instead of its B).

    Both plans run the same task body over the owned rows; that term
    cancels. The onehot/pair combine difference is folded into the noise
    (documented model simplification). Returns (verdict, reason) with
    the reason naming the measured numbers — the runtime surfaces it so
    a wrong auto-pick is diagnosable from the message alone.
    """
    model = _resolve_model(model)
    if not getattr(model, "can_rank_plans", False):
        return False, (
            f"plan ranking needs a measured model; verdict source: "
            f"{model.describe()}")
    stride_us = model.stride_us_for(impl)
    if off_block_strides > 0 and stride_us is None:
        return False, (
            f"no measured stride-exchange cost for impl {impl!r}; "
            f"verdict source: {model.describe(width)}")
    S = max(1, int(steps_per_launch))
    gather_us = model.gather_us_at(width)
    stride_cost = model.launch_us + (
        (off_block_strides / max(1, period)) * (stride_us or 0.0))
    gather_cost = ((model.launch_us + gather_us) / S
                   + (width - block) * model.row_step_us)
    verdict = gather_cost < stride_cost
    reason = (
        f"measured: stride-plan step {stride_cost:.1f}us vs gathered "
        f"step {gather_cost:.1f}us at S={S} "
        f"(launch={model.launch_us:.1f}us, "
        f"stride={0.0 if stride_us is None else stride_us:.1f}us x "
        f"{off_block_strides}/{max(1, period)} slots, "
        f"gather={gather_us:.1f}us@w{width}, "
        f"replication={width - block} rows x "
        f"{model.row_step_us:.3f}us)")
    return verdict, reason


#: below this device count a single rendezvous is already minimal and the
#: chunked gather's second stage is pure overhead; at/above it the two
#: ~sqrt(D)-participant segment gathers win structurally on this container
DEFAULT_CHUNKED_GATHER_MIN_DEVICES = 16


def choose_gather_impl(*, width: int, devices: int,
                       model=None) -> Tuple[str, str]:
    """Rank gather_global transports at (devices, width).

    All gather impls are bit-identical (exact row copies); only the wall
    differs, so this is a pure cost choice. A measured model with the
    devices-dimension gather probes (``gather_impl_us``) ranks by
    interpolated walls at this exact (D, W); otherwise the structural
    rule applies: "chunked" at D >= DEFAULT_CHUNKED_GATHER_MIN_DEVICES
    (two ~sqrt(D)-party segment all-gathers against one D-wide
    rendezvous), monolithic "xla" below. Returns (impl, reason) with the
    reason naming the numbers, same contract as gathered_beats_strides.
    """
    if devices <= 2:
        return "xla", (f"{devices} device(s): one rendezvous is already "
                       f"minimal, nothing to chunk")
    model = _resolve_model(model)
    walls = {}
    if getattr(model, "gather_walls_at", None) is not None:
        walls = model.gather_walls_at(width, devices) or {}
    # grouping variants ("chunked:g8") rank the chunk GROUP, not the impl —
    # choose_gather_chunk_group owns them; here they would shadow "chunked"
    walls = {k: v for k, v in walls.items() if ":" not in k}
    if len(walls) >= 2:
        impl = min(walls, key=walls.get)
        detail = ", ".join(
            f"{k}={v:.1f}us" for k, v in sorted(walls.items()))
        return impl, (f"measured gather walls at D={devices}, "
                      f"W={width}: {detail}")
    if devices >= DEFAULT_CHUNKED_GATHER_MIN_DEVICES:
        return "chunked", (
            f"structural: D={devices} >= "
            f"{DEFAULT_CHUNKED_GATHER_MIN_DEVICES}, two ~sqrt(D)-party "
            f"segment gathers beat one {devices}-wide rendezvous "
            f"(no measured devices-dimension probes to overrule)")
    return "xla", (
        f"structural: D={devices} < "
        f"{DEFAULT_CHUNKED_GATHER_MIN_DEVICES}, monolithic all-gather "
        f"(no measured devices-dimension probes to overrule)")


_GATHER_CHUNK_GROUP_ENV = "REPRO_GATHER_CHUNK_GROUP"


def choose_gather_chunk_group(*, devices: int, width: Optional[int] = None,
                              model=None,
                              explicit: Optional[int] = None
                              ) -> Tuple[int, str]:
    """Pick the chunked gather's rendezvous group size G at (devices, width).

    The two-stage hierarchical gather splits D devices into D/G segments of
    G parties each; every G | D is bit-identical, only the wall differs, so
    this is a pure cost choice — the same contract as choose_gather_impl.
    Analytically the per-stage party count is balanced at G ~ sqrt(D), but
    the anatomy probes disagree where rendezvous cost is not symmetric
    across the two stages (e.g. G=8 beating G=4 at D=32 on this
    container), so a measured model with grouping probes
    (``gather_impl_us`` keys "chunked:g{G}") overrules the analytic rule.

    Precedence is the standard resolver ladder: ``explicit`` argument >
    ``REPRO_GATHER_CHUNK_GROUP`` env > measured grouping walls at this
    exact (D, W) (needs >= 2 candidates to rank) > the sqrt(D) analytic
    rule (``_halo.gather_chunk_group``). Explicit/env values that do not
    divide D fail loudly — a silently ignored override is worse than a
    crash. Returns (group, reason) with numbers in the reason.
    """
    def _validated(value, origin: str) -> int:
        try:
            g = int(value)
        except (TypeError, ValueError):
            raise ValueError(
                f"{origin} chunk group {value!r} is not an integer")
        if g < 1 or devices % g:
            raise ValueError(
                f"{origin} chunk group {g} does not divide D={devices} "
                f"(the two-stage segment gather needs G | D)")
        return g

    if explicit is not None:
        g = _validated(explicit, "explicit")
        return g, f"explicit chunk group G={g}"
    raw = os.environ.get(_GATHER_CHUNK_GROUP_ENV)
    if raw is not None and raw.strip():
        g = _validated(raw.strip(), f"env {_GATHER_CHUNK_GROUP_ENV}")
        return g, f"env {_GATHER_CHUNK_GROUP_ENV}={g}"
    model = _resolve_model(model)
    if width is not None and getattr(model, "gather_walls_at", None):
        walls = model.gather_walls_at(width, devices) or {}
        grouped = {}
        for impl, us in walls.items():
            if not impl.startswith("chunked:g"):
                continue
            try:
                g = int(impl.split(":g", 1)[1])
            except ValueError:
                continue
            if 1 < g < devices and devices % g == 0:
                grouped[g] = us
        if len(grouped) >= 2:
            best = min(grouped, key=lambda g: (grouped[g], g))
            detail = ", ".join(
                f"g{g}={us:.1f}us" for g, us in sorted(grouped.items()))
            return best, (f"measured chunked-gather grouping walls at "
                          f"D={devices}, W={width}: {detail}")
    from repro.core.runtimes import _halo

    g = _halo.gather_chunk_group(devices)
    return g, (f"analytic: divisor of D={devices} nearest sqrt(D) -> G={g} "
               f"(no measured grouping probes at this D, W to overrule)")


def choose_member_shards(*, devices: int, num_members: int, width: int,
                         steps_per_launch: int = 1, radius: int = 1,
                         model=None) -> Tuple[int, str]:
    """Price the (Dr, Dk) split of the 2D (row, member) mesh.

    Per-device compute is split-invariant — (K/Dk) members x (W/Dr) rows
    = K*W/D rows whatever the split — so the split is priced on exchange
    structure alone: sharding K divides every deep-halo payload by Dk
    (each device ships halos for only its K/Dk members) and grows blocks
    to W/Dr, cutting the multi-hop count ceil(S*r / B). Candidates are
    the common divisors Dk of (devices, num_members) that keep a row
    RING alive (Dr = devices/Dk >= 2; Dr == 1 would drop the halo
    transport's partner set entirely, a different code path the stacked
    builders do not take) and W % Dr == 0.

    A measured model prices each candidate as

      hops(Dk) * halo_exchange_us + (K/Dk) * 2*S*r * row_step_us

    (rendezvous count + moved halo rows) and returns the argmin; the
    analytic fallback keeps Dk=1 — pre-measurement behavior unchanged,
    same conservatism as gathered_beats_strides.
    """
    depth = max(1, int(steps_per_launch)) * max(0, int(radius))
    candidates = []
    for dk in range(1, min(devices, num_members) + 1):
        if devices % dk or num_members % dk:
            continue
        dr = devices // dk
        if dr < 2 and devices > 1:
            continue
        if width % dr:
            continue
        candidates.append(dk)
    if not candidates or candidates == [1]:
        return 1, (f"no viable (Dr, Dk) split: D={devices}, K={num_members} "
                   f"share no divisor keeping Dr >= 2 and W % Dr == 0")
    model = _resolve_model(model)
    halo_us = getattr(model, "halo_exchange_us", None) or {}
    row_step_us = getattr(model, "row_step_us", None)
    launch_us = getattr(model, "launch_us", None)
    if not halo_us or row_step_us is None or launch_us is None:
        return 1, ("member-shard pricing needs a measured model; "
                   f"verdict source: {model.describe()} — keeping the "
                   "replicated 1D row mesh")
    ex_us = min(halo_us.values())

    def price(dk: int) -> float:
        block = width // (devices // dk)
        hops = max(1, -(-depth // max(1, block)))
        return (hops * ex_us
                + (num_members / dk) * 2 * depth * row_step_us)

    best = min(candidates, key=price)
    return best, (
        f"measured: Dk={best} prices {price(best):.1f}us/launch vs "
        f"Dk=1 at {price(1):.1f}us "
        f"(exchange={ex_us:.1f}us, row-step={row_step_us:.3f}us, "
        f"depth={depth}, K={num_members}, D={devices})")


# --------------------------------------------------------------- deadlines

#: deadline = DEADLINE_FACTOR x the model's expected launch wall. Generous
#: on purpose: a missed straggler costs one late launch, a false positive
#: flags healthy work — and the resilience engine only *reports* deadline
#: hits, so the factor bounds noise tolerance, not correctness.
DEADLINE_FACTOR = 8.0


def expected_launch_wall_us(
    *,
    rows: int,
    steps_per_launch: int,
    model=None,
    impl: str = "xla",
    gather_width: Optional[int] = None,
) -> Optional[float]:
    """The cost model's expected wall of ONE blocked launch, in us.

    ``rows`` is the per-device working-row count (K x block for a stacked
    ensemble). Priced as launch dispatch + rows x S row-steps + one
    transport (a deep halo exchange, or a full-state gather when
    ``gather_width`` names the allgather plan's width). Only a MEASURED
    model carries absolute walls — analytic/env models return None and
    the caller self-calibrates from observed walls instead
    (resilience.detect.DeadlineDetector's fallback)."""
    model = _resolve_model(model)
    launch_us = getattr(model, "launch_us", None)
    row_step_us = getattr(model, "row_step_us", None)
    if launch_us is None or row_step_us is None:
        return None
    us = launch_us + rows * max(1, steps_per_launch) * row_step_us
    if gather_width is not None:
        g = model.gather_us_at(gather_width)
        if g is not None:
            us += g
    elif model.halo_exchange_us:
        us += model.halo_exchange_us.get(
            impl, min(model.halo_exchange_us.values()))
    return us


def launch_deadline_us(
    *,
    rows: int,
    steps_per_launch: int,
    model=None,
    impl: str = "xla",
    gather_width: Optional[int] = None,
    factor: float = DEADLINE_FACTOR,
) -> Optional[float]:
    """``factor`` x the expected launch wall — the straggler deadline, or
    None when the model cannot price one (see expected_launch_wall_us)."""
    expected = expected_launch_wall_us(
        rows=rows, steps_per_launch=steps_per_launch, model=model,
        impl=impl, gather_width=gather_width)
    return None if expected is None else factor * expected
