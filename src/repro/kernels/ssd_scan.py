"""Mamba-2 SSD intra-chunk kernel (state-space duality, arXiv:2405.21060).

SSD evaluates the selective-SSM recurrence chunk-parallel: within a chunk of
T tokens the output decomposes into an intra-chunk quadratic part (this
kernel — the compute hot spot, three MXU matmuls per (chunk, head)) and an
inter-chunk linear recurrence over per-chunk states (tiny, handled by a
lax.scan in ops.py/ref.py).

Per (batch*chunk, head) grid cell, with T tokens, state size N, head dim P:

  a      = cumsum(dtA)                          (T,)   log-decay within chunk
  L_ij   = exp(a_i - a_j) * [j <= i]            (T,T)  causal decay mask
  scores = (C @ B^T) * L                        (T,T)
  Y      = scores @ (X * dt)                    (T,P)  intra-chunk output
  S      = (B * exp(a_T - a) * dt)^T @ X        (N,P)  chunk state contribution

The (T,T) intermediate lives entirely in VMEM (T=128 -> 64 KiB f32), which is
the reason to fuse: XLA would materialize it in HBM per (chunk, head).
Grouped B/C (n_groups < heads) is expressed in the BlockSpec index map.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
NEG_INF = -1e30


def _ssd_chunk_kernel(x_ref, b_ref, c_ref, dta_ref, dt_ref, y_ref, s_ref):
    x = x_ref[0, 0].astype(jnp.float32)    # (T, P)
    bm = b_ref[0, 0].astype(jnp.float32)   # (T, N)
    cm = c_ref[0, 0].astype(jnp.float32)   # (T, N)
    dta = dta_ref[0, 0].astype(jnp.float32)  # (T, 1)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (T, 1)

    a = jnp.cumsum(dta, axis=0)  # (T, 1)
    T = x.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    # exp(a_i - a_j) for j <= i; dtA <= 0 so a is non-increasing -> exp <= 1
    logl = a - a.T  # broadcast (T,1)-(1,T)
    L = jnp.where(ii >= jj, jnp.exp(logl), 0.0)

    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * L  # (T, T)
    xdt = x * dt
    y = jax.lax.dot_general(
        scores, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (T, P)

    a_last = a[-1:, :]  # (1,1)
    decay_to_end = jnp.exp(a_last - a)  # (T, 1)
    bw = bm * decay_to_end * dt  # (T, N)
    state = jax.lax.dot_general(
        bw, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (N, P)

    y_ref[0, 0] = y.astype(y_ref.dtype)
    s_ref[0, 0] = state.astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(
    x: jax.Array,    # (BC, H, T, P)   BC = batch * n_chunks
    b: jax.Array,    # (BC, G, T, N)   G groups, H % G == 0
    c: jax.Array,    # (BC, G, T, N)
    dta: jax.Array,  # (BC, H, T)      dt * A  (<= 0)
    dt: jax.Array,   # (BC, H, T)
    *,
    interpret: bool = False,
):
    """Returns (y: (BC,H,T,P), state: (BC,H,N,P)) — intra-chunk terms."""
    BC, H, T, P = x.shape
    _, G, _, N = b.shape
    if H % G:
        raise ValueError(f"H={H} not a multiple of groups G={G}")
    ratio = H // G

    pad_p = (-P) % LANE
    pad_n = (-N) % LANE
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad_p)))
    bp = jnp.pad(b, ((0, 0), (0, 0), (0, 0), (0, pad_n)))
    cp = jnp.pad(c, ((0, 0), (0, 0), (0, 0), (0, pad_n)))
    dtap = dta[..., None]  # (BC, H, T, 1)
    dtp = dt[..., None]
    Pp, Np = P + pad_p, N + pad_n

    grid = (BC, H)
    y, state = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, T, Pp), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, T, Np), lambda i, h, r=ratio: (i, h // r, 0, 0)),
            pl.BlockSpec((1, 1, T, Np), lambda i, h, r=ratio: (i, h // r, 0, 0)),
            pl.BlockSpec((1, 1, T, 1), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, T, 1), lambda i, h: (i, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, T, Pp), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, 1, Np, Pp), lambda i, h: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BC, H, T, Pp), x.dtype),
            jax.ShapeDtypeStruct((BC, H, Np, Pp), jnp.float32),
        ],
        interpret=interpret,
    )(xp, bp, cp, dtap, dtp)
    return y[..., :P], state[:, :, :N, :P]
