"""Fused RMSNorm Pallas kernel.

y = x * rsqrt(mean(x^2) + eps) * w — one VMEM pass instead of XLA's
reduce + broadcast + mul chain. Rows are tiled over the grid; the feature
dim stays whole in VMEM (d_model up to ~8k fits comfortably: 8k x 4B x
block_rows(8) = 256 KiB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, d: int):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    # mean over the true feature count d (padding columns are zero)
    ms = (x * x).sum(axis=-1, keepdims=True) / d
    y = x * jax.lax.rsqrt(ms + eps) * w
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("eps", "block_rows", "interpret")
)
def rmsnorm_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """RMSNorm over the last dim. x: (rows, d), w: (d,)."""
    rows, d = x.shape
    pad_d = (-d) % LANE
    pad_r = (-rows) % block_rows
    xp = jnp.pad(x, ((0, pad_r), (0, pad_d)))
    wp = jnp.pad(w, (0, pad_d))[None, :]  # keep 2D for TPU layout
    rp, dp = xp.shape

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d=d),
        grid=(rp // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, dp), lambda i: (i, 0)),
            pl.BlockSpec((1, dp), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, dp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, dp), x.dtype),
        interpret=interpret,
    )(xp, wp)
    return out[:rows, :d]
