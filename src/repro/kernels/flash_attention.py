"""Blockwise online-softmax attention (FlashAttention) for TPU in Pallas.

Grid: (batch, q_heads, q_blocks, k_blocks) — the k dimension is innermost and
TPU grids execute sequentially, so the running (m, l, acc) state lives in VMEM
scratch across k steps (the canonical TPU flash pattern; FA-2 arXiv:2307.08691
adapted to MXU tiling: blocks are (blk_q x D) @ (D x blk_k) matmuls with
lane-padded D).

Features:
  * causal masking
  * sliding-window masking (SWA, window w: q - k < w) — Mistral/Gemma local
  * GQA: kv head = q head // group, expressed in the k/v BlockSpec index maps
    so kv blocks are fetched once per group (no host-side head replication)
  * key-length masking for padded sequences
  * fully-masked k blocks are skipped via pl.when (big win for SWA/causal)

Forward only: the framework uses this kernel on no-grad paths (prefill/serve);
the training path uses the jnp reference (ref.py) which jax.grad handles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, sm_scale: float, causal: bool, window: int, blk_q: int, blk_k: int,
    seq_k: int,
):
    i = pl.program_id(2)  # q block
    j = pl.program_id(3)  # k block
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # --- block-level visibility (skip fully masked k blocks) ---------------
    q_lo = i * blk_q
    q_hi = q_lo + blk_q - 1
    k_lo = j * blk_k
    visible = k_lo < seq_k  # traced (program_id): padded tail blocks skip
    if causal:
        visible = jnp.logical_and(visible, k_lo <= q_hi)
    if window > 0:
        k_hi_blk = k_lo + blk_k - 1
        visible = jnp.logical_and(visible, k_hi_blk >= q_lo - window + 1)

    @pl.when(visible)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # (blk_q, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (blk_k, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (blk_k, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (blk_q, blk_k)

        qi = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kj = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kj < seq_k
        if causal:
            mask = jnp.logical_and(mask, kj <= qi)
        if window > 0:
            mask = jnp.logical_and(mask, qi - kj < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (blk_q, 1)
        m_cur = s.max(axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # (blk_q, 1)
        p = jnp.exp(s - m_new)  # (blk_q, blk_k)
        p = jnp.where(mask, p, 0.0)

        l_new = alpha * l_ref[:, :1] + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nk - 1)
    def _fin():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "sm_scale", "blk_q", "blk_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,  # 0 = unlimited
    sm_scale: float | None = None,
    blk_q: int = 128,
    blk_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    group = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(D)

    blk_q = min(blk_q, max(8, 1 << (Sq - 1).bit_length()))
    blk_k = min(blk_k, max(8, 1 << (Sk - 1).bit_length()))
    pad_d = (-D) % LANE
    pad_q = (-Sq) % blk_q
    pad_k = (-Sk) % blk_k
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, pad_d)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, pad_d)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, pad_d)))
    Sqp, Skp, Dp = Sq + pad_q, Sk + pad_k, D + pad_d

    grid = (B, Hq, Sqp // blk_q, Skp // blk_k)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            sm_scale=sm_scale,
            causal=causal,
            window=window,
            blk_q=blk_q,
            blk_k=blk_k,
            seq_k=Sk,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, Dp), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, blk_k, Dp), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, blk_k, Dp), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, Dp), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sqp, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, Dp), jnp.float32),
            pltpu.VMEM((blk_q, LANE), jnp.float32),
            pltpu.VMEM((blk_q, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq, :D]
