"""Single-token decode attention (FlashDecoding-style) in Pallas.

Decode reads a long KV cache with a single query per head: memory-bound, so
the kernel's job is to stream the cache through VMEM exactly once. Grid is
(batch, kv_heads, cache_blocks); each step loads one (blk_s x D) cache tile
and updates the online-softmax state for the whole GQA query group (G query
rows that share this kv head) — the group rides in sublanes so the tile is
read once per group, not once per query head.

Validity of cache positions is supplied as an additive bias row (0 or -inf)
rather than a scalar-prefetch length: portable across interpret mode and
easily extended to paged caches (bias doubles as the page mask).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
SUBLANE = 8
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_out_ref, l_out_ref,
                   acc_ref, m_ref, l_ref):
    s_blk = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s_blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (blk_s, D)
    v = v_ref[0, 0].astype(jnp.float32)  # (blk_s, D)
    bias = bias_ref[0].astype(jnp.float32)  # (blk_s,)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (G, blk_s)
    s = s + bias[None, :]

    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(bias[None, :] > NEG_INF * 0.5, p, 0.0)

    l_ref[...] = jnp.broadcast_to(
        alpha * l_ref[:, :1] + p.sum(axis=-1, keepdims=True), l_ref.shape
    )
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(s_blk == ns - 1)
    def _fin():
        l = l_ref[:, :1]
        lsafe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / lsafe).astype(o_ref.dtype)
        m_out_ref[0, 0] = m_ref[...].astype(m_out_ref.dtype)
        l_out_ref[0, 0] = l_ref[...].astype(l_out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("sm_scale", "blk_s", "window", "interpret")
)
def decode_attention_pallas(
    q: jax.Array,        # (B, Hq, D) — one query token per head
    k_cache: jax.Array,  # (B, Hkv, S, D)
    v_cache: jax.Array,  # (B, Hkv, S, D)
    lengths: jax.Array,  # (B,) int32 — valid cache prefix per sequence
    *,
    sm_scale: float | None = None,
    blk_s: int = 512,
    window: int = 0,  # sliding window: only the last `window` positions visible
    interpret: bool = False,
) -> jax.Array:
    B, Hq, D = q.shape
    _, Hkv, S, _ = k_cache.shape
    if Hq % Hkv:
        raise ValueError(f"Hq={Hq} not a multiple of Hkv={Hkv}")
    G = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(D)

    # additive validity bias, precomputed on host-side jnp (B, S)
    pos = jnp.arange(S, dtype=jnp.int32)[None, :]
    valid = pos < lengths[:, None]
    if window > 0:
        valid = jnp.logical_and(valid, pos >= lengths[:, None] - window)
    bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)

    # group queries under their kv head: (B, Hkv, G, D), scale folded into q
    qg = (q * sm_scale).reshape(B, Hkv, G, D)
    pad_g = (-G) % SUBLANE
    pad_d = (-D) % LANE
    blk_s = min(blk_s, max(SUBLANE, 1 << (S - 1).bit_length()))
    pad_s = (-S) % blk_s
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, pad_g), (0, pad_d)))
    kp = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad_s), (0, pad_d)))
    vp = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad_s), (0, pad_d)))
    biasp = jnp.pad(bias, ((0, 0), (0, pad_s)), constant_values=NEG_INF)
    Gp, Dp, Sp = G + pad_g, D + pad_d, S + pad_s

    grid = (B, Hkv, Sp // blk_s)
    out, m_out, l_out = pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Gp, Dp), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, blk_s, Dp), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, blk_s, Dp), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, blk_s), lambda b, h, s: (b, s)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Gp, Dp), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Gp, LANE), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, Gp, LANE), lambda b, h, s: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, Gp, Dp), q.dtype),
            jax.ShapeDtypeStruct((B, Hkv, Gp, LANE), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, Gp, LANE), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((Gp, Dp), jnp.float32),
            pltpu.VMEM((Gp, LANE), jnp.float32),
            pltpu.VMEM((Gp, LANE), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kp, vp, biasp)
    o = out[:, :, :G, :D].reshape(B, Hq, D)
    m = m_out[:, :, :G, 0].reshape(B, Hq)
    l = l_out[:, :, :G, 0].reshape(B, Hq)
    return o, m, l
