"""Pallas TPU kernel for the Task Bench compute-bound task body.

The paper's kernel is an iterated elementwise FMA (grain unit ~2.5 ns/iter on
the EPYC nodes, §6.1). On TPU this is VPU work: each (rows x payload) tile is
held in VMEM and iterated in registers — arithmetic intensity grows linearly
with `iterations`, so at fine grain the op is bandwidth-bound (2 x 4B per
element) and at coarse grain it saturates the VPU. BlockSpec tiles are
(block_rows, lane-padded payload) so the last dim fills the 128-lane VPU and
rows cover the 8 sublanes.

Validated against ref.py (pure jnp) in interpret mode on CPU; see
tests/test_kernels.py for the shape/dtype sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bodies import LANE, SUBLANE, fma_body


def _fma_kernel(x_ref, o_ref, *, iterations: int):
    o_ref[...] = fma_body(x_ref[...], iterations)


@functools.partial(jax.jit, static_argnames=("iterations", "block_rows", "interpret"))
def taskbench_compute_pallas(
    x: jax.Array,
    iterations: int,
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Iterated FMA over x: (rows, payload). Returns same shape/dtype."""
    if x.ndim != 2:
        raise ValueError(f"expected (rows, payload), got {x.shape}")
    rows, payload = x.shape

    # Pad to hardware tiles: payload -> multiple of 128 lanes, rows -> block.
    pad_p = (-payload) % LANE
    block_rows = max(SUBLANE, min(block_rows, rows + (-rows) % SUBLANE))
    pad_r = (-rows) % block_rows
    xp = jnp.pad(x, ((0, pad_r), (0, pad_p)))
    rp, pp = xp.shape

    out = pl.pallas_call(
        functools.partial(_fma_kernel, iterations=iterations),
        grid=(rp // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, pp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, pp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, pp), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:rows, :payload]
