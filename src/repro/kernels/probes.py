"""Measured cost model: probe microbenchmarks behind the scheduling policy.

``schedule.py`` ranks launch depths and plans with covers/pays-off rules
expressed in *row-steps* against one exchange-cost constant
(``PIPELINE_EXCHANGE_ROW_STEPS``). That constant is a hand calibration of
THIS container; the paper's whole point is that such constants are
measurable per platform and that cross-system verdicts only hold when they
are re-measured. This module does the measuring:

  probe_launch_us          per-launch dispatch cost (tiny step kernel)
  probe_row_step_us        marginal cost of one working row advanced one
                           depth (slope of the step kernel over width)
  probe_halo_exchange_us   one deep ring exchange, per HALO_ASYNC_IMPLS key
  probe_stride_exchange_us one XOR block exchange, per STRIDE_ASYNC_IMPLS
                           key (power-of-two device counts only)
  probe_gather_us          ``gather_global`` wall as a function of width

``run_probes`` bundles the results into a :class:`CostModel` and
``save_cost_model`` persists it under ``artifacts/bench/cost_model.json``,
keyed per (platform, device count, payload) so one cache file serves many
configurations. ``default_cost_model`` is the resolution every scheduling
decision goes through when no model is passed explicitly; precedence:

  explicit option  a CostModel handed to the resolver / runtime wins
  env              REPRO_PIPELINE_EXCHANGE_ROW_STEPS overrides the
                   exchange constant (source="env"; the PR-5 calibration
                   knob keeps working, and keeps beating cached probes so
                   a one-off experiment never has to delete the cache)
  cached probes    a matching entry in the cache file (REPRO_COST_MODEL
                   names the file; unset -> the default path; "off"
                   disables the cache entirely, which is what the test
                   suite pins so ambient calibrations cannot flip
                   analytic-expectation tests)
  analytic         the documented fallback: PIPELINE_EXCHANGE_ROW_STEPS,
                   no measured launch/gather costs, plans not rankable

Only a *measured* model can rank the STRIDE vs ALLGATHER plan choice
(``schedule.gathered_beats_strides``): the analytic model knows one ratio
(exchange/row-step), but plan ranking needs the absolute launch, gather
and stride walls, which no single constant encodes.

CLI (also the CI calibration step and the benchmarks' ``--calibrate``
subprocess target)::

    python -m repro.kernels.probes --smoke --devices 2 \
        --out artifacts/bench/cost_model.json

Heavy imports (jax, the transports) happen inside the probe functions, so
importing this module — which schedule.py does lazily on every default
resolution — costs nothing beyond the stdlib.
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Dict, Optional, Sequence, Tuple, Union

from repro.kernels import schedule as _schedule

#: Cache layout version; bump on any incompatible CostModel field change.
#: Loads fail LOUDLY on mismatch — a silently reinterpreted calibration is
#: worse than a crash (same philosophy as the env-var parse).
SCHEMA_VERSION = 1

#: REPRO_COST_MODEL: path of the calibration cache file; empty/unset ->
#: the default path below; one of _DISABLE_VALUES -> no cache (analytic
#: fallback unless the env constant is set).
COST_MODEL_ENV = "REPRO_COST_MODEL"

_DISABLE_VALUES = ("off", "0", "none", "disabled")

#: repo-root anchored, matching benchmarks.common.bench_path("cost_model.json")
DEFAULT_CACHE_PATH = (
    Path(__file__).resolve().parents[3] / "artifacts" / "bench"
    / "cost_model.json"
)

_AXIS = "shard"  # the bsp mesh axis name (repro.core.runtimes.bsp.AXIS)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """The costs the scheduling policy runs on, and where they came from.

    ``exchange_row_steps`` is the one number every covers/pays-off rule
    consumes (schedule.py's X); the remaining fields exist only on
    measured models and enable plan *ranking* on top of depth choice.
    All wall costs are microseconds.
    """

    source: str  # "analytic" | "env" | "measured"
    exchange_row_steps: float
    launch_us: Optional[float] = None
    row_step_us: Optional[float] = None
    halo_exchange_us: Dict[str, float] = dataclasses.field(default_factory=dict)
    stride_exchange_us: Dict[str, float] = dataclasses.field(default_factory=dict)
    gather_us: Dict[int, float] = dataclasses.field(default_factory=dict)
    #: impl -> devices -> width -> us: the devices-dimension gather probes
    #: behind schedule.choose_gather_impl (chunked-vs-monolithic is a
    #: function of D, not just W, so the flat gather_us curve cannot rank
    #: transports). Optional — absent on pre-PR-9 calibrations, which
    #: still load (same schema) and simply fall back to the structural
    #: gather rule.
    gather_impl_us: Dict[str, Dict[int, Dict[int, float]]] = (
        dataclasses.field(default_factory=dict))
    platform: str = ""
    devices: int = 0
    payload: int = 0

    # ------------------------------------------------------------ queries

    @property
    def is_measured(self) -> bool:
        return self.source == "measured"

    @property
    def can_rank_plans(self) -> bool:
        """Plan ranking needs absolute costs: launch, row-step and at
        least one measured gather width. (Stride cost is only needed when
        the graph actually has off-block strides; ``stride_us_for``
        returning None makes that case unrankable at the call site.)"""
        return (self.is_measured and self.launch_us is not None
                and self.row_step_us is not None and bool(self.gather_us))

    @staticmethod
    def _interp_width(curve: Dict[int, float],
                      width: int) -> Optional[float]:
        """Piecewise-linear over probed widths, clamp-extrapolated with
        the end slopes (collective walls are near-affine in bytes moved
        at these sizes). None on an empty curve."""
        if not curve:
            return None
        pts = sorted(curve.items())
        if len(pts) == 1 or width <= pts[0][0]:
            lo, hi = pts[0], pts[min(1, len(pts) - 1)]
        elif width >= pts[-1][0]:
            lo, hi = pts[-2], pts[-1]
        else:
            lo = max(p for p in pts if p[0] <= width)
            hi = min(p for p in pts if p[0] >= width)
        if lo[0] == hi[0]:
            return float(lo[1])
        slope = (hi[1] - lo[1]) / (hi[0] - lo[0])
        return float(max(0.0, lo[1] + slope * (width - lo[0])))

    def gather_us_at(self, width: int) -> Optional[float]:
        """Measured ``gather_global`` wall at ``width`` (default
        transport), interpolated per :meth:`_interp_width`. None when the
        model has no gather probes."""
        return self._interp_width(self.gather_us, width)

    def gather_walls_at(self, width: int,
                        devices: Optional[int] = None) -> Dict[str, float]:
        """Per-transport gather walls at (devices, width) from the
        devices-dimension probes: impl -> interpolated us, only for impls
        probed at exactly ``devices`` (a wall measured at D' devices says
        nothing about the rendezvous structure at D — the same
        exact-device-match rule ``_match_entry`` enforces for whole
        models). Empty when nothing was probed at that count."""
        d = int(devices) if devices is not None else self.devices
        out: Dict[str, float] = {}
        for impl, by_devices in self.gather_impl_us.items():
            us = self._interp_width(by_devices.get(d, {}), width)
            if us is not None:
                out[impl] = us
        return out

    def stride_us_for(self, impl: str = "xla") -> Optional[float]:
        """One XOR block-exchange wall for ``impl``, falling back to any
        probed transport (the relative plan verdict rarely hinges on the
        transport; missing entirely -> None, caller treats as unrankable)."""
        if impl in self.stride_exchange_us:
            return float(self.stride_exchange_us[impl])
        if self.stride_exchange_us:
            return float(min(self.stride_exchange_us.values()))
        return None

    def describe(self, width: Optional[int] = None) -> str:
        """The verdict source, for supports()/tuner-decline messages —
        a wrong auto-pick must be diagnosable from the error alone."""
        if self.source == "env":
            return (f"env override {_schedule._EXCHANGE_ROW_STEPS_ENV}="
                    f"{self.exchange_row_steps:g} row-steps")
        if not self.is_measured:
            return (f"analytic fallback "
                    f"(exchange={self.exchange_row_steps:g} row-steps)")
        parts = [f"measured on {self.platform} x{self.devices}"]
        costs = []
        if self.halo_exchange_us:
            costs.append(f"exchange={min(self.halo_exchange_us.values()):.1f}us")
        stride = self.stride_us_for()
        if stride is not None:
            costs.append(f"stride={stride:.1f}us")
        g = self.gather_us_at(width) if width else None
        if g is not None:
            costs.append(f"gather={g:.1f}us@w{width}")
        elif self.gather_us:
            w, us = sorted(self.gather_us.items())[-1]
            costs.append(f"gather={us:.1f}us@w{w}")
        if self.launch_us is not None:
            costs.append(f"launch={self.launch_us:.1f}us")
        if self.row_step_us is not None:
            costs.append(f"row-step={self.row_step_us:.3f}us")
        return (f"{parts[0]}: " + ", ".join(costs)
                + f" -> exchange={self.exchange_row_steps:g} row-steps")

    # -------------------------------------------------------------- codec

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # JSON object keys are strings; keep widths sorted for stable files
        d["gather_us"] = {str(k): v for k, v in sorted(self.gather_us.items())}
        d["gather_impl_us"] = {
            impl: {str(dd): {str(w): us for w, us in sorted(curve.items())}
                   for dd, curve in sorted(by_d.items())}
            for impl, by_d in sorted(self.gather_impl_us.items())}
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "CostModel":
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - known
        if extra:
            raise ValueError(f"unknown CostModel fields {sorted(extra)}")
        d = dict(d)
        d["gather_us"] = {int(k): float(v)
                          for k, v in d.get("gather_us", {}).items()}
        d["gather_impl_us"] = {
            str(impl): {int(dd): {int(w): float(us)
                                  for w, us in curve.items()}
                        for dd, curve in by_d.items()}
            for impl, by_d in d.get("gather_impl_us", {}).items()}
        return cls(**d)

    def cache_key(self) -> str:
        return f"{self.platform}|d{self.devices}|p{self.payload}"


def analytic_cost_model() -> CostModel:
    """The documented fallback: schedule.py's hand-calibrated constant,
    no absolute costs, plans not rankable."""
    return CostModel(source="analytic",
                     exchange_row_steps=float(
                         _schedule.PIPELINE_EXCHANGE_ROW_STEPS))


def _env_cost_model(raw: str) -> CostModel:
    """REPRO_PIPELINE_EXCHANGE_ROW_STEPS as a model; invalid values fail
    loudly (same contract as schedule.exchange_row_steps always had)."""
    value = int(raw)
    if value <= 0:
        raise ValueError(
            f"{_schedule._EXCHANGE_ROW_STEPS_ENV} must be a positive "
            f"integer, got {raw!r}")
    return CostModel(source="env", exchange_row_steps=float(value))


# --------------------------------------------------------------- cache file


def save_cost_model(model: CostModel, path=None) -> Path:
    """Merge one calibration into the cache file (other keys survive)."""
    path = Path(path) if path is not None else DEFAULT_CACHE_PATH
    entries: Dict[str, CostModel] = {}
    if path.exists():
        entries = load_cost_model(path)
    entries[model.cache_key()] = model
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {
        "schema": SCHEMA_VERSION,
        "entries": {k: m.to_dict() for k, m in sorted(entries.items())},
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path


def load_cost_model(path=None) -> Dict[str, CostModel]:
    """All cached calibrations, keyed "platform|dD|pP". Corrupt files and
    schema mismatches raise ValueError."""
    path = Path(path) if path is not None else DEFAULT_CACHE_PATH
    try:
        raw = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        raise ValueError(f"corrupt cost-model cache {path}: {e}") from None
    if not isinstance(raw, dict) or raw.get("schema") != SCHEMA_VERSION:
        raise ValueError(
            f"cost-model cache {path} has schema {raw.get('schema')!r}, "
            f"this build reads schema {SCHEMA_VERSION} — re-run "
            f"`python -m repro.kernels.probes` to recalibrate")
    try:
        return {k: CostModel.from_dict(v)
                for k, v in raw.get("entries", {}).items()}
    except (TypeError, ValueError) as e:
        raise ValueError(f"corrupt cost-model cache {path}: {e}") from None


def _match_entry(entries: Dict[str, CostModel], platform: str,
                 devices: Optional[int],
                 payload: Optional[int]) -> Optional[CostModel]:
    """Best cached calibration for the current context: platform must
    match exactly; device count must match when known (scheduling
    verdicts at D devices judged by a D'-device calibration would be
    exactly the cross-platform mistake this module exists to kill);
    payload picks the nearest probe (costs vary slowly in payload — the
    lane padding quantizes it anyway)."""
    pool = [m for m in entries.values() if m.platform == platform]
    if devices is not None:
        pool = [m for m in pool if m.devices == devices]
    if not pool:
        return None
    if payload is not None:
        pool.sort(key=lambda m: (abs(m.payload - payload), m.payload))
    else:
        pool.sort(key=lambda m: m.payload)
    return pool[0]


def _platform() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:  # pragma: no cover - jax is a hard dep in practice
        return "cpu"


_default_cache: Dict[tuple, CostModel] = {}


def default_cost_model(devices: Optional[int] = None,
                       payload: Optional[int] = None) -> CostModel:
    """The model scheduling decisions use when none is passed explicitly.

    Precedence (locked by tests/test_cost_model.py):
    env constant > cached probes > analytic fallback. The explicit-option
    tier above these lives at the call sites (a ``model=`` argument or
    the runtime's ``cost_model`` option short-circuits this function
    entirely). Re-reads the environment per call — a harness can flip the
    env between resolutions without reimports — but memoizes file loads
    per (path, mtime), so hot resolver loops don't re-parse JSON."""
    raw_env = os.environ.get(_schedule._EXCHANGE_ROW_STEPS_ENV)
    if raw_env:
        return _env_cost_model(raw_env)
    raw_path = os.environ.get(COST_MODEL_ENV)
    if raw_path and raw_path.strip().lower() in _DISABLE_VALUES:
        return analytic_cost_model()
    path = Path(raw_path) if raw_path else DEFAULT_CACHE_PATH
    if not path.exists():
        return analytic_cost_model()
    mtime = path.stat().st_mtime_ns
    key = (str(path), mtime, _platform(), devices, payload)
    if key not in _default_cache:
        entry = _match_entry(load_cost_model(path), _platform(),
                             devices, payload)
        _default_cache[key] = entry if entry is not None \
            else analytic_cost_model()
    return _default_cache[key]


def coerce_cost_model(value, devices: Optional[int] = None,
                      payload: Optional[int] = None) -> CostModel:
    """A runtime's ``cost_model`` option -> CostModel. Accepts a
    CostModel, a to_dict()-shaped dict, or a cache-file path; None means
    "no explicit choice" and falls through to ``default_cost_model``."""
    if value is None:
        return default_cost_model(devices=devices, payload=payload)
    if isinstance(value, CostModel):
        return value
    if isinstance(value, dict):
        return CostModel.from_dict(value)
    if isinstance(value, (str, os.PathLike)):
        entry = _match_entry(load_cost_model(Path(value)), _platform(),
                             devices, payload)
        if entry is None:
            raise ValueError(
                f"cost-model file {value} has no entry for platform "
                f"{_platform()!r} at {devices} devices")
        return entry
    raise TypeError(
        f"cost_model option must be a CostModel, dict, or path; "
        f"got {type(value).__name__}")


# ------------------------------------------------------------------- probes


def _time_best_us(fn, reps: int, warmup: int = 1) -> float:
    """Best-of-reps wall of ``fn()`` in microseconds (block_until_ready
    inside the timed region; best-of matches the runtimes' TimingStats)."""
    import time

    import jax

    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _time_median_us(fn, reps: int, warmup: int = 2) -> float:
    """Median-of-reps wall of ``fn()`` in microseconds.

    For per-dispatch collectives on an oversubscribed (forced-host) mesh
    the wall distribution is heavy-tailed by thread scheduling — a full
    D-participant barrier pays a convoy tax whenever the scheduler wakes
    its threads in an unlucky order. Best-of-reps erases exactly that
    tail, ranking transports by a best case no dispatch cadence ever
    pays repeatedly; the median is what a host-stepped launch loop pays
    per launch, so transport CHOICE probes use it."""
    import time

    import jax

    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn())
    walls = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        walls.append(time.perf_counter() - t0)
    walls.sort()
    n = len(walls)
    mid = n // 2
    med = walls[mid] if n % 2 else 0.5 * (walls[mid - 1] + walls[mid])
    return med * 1e6


def _step_call(width: int, payload: int):
    """A zero-arg thunk running ONE single-step window-mode launch of the
    fused step kernel over ``width`` rows (radius-1 three-point stencil:
    the same kernel + combine the halo plan times, so the launch and
    row-step probes price what the scheduler actually schedules)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops as _kops

    src = jnp.zeros((1, width + 2, payload), jnp.float32)
    idx = jnp.zeros((1, width, 1), jnp.int32)
    wgt = jnp.asarray(np.full((1, width, 3), 1.0 / 3.0, np.float32))
    kw = dict(kind="compute_bound", iterations=1, combine="window")
    return lambda: _kops.taskbench_step(src, idx, wgt, **kw)


def probe_launch_us(payload: int = 64, *, reps: int = 5) -> float:
    """Per-launch dispatch cost: a step launch over rows too few for the
    body to matter is ~all dispatch."""
    return _time_best_us(_step_call(8, payload), reps)


def probe_row_step_us(payload: int = 64, *,
                      widths: Sequence[int] = (64, 256, 512),
                      reps: int = 5) -> float:
    """Marginal cost of one working row advanced one depth: the
    least-squares slope of the single-step launch wall over ``widths``
    (the intercept absorbs the dispatch cost the launch probe measures;
    fitting >= 3 points keeps one noisy sample from flipping the sign).
    Floored well above zero — a zero/negative slope is measurement noise
    and would make the derived exchange ratio explode."""
    reps = max(reps, 3)  # the slope is a difference of near-equal walls
    ws = sorted(set(int(w) for w in widths))
    ts = [_time_best_us(_step_call(w, payload), reps) for w in ws]
    n = len(ws)
    mw, mt = sum(ws) / n, sum(ts) / n
    var = sum((w - mw) ** 2 for w in ws)
    slope = sum((w - mw) * (t - mt) for w, t in zip(ws, ts)) / var
    return max(1e-3, slope)


def _probe_mesh(devices: int):
    import jax
    import numpy as np
    from jax.sharding import Mesh

    avail = jax.devices()
    if devices > len(avail):
        raise ValueError(
            f"probe wants {devices} devices, jax sees {len(avail)} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count "
            f"before jax initializes, or run via the probes CLI)")
    return Mesh(np.array(avail[:devices]), (_AXIS,))


def _sharded_wall_us(local_fn, devices: int, rows_per_device: int,
                     payload: int, reps: int,
                     stat: str = "best",
                     replicated_out: bool = False) -> float:
    """Wall of one jitted shard_map'd ``local_fn(local) -> array`` over a
    (devices*rows, payload) f32 operand. ``stat`` picks the aggregation:
    "best" (floor probes) or "median" (transport-choice probes — see
    ``_time_median_us`` for why). ``replicated_out`` returns the local
    fn's result replicated (P(None)) instead of row-sharded — gather
    probes need it so the program's product IS the gathered buffer; a
    reduction-style consumption instead invites XLA to rewrite the
    gather+reduce into a cheaper collective and the probe stops
    measuring the transport it names."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    mesh = _probe_mesh(devices)
    out_specs = P(None) if replicated_out else P(_AXIS)
    fn = jax.jit(shard_map(local_fn, mesh=mesh, check_vma=False,
                           in_specs=P(_AXIS), out_specs=out_specs))
    arr = jnp.zeros((devices * rows_per_device, payload), jnp.float32)
    timer = _time_median_us if stat == "median" else _time_best_us
    return timer(lambda: fn(arr), reps)


def probe_halo_exchange_us(devices: int, payload: int = 64, *,
                           depth: int = 8,
                           reps: int = 5) -> Dict[str, float]:
    """One deep ring exchange per HALO_ASYNC_IMPLS transport. Rendezvous
    dominates at these sizes, so one depth stands in for all."""
    from repro.core.runtimes import _halo

    out: Dict[str, float] = {}
    block = max(2 * depth, 16)
    for impl in sorted(_halo.HALO_ASYNC_IMPLS):
        def local(x, impl=impl):
            h = _halo.exchange_edges_start(
                x[:depth], x[-depth:], devices, _AXIS, impl=impl)
            hl, hr = h.join()
            # consume both landing buffers so the collective can't be DCE'd
            return x + 0.0 * (hl.sum() + hr.sum())

        out[impl] = _sharded_wall_us(local, devices, block, payload, reps)
    return out


def probe_stride_exchange_us(devices: int, payload: int = 64, *,
                             block: int = 32,
                             reps: int = 5) -> Dict[str, float]:
    """One XOR block exchange (stride 1) per STRIDE_ASYNC_IMPLS transport.
    Skipped (empty dict) on non-power-of-two device counts and on a
    single device, mirroring the transport's own contract."""
    from repro.core.runtimes import _halo

    if devices < 2 or devices & (devices - 1):
        return {}
    out: Dict[str, float] = {}
    for impl in sorted(_halo.STRIDE_ASYNC_IMPLS):
        def local(x, impl=impl):
            h = _halo.exchange_stride_start(x, (1,), devices, _AXIS,
                                            impl=impl)
            (partner,) = h.join()
            return x + 0.0 * partner.sum()

        out[impl] = _sharded_wall_us(local, devices, block, payload, reps)
    return out


def probe_gather_us(devices: int, payload: int = 64, *,
                    widths: Sequence[int] = (64, 256, 512),
                    reps: int = 5) -> Dict[int, float]:
    """``gather_global`` wall per width (the all-gather plan's collective).
    Widths not divisible by the device count are skipped — the plan never
    runs them either."""
    from repro.core.runtimes import _halo

    out: Dict[int, float] = {}
    for width in sorted(set(int(w) for w in widths)):
        if width < devices or width % devices:
            continue

        def local(x):
            g = _halo.gather_global(x, devices, _AXIS)
            return x + 0.0 * g.sum()

        out[width] = _sharded_wall_us(local, devices, width // devices,
                                      payload, reps)
    return out


def _gather_probe_device_counts(devices: int) -> Tuple[int, ...]:
    """The devices-dimension grid: the calibration count and its /2, /4
    subdivisions when they divide it (subgroup meshes over a prefix of the
    same device set), all >= 2. One calibration run then serves the
    scaling sweep's smaller Ds without extra subprocesses."""
    counts = []
    for d in (devices, devices // 2, devices // 4):
        if d >= 2 and devices % d == 0 and d not in counts:
            counts.append(d)
    return tuple(counts)


def _chunk_group_candidates(devices: int) -> Tuple[int, ...]:
    """Proper divisors 1 < g < D — every grouping the chunked gather can
    actually run without degrading to the monolithic path."""
    return tuple(g for g in range(2, devices)
                 if devices % g == 0)


def probe_gather_impl_us(devices: int, payload: int = 64, *,
                         widths: Sequence[int] = (64, 256, 512),
                         impls: Sequence[str] = ("xla", "chunked"),
                         device_counts: Optional[Sequence[int]] = None,
                         reps: int = 25,
                         chunk_groups: Union[str, Sequence[int], None]
                         = "auto",
                         ) -> Dict[str, Dict[int, Dict[int, float]]]:
    """``gather_global`` wall per (transport, device count, width) — the
    devices-dimension behind ``schedule.choose_gather_impl``. Each sub
    count runs on a mesh over a prefix of the available devices; widths
    that don't divide a count are skipped for it, and impls that degrade
    to the monolithic path at a count (chunked with no usable segment
    split) are skipped there too so the table never ranks an impl against
    itself.

    ``chunk_groups`` adds grouping-anatomy rows for the chunked
    transport: each candidate G probes as a pseudo-impl key
    ``"chunked:g{G}"`` (forced via ``gather_global(chunk_group=G)``), the
    input behind ``schedule.choose_gather_chunk_group``'s measured tier.
    "auto" probes every proper divisor 1 < G < d of each count; an
    explicit sequence probes its members where they divide d; None skips
    grouping rows entirely. The colon keeps these keys out of the
    impl-choice ranking (choose_gather_impl filters them) while fitting
    the existing ``gather_impl_us`` cache schema unchanged.

    Walls are MEDIAN-of-reps, unlike the floor probes' best-of: the full
    D-participant barrier's wall is heavy-tailed by scheduler convoy
    effects on an oversubscribed mesh, and a transport choice paid on
    every host-stepped dispatch should be ranked by the typical wall,
    not a best case that erases exactly the tail the chunked gather's
    bounded rendezvous width avoids."""
    from repro.core.runtimes import _halo

    counts = tuple(device_counts) if device_counts is not None \
        else _gather_probe_device_counts(devices)
    out: Dict[str, Dict[int, Dict[int, float]]] = {}
    for impl in impls:
        if impl not in _halo.GATHER_IMPLS:
            raise ValueError(
                f"unknown gather impl {impl!r}; known "
                f"{sorted(_halo.GATHER_IMPLS)}")

    def _measure(key, impl, d, width, group=None):
        def local(x, impl=impl, d=d, group=group):
            # the program's output IS the gathered (W, P) buffer
            # (replicated_out) — what the allgather plan feeds
            # the kernel; see _sharded_wall_us for why a
            # reduction-style consumption would measure the
            # wrong collective
            return _halo.gather_global(x, d, _AXIS, impl=impl,
                                       chunk_group=group)

        us = _sharded_wall_us(local, d, width // d, payload, reps,
                              stat="median", replicated_out=True)
        out.setdefault(key, {}).setdefault(d, {})[width] = us

    for d in counts:
        for impl in impls:
            if impl == "chunked":
                g = _halo.gather_chunk_group(d)
                if g <= 1 or g >= d:
                    continue  # degrades to xla at this count
            for width in sorted(set(int(w) for w in widths)):
                if width < d or width % d:
                    continue
                _measure(impl, impl, d, width)
        if chunk_groups is None or "chunked" not in impls:
            continue
        groups = _chunk_group_candidates(d) if chunk_groups == "auto" \
            else tuple(g for g in chunk_groups
                       if 1 < int(g) < d and d % int(g) == 0)
        if len(groups) < 2:
            continue  # a single viable grouping is nothing to rank
        for g in groups:
            for width in sorted(set(int(w) for w in widths)):
                if width < d or width % d:
                    continue
                _measure(f"chunked:g{int(g)}", "chunked", d, width,
                         group=int(g))
    return out


def run_probes(devices: Optional[int] = None, payload: int = 64, *,
               reps: int = 5, smoke: bool = False) -> CostModel:
    """All probes -> one measured CostModel (not yet persisted).

    ``smoke`` shrinks reps and the width grids so a CI step finishes in
    seconds; the schema and the derivation are identical to a full run.
    """
    import jax

    if devices is None:
        devices = len(jax.devices())
    if smoke:
        reps = min(reps, 3)
        row_widths, gather_widths = (64, 256, 512), (64, 128)
    else:
        row_widths, gather_widths = (64, 256, 512), (64, 256, 512)
    launch = probe_launch_us(payload, reps=reps)
    row_step = probe_row_step_us(payload, widths=row_widths, reps=reps)
    halo = probe_halo_exchange_us(devices, payload, reps=reps)
    stride = probe_stride_exchange_us(devices, payload, reps=reps)
    gather = probe_gather_us(devices, payload, widths=gather_widths,
                             reps=reps)
    # Devices-dimension transport table (choose_gather_impl's input, plus
    # the "chunked:g{G}" grouping-anatomy rows choose_gather_chunk_group
    # ranks): smoke probes only the calibration count, full runs add the
    # /2, /4 subgroup counts so one calibration serves the scaling sweep.
    impl_counts = (devices,) if smoke else None
    # median-of-reps needs a real sample; don't let the floor probes'
    # small reps starve the transport-choice distribution
    impl_reps = max(reps, 5 if smoke else 25)
    gather_impl = probe_gather_impl_us(
        devices, payload, widths=gather_widths,
        device_counts=impl_counts, reps=impl_reps) if devices >= 2 else {}
    # The covers/pays-off unit: one exchange in row-steps, priced with the
    # DEFAULT transport ("xla") because that is what the pipelined
    # schedule runs unless ablated.
    exch = halo.get("xla", min(halo.values()) if halo else None)
    x = (exch / row_step) if exch else float(
        _schedule.PIPELINE_EXCHANGE_ROW_STEPS)
    return CostModel(
        source="measured",
        exchange_row_steps=float(max(1.0, x)),
        launch_us=float(launch),
        row_step_us=float(row_step),
        halo_exchange_us={k: float(v) for k, v in halo.items()},
        stride_exchange_us={k: float(v) for k, v in stride.items()},
        gather_us={k: float(v) for k, v in gather.items()},
        gather_impl_us={impl: {d: {w: float(us) for w, us in curve.items()}
                               for d, curve in by_d.items()}
                        for impl, by_d in gather_impl.items()},
        platform=_platform(),
        devices=int(devices),
        payload=int(payload),
    )


# ---------------------------------------------------------------------- CLI


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Calibrate and persist. MUST run before jax initializes when
    ``--devices`` exceeds the physical count (the CLI sets the host-device
    forcing flag itself; as a library call that is the caller's problem —
    benchmarks run this module in a subprocess for exactly that reason)."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--devices", type=int, default=0,
                    help="device count to calibrate for (0 = current)")
    ap.add_argument("--payload", type=int, default=64)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grids/reps (CI calibration step)")
    ap.add_argument("--out", default=str(DEFAULT_CACHE_PATH),
                    help="cache file to merge into ('-' = don't persist)")
    ap.add_argument("--json", action="store_true",
                    help="print the model as JSON on stdout")
    args = ap.parse_args(argv)

    if args.devices > 1:
        # Must land before the first jax.devices() call (backend init);
        # merely having imported jax is fine. If some earlier code already
        # initialized a too-small backend, _probe_mesh fails loudly.
        import re

        flags = os.environ.get("XLA_FLAGS", "")
        m = re.search(r"--xla_force_host_platform_device_count=(\d+)",
                      flags)
        if m is None:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
        elif int(m.group(1)) < args.devices:
            # An ambient pin SMALLER than the calibration target used to
            # survive the substring check above, so the CLI promised
            # --devices N while run_probes saw the ambient count and
            # _probe_mesh failed with a mismatch naming neither side.
            # The backend is not initialized yet in this process, so the
            # flag can simply be rewritten to what the CLI was asked for.
            os.environ["XLA_FLAGS"] = flags.replace(
                m.group(0),
                f"--xla_force_host_platform_device_count={args.devices}")
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    model = run_probes(devices=args.devices or None, payload=args.payload,
                       reps=args.reps, smoke=args.smoke)
    if args.out != "-":
        path = save_cost_model(model, args.out)
        print(f"cost model [{model.cache_key()}] -> {path}")
    print(model.describe())
    if args.json:
        print(json.dumps(model.to_dict(), indent=2, sort_keys=True))
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised via subprocess
    raise SystemExit(main())
