"""Gradient compression for the slow inter-pod hop (distributed-opt trick).

Scheme: hierarchical reduction — gradients reduce-scatter/all-reduce in-pod
over the fast ICI ("data" axis) in bf16, then the *inter-pod* exchange is
int8 with per-tensor scale, stochastic rounding, and error feedback (the
quantization residual is carried to the next step, Seide et al. 1-bit SGD /
Dettmers 8-bit). The DCI hop carries 4x fewer bytes than an f32 all-reduce.

All pieces are pure functions + one shard_map'd collective, tested
numerically on virtual meshes (tests/test_distributed.py): with error
feedback the compressed path's cumulative bias vanishes.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 with stochastic rounding."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    y = x / scale
    lo = jnp.floor(y)
    frac = y - lo
    rnd = jax.random.uniform(key, x.shape)
    q = lo + (rnd < frac).astype(y.dtype)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(
    g: jax.Array, ef: jax.Array, key: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q, scale, new_ef): quantize (g + ef); ef' = input - dequant."""
    target = g.astype(jnp.float32) + ef
    q, scale = quantize_int8(target, key)
    new_ef = target - dequantize_int8(q, scale)
    return q, scale, new_ef


def cross_pod_mean_int8(
    g: jax.Array, ef: jax.Array, key: jax.Array, axis: str = "pod"
) -> Tuple[jax.Array, jax.Array]:
    """Inside shard_map: mean of g across `axis` with int8 transport + EF.

    The int8 payload is all-gathered over the (small, e.g. 2-way) pod axis
    and summed after dequantization — int8 summation would overflow and ring
    all-reduce cannot re-quantize per hop without compounding error.
    """
    q, scale, new_ef = compress_with_feedback(g, ef, key)
    qs = jax.lax.all_gather(q, axis)  # (npod, ...) int8 — the DCI payload
    ss = jax.lax.all_gather(scale, axis)  # (npod,) f32
    total = jnp.tensordot(ss, qs.astype(jnp.float32), axes=((0,), (0,)))
    npod = qs.shape[0]
    return (total / npod).astype(g.dtype), new_ef


def compression_ratio(g_dtype=jnp.bfloat16) -> float:
    return jnp.dtype(g_dtype).itemsize / jnp.dtype(jnp.int8).itemsize
