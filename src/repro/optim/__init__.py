"""optim substrate."""
