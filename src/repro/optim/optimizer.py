"""AdamW + cosine schedule + global-norm clipping, ZeRO-1-shardable states.

Hand-rolled (no optax in this container). Moments are stored in f32
regardless of param dtype; when a ShardingPolicy is supplied, moment trees
get the param specs PLUS data-axis sharding on the leading dim where it
divides (ZeRO-1: optimizer state sharded over the DP axes, params gathered
for compute as usual).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # ()
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - cfg.warmup_steps)
            / max(cfg.total_steps - cfg.warmup_steps, 1),
            0.0, 1.0,
        )
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)

    return lr


class AdamW:
    def __init__(self, cfg: AdamWConfig = AdamWConfig()):
        self.cfg = cfg
        self.schedule = cosine_schedule(cfg)

    def init(self, params: Any) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(self, grads: Any, state: AdamWState, params: Any
               ) -> Tuple[Any, AdamWState, dict]:
        cfg = self.cfg
        b1, b2 = cfg.betas

        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads))
        )
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        step = state.step + 1
        lr = self.schedule(step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + cfg.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.m)
        flat_v = tdef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, AdamWState(step, new_m, new_v), metrics

    # --------------------------------------------------------- sharding

    def state_shardings(self, policy, params: Any):
        """ZeRO-1: moments take the param spec, with the leading dim
        additionally sharded over the DP axes when divisible."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        specs = policy.param_specs(params)
        dp = policy.rules.resolve("batch")
        mesh = policy.mesh
        import math as _m

        dp_size = (_m.prod(mesh.shape[a] for a in dp)
                   if isinstance(dp, tuple) else mesh.shape[dp]) if dp else 1

        dp_axes = set()
        if dp:
            dp_axes = {dp} if isinstance(dp, str) else set(dp)

        def zero1(p, spec):
            parts = list(spec) + [None] * (p.ndim - len(spec))
            # a mesh axis may appear once per spec: if FSDP already put the
            # DP axes on some dim (e.g. MoE expert weights), skip ZeRO-1's
            # extra sharding for this leaf
            used = set()
            for cur in parts:
                if cur is not None:
                    used |= {cur} if isinstance(cur, str) else set(cur)
            if not (used & dp_axes):
                for i, (dim, cur) in enumerate(zip(p.shape, parts)):
                    if cur is None and dp and dim % dp_size == 0:
                        parts[i] = dp
                        break
            while parts and parts[-1] is None:
                parts.pop()
            return NamedSharding(mesh, P(*parts))

        m_sh = jax.tree.map(zero1, params, specs)
        return AdamWState(
            step=NamedSharding(mesh, P()), m=m_sh, v=m_sh
        )
