import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this entrypoint:
  1. builds the production mesh (single-pod 16x16 or multi-pod 2x16x16 over
     512 forced host devices),
  2. constructs the cell's step function (train_step / prefill_step /
     serve_step) under the cell's ShardingPolicy,
  3. ``jax.jit(step, in_shardings=..., out_shardings=...).lower(**specs)``
     with ShapeDtypeStruct inputs (no allocation),
  4. ``.compile()`` — success proves the sharding config is coherent (no
     mismatched collectives, fits per-device HBM at compile time),
  5. records memory_analysis(), cost_analysis(), and the collective-traffic
     census (hlo_analysis.py) as one JSON artifact per cell under
     ``artifacts/dryrun/``.

EXPERIMENTS.md §Dry-run / §Roofline are assembled from these artifacts by
benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--jobs N]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import ARCHS, cells, get_config, get_shape
from repro.distributed.api import sharding_context
from repro.distributed.sharding import ShardingPolicy
from repro.launch import steps as steps_lib
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    make_production_mesh,
)
from repro.models.model import Model
from repro.optim.optimizer import AdamW

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

#: Gradient-accumulation (microbatch) factors for train_4k, chosen so peak
#: per-chip memory fits 16 GiB (global batch 256 stays identical — grads
#: are averaged before the single optimizer update). A production launcher
#: would pick these from the same dry-run memory_analysis loop.
ACCUM = {
    "llama-3.2-vision-90b": 16,
    "granite-moe-3b-a800m": 16,
    "mixtral-8x7b": 16,
    "minitron-8b": 16,
    "gemma3-4b": 4,
    "hymba-1.5b": 4,
    "musicgen-medium": 2,
    "stablelm-3b": 2,
    "internlm2-1.8b": 2,
}


def _artifact_path(arch: str, shape: str, mesh_tag: str) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    return os.path.join(ARTIFACT_DIR, f"{arch}__{shape}__{mesh_tag}.json")


# --------------------------------------------------------------------------


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               policy: Optional[ShardingPolicy] = None):
    """Returns (jitted_step, kwargs-of-ShapeDtypeStructs) for the cell.

    use_flash=False for the compile of record: Mosaic cannot lower on this
    CPU container, and interpret-mode Pallas lowers to a grid-sized while
    loop whose HLO misrepresents the kernel's cost by orders of magnitude.
    The jnp implementations (chunked flash attention, chunked SSD) have the
    same FLOPs/bytes shape as the fused kernels; tests pin their numerical
    equivalence (DESIGN.md §8).

    Serving cells (prefill/decode) store params in bf16 — f32 masters are a
    training-only artifact, and they dominated decode HBM at baseline.
    Train cells use per-arch gradient accumulation (ACCUM) to fit
    activations in 16 GiB/chip (§Perf #5).
    """
    cfg = dataclasses.replace(cfg, use_flash=False)
    if shape.kind in ("prefill", "decode"):
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    if shape.kind == "decode":
        cfg = dataclasses.replace(cfg, kv_quant=True)  # §Perf #6
    model = Model(cfg)
    if policy is None:
        policy = ShardingPolicy.for_step(cfg, shape, mesh)
    specs = steps_lib.input_specs(cfg, shape)

    abstract_params = steps_lib.abstract_params(cfg)
    p_shardings = policy.param_shardings(abstract_params)

    if shape.kind == "train":
        opt = AdamW()
        abstract_opt = jax.eval_shape(opt.init, abstract_params)
        opt_shardings = opt.state_shardings(policy, abstract_params)
        # microbatch must stay divisible by the DP shard count, or the
        # batch dim replicates (divisibility guard) and activations blow up
        import math as _m

        dp = policy.rules.resolve("batch")
        dp_size = (mesh.shape[dp] if isinstance(dp, str)
                   else _m.prod(mesh.shape[a] for a in dp)) if dp else 1
        accum = min(ACCUM.get(cfg.name, 1),
                    max(shape.global_batch // dp_size, 1))
        step = steps_lib.make_train_step(
            model, opt, accum=accum,
            grad_shardings=opt_shardings.m if accum > 1 else None)

        def wrapped(params, opt_state, batch):
            with sharding_context(mesh, policy.rules):
                return step(params, opt_state, batch)

        jitted = jax.jit(
            wrapped,
            in_shardings=(p_shardings, opt_shardings,
                          policy.batch_shardings(specs["batch"])),
            out_shardings=(p_shardings, opt_shardings, None),
            donate_argnums=(0, 1),
        )
        args = (abstract_params, abstract_opt, specs["batch"])
        return jitted, args, policy

    if shape.kind == "prefill":
        step = steps_lib.make_prefill_step(model)

        def wrapped(params, batch):
            with sharding_context(mesh, policy.rules):
                return step(params, batch)

        jitted = jax.jit(
            wrapped,
            in_shardings=(p_shardings, policy.batch_shardings(specs["batch"])),
        )
        args = (abstract_params, specs["batch"])
        return jitted, args, policy

    # decode
    step = steps_lib.make_serve_step(model)
    cache_shardings = policy.cache_shardings(specs["caches"])

    def wrapped(params, batch, lengths, caches):
        with sharding_context(mesh, policy.rules):
            return step(params, batch, lengths, caches)

    jitted = jax.jit(
        wrapped,
        in_shardings=(p_shardings, policy.batch_shardings(specs["batch"]),
                      policy.replicated(), cache_shardings),
        out_shardings=(None, cache_shardings),
        donate_argnums=(3,),
    )
    args = (abstract_params, specs["batch"], specs["lengths"], specs["caches"])
    return jitted, args, policy


# --------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             save: bool = True, verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"

    runnable = shape.name != "long_500k" or cfg.supports_long_context
    if not runnable:
        result = {
            "arch": arch, "shape": shape_name, "mesh": mesh_tag,
            "status": "skip",
            "reason": "pure full-attention arch x long-context decode "
                      "(DESIGN.md §Arch-applicability)",
        }
        if save:
            with open(_artifact_path(arch, shape_name, mesh_tag), "w") as f:
                json.dump(result, f, indent=2)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.perf_counter()
    jitted, args, policy = build_cell(cfg, shape, mesh)
    lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # post-SPMD per-device program census (trip-adjusted; XLA's own
    # cost_analysis counts while bodies once — see hlo_analysis.py)
    census = analyze_hlo(compiled.as_text())

    flops_per_dev = census.flops
    bytes_per_dev = census.hbm_bytes
    model_flops = steps_lib.step_flops_estimate(cfg, shape)

    # roofline terms (seconds) — per-device critical path
    compute_s = flops_per_dev / PEAK_FLOPS_BF16
    memory_s = bytes_per_dev / HBM_BW
    collective_s = census.collective_wire_bytes / ICI_BW

    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)),
        key=lambda kv: kv[1],
    )[0]

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_tag,
        "status": "ok",
        "chips": n_chips,
        "step_kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0)
            + (getattr(mem, "output_size_in_bytes", 0) or 0),
        },
        "cost": {
            "flops_per_device": flops_per_dev,
            "dot_flops_per_device": census.dot_flops,
            "bytes_per_device": bytes_per_dev,
            "xla_cost_flops_unadjusted": float(cost.get("flops", 0.0)),
            "xla_cost_bytes_unadjusted": float(
                cost.get("bytes accessed", 0.0)
            ),
        },
        "collectives": {
            "wire_bytes_by_kind": census.collective_bytes_by_kind,
            "wire_bytes_by_group": census.collective_bytes_by_group,
            "wire_bytes_per_device": census.collective_wire_bytes,
            "op_counts": census.collective_ops_by_kind,
        },
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": dominant,
            "model_flops": model_flops,
            "hlo_flops_global": flops_per_dev * n_chips,
            "useful_flops_ratio": (
                model_flops / (flops_per_dev * n_chips)
                if flops_per_dev else None
            ),
        },
        "fsdp": policy.fsdp,
    }
    if save:
        with open(_artifact_path(arch, shape_name, mesh_tag), "w") as f:
            json.dump(result, f, indent=2)
    if verbose:
        r = result["roofline"]
        print(
            f"[ok] {arch:24s} {shape_name:12s} {mesh_tag:10s} "
            f"compile {t_compile:6.1f}s  "
            f"C/M/X = {r['compute_s']*1e3:8.2f} / {r['memory_s']*1e3:8.2f} / "
            f"{r['collective_s']*1e3:8.2f} ms  dom={r['dominant']:10s} "
            f"useful={r['useful_flops_ratio'] and round(r['useful_flops_ratio'],3)}",
            flush=True,
        )
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 multi-pod mesh (default: 16x16 single pod)")
    ap.add_argument("--no-save", action="store_true")
    args = ap.parse_args(argv)

    if args.all:
        failures = []
        for cfg, shape, runnable in cells():
            try:
                run_cell(cfg.name, shape.name, multi_pod=args.multi_pod,
                         save=not args.no_save)
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                failures.append((cfg.name, shape.name, repr(e)))
                traceback.print_exc()
                print(f"[FAIL] {cfg.name} {shape.name}: {e}", flush=True)
        if failures:
            print(f"\n{len(failures)} cells failed:")
            for a, s, e in failures:
                print(f"  {a} x {s}: {e}")
            return 1
        print("\nall cells compiled.")
        return 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   save=not args.no_save)
    print(json.dumps(res, indent=2))
    return 0 if res["status"] in ("ok", "skip") else 1


if __name__ == "__main__":
    sys.exit(main())
