"""Step builders + abstract input specs shared by dryrun/train/serve.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input of the cell — weak-type-correct, shardable, no device allocation — and
`abstract_state` does the same for params/optimizer/caches via eval_shape.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import Model
from repro.optim.optimizer import AdamW


def _act_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                batch_override: Optional[int] = None,
                seq_override: Optional[int] = None) -> Dict[str, Any]:
    """Abstract batch for the cell's step function."""
    B = batch_override or shape.global_batch
    S = seq_override or shape.seq_len
    dt = _act_dtype(cfg)
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch: Dict[str, Any] = {
            "tokens": sds((B, S), jnp.int32),
            "labels": sds((B, S), jnp.int32),
        }
        if cfg.embed_inputs:
            batch["embeds"] = sds((B, S, cfg.d_model), dt)
        if cfg.n_image_tokens:
            batch["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model), dt)
        return {"batch": batch}
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S), jnp.int32)}
        if cfg.embed_inputs:
            batch["embeds"] = sds((B, S, cfg.d_model), dt)
        if cfg.n_image_tokens:
            batch["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model), dt)
        return {"batch": batch}
    # decode: one new token against a cache of length S
    batch = {"tokens": sds((B, 1), jnp.int32)}
    if cfg.embed_inputs:
        batch["embeds"] = sds((B, 1, cfg.d_model), dt)
    return {
        "batch": batch,
        "lengths": sds((B,), jnp.int32),
        "caches": abstract_caches(cfg, B, S),
    }


def abstract_params(cfg: ModelConfig) -> Any:
    model = Model(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def abstract_caches(cfg: ModelConfig, batch: int, capacity: int) -> Any:
    model = Model(cfg)
    return jax.eval_shape(lambda: model.init_caches(batch, capacity))


def abstract_opt_state(cfg: ModelConfig) -> Any:
    model = Model(cfg)
    opt = AdamW()
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return jax.eval_shape(opt.init, params)


# ------------------------------------------------------------------ steps


def make_train_step(model: Model, optimizer: AdamW, accum: int = 1,
                    grad_shardings: Optional[Any] = None):
    """One optimizer step; with accum > 1 the global batch is split into
    `accum` microbatches scanned with gradient accumulation — peak
    activation memory scales 1/accum while the maths are identical (grads
    averaged before the single optimizer update). This is how the >8B
    train cells fit 16 GiB/chip (EXPERIMENTS.md §Perf #5).

    grad_shardings (a pytree of NamedShardings, typically the ZeRO-1
    moment shardings): constrains the accumulated-grad scan carry to a
    DP-sharded layout so each microbatch's weight-grad reduction lowers as
    reduce-scatter (half the all-reduce wire) into the shard this device
    owns, with one gather at the optimizer update (§Perf #5b)."""

    def _constrain_grads(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            g, grad_shardings)

    def train_step(params, opt_state, batch):
        if accum <= 1:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                l, g = carry
                li, gi = jax.value_and_grad(model.loss)(params, mb)
                g = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g,
                                 _constrain_grads(gi))
                return (l + li, _constrain_grads(g)), None

            zero = (jnp.zeros((), jnp.float32),
                    _constrain_grads(jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)))
            (loss, grads), _ = jax.lax.scan(body, zero, mbs)
            loss = loss / accum
            grads = jax.tree.map(lambda g: g / accum, grads)
        params, opt_state, metrics = optimizer.update(grads, opt_state, params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        logits, caches = model.prefill(params, batch)
        return logits, caches

    return prefill_step


def make_serve_step(model: Model):
    """One decode step: append token, read cache, emit next token greedily."""

    def serve_step(params, batch, lengths, caches):
        logits, caches = model.decode_step(params, batch, lengths, caches)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, caches

    return serve_step


def step_flops_estimate(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS for the roofline: 6ND train, 2ND prefill, 2N_active x B
    decode (N = params, N_active = params with only top-k experts counted)."""
    n = cfg.param_count()
    if cfg.n_experts:
        ff = cfg.d_ff_expert or cfg.d_ff
        expert_params = cfg.n_experts * 3 * cfg.d_model * ff * cfg.n_layers
        active = n - expert_params + expert_params * cfg.top_k / cfg.n_experts
    else:
        active = n
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch  # decode: one token per seq
