"""Batched serving loop: prefill a prompt batch, decode tokens step by step.

The serving path exercises the inference-side features the dry-run proves at
scale: KV caches (attention), O(1) SSM decode state, flash-decode kernels,
and (on multi-device meshes) the sequence-parallel cache read with
lse-combine. The OverheadProfiler reports per-token dispatch overhead — the
serving analogue of the paper's per-task overhead measurement, where a
"task" is one decode step of one sequence.

Usage (reduced, CPU):
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --reduced --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.registry import ARCHS, get_config
from repro.core.instrumentation import OverheadProfiler
from repro.distributed.api import sharding_context
from repro.distributed.sharding import ShardingPolicy
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray  # (B, gen)
    prefill_s: float
    decode_s: float
    tokens_per_s: float
    report: Optional[Any]
    #: decode steps whose wall blew the self-calibrated deadline
    #: (repro.resilience.DeadlineDetector): [{step, wall_us, deadline_us,
    #: overshoot_us}] — a stalled step is REPORTED, never silently absorbed
    flagged_steps: List[dict] = dataclasses.field(default_factory=list)
    #: decode steps whose logits carried NaN/Inf (poisoned output)
    poisoned_steps: List[int] = dataclasses.field(default_factory=list)

    @property
    def healthy(self) -> bool:
        return not self.flagged_steps and not self.poisoned_steps


def serve(
    cfg: ModelConfig,
    *,
    batch: int,
    prompt_len: int,
    gen: int,
    mesh=None,
    seed: int = 0,
    greedy: bool = True,
    temperature: float = 1.0,
    verbose: bool = True,
    deadline_factor: Optional[float] = None,
) -> ServeResult:
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    capacity = prompt_len + gen

    rules = None
    if mesh is not None:
        from repro.configs.base import ShapeConfig

        shape = ShapeConfig("serve", capacity, batch, "decode")
        policy = ShardingPolicy.for_step(cfg, shape, mesh)
        rules = policy.rules
        params = jax.device_put(params, policy.param_shardings(params))

    key = jax.random.PRNGKey(seed + 1)
    prompts = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab,
                                 jnp.int32)

    def _ctx():
        return sharding_context(mesh, rules) if mesh is not None else \
            sharding_context(None, None)

    # ---- prefill ----------------------------------------------------------
    @jax.jit
    def prefill(params, prompts, embeds=None):
        with _ctx():
            b = {"tokens": prompts}
            if cfg.embed_inputs:
                b = {"embeds": embeds}
            if cfg.n_image_tokens:
                b["image_embeds"] = jnp.zeros(
                    (prompts.shape[0], cfg.n_image_tokens, cfg.d_model),
                    jnp.float32)
            logits, caches = model.prefill(params, b)
            return logits, caches

    embeds = (0.02 * jax.random.normal(
        key, (batch, prompt_len, cfg.d_model)) if cfg.embed_inputs else None)
    t0 = time.perf_counter()
    logits, caches = prefill(params, prompts, embeds)
    # prefill caches hold exactly prompt_len entries; grow to capacity
    caches = jax.block_until_ready(caches)
    prefill_s = time.perf_counter() - t0

    caches = _grow_caches(model, caches, batch, capacity)

    # ---- decode loop ------------------------------------------------------
    @jax.jit
    def decode(params, tok, lengths, caches, key):
        with _ctx():
            b = {"tokens": tok}
            if cfg.embed_inputs:
                b = {"embeds": 0.02 * jax.random.normal(
                    key, (tok.shape[0], 1, cfg.d_model))}
            lg, caches = model.decode_step(params, b, lengths, caches)
            # one fused scalar: argmax of poisoned logits still yields a
            # legal token id, so health must be read off the logits
            bad = ~jnp.isfinite(lg).all()
            if greedy:
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            else:
                nxt = jax.random.categorical(key, lg / temperature, axis=-1
                                             ).astype(jnp.int32)
            return nxt[:, None], caches, bad

    profiler = OverheadProfiler(
        devices=mesh.size if mesh is not None else 1,
        tasks_per_step=batch,  # one "task" = one sequence's token step
        tokens_per_step=batch,  # each decode step emits one token per seq
    )
    # deadline detector around each decode step: no cost model prices a
    # decode step, so it self-calibrates from the run's own clean walls.
    # Step 0 carries the compile — a recompile boundary, so its wall is
    # excluded from the calibration median outright (merely being inside
    # the warmup window would still seed the median with a compile wall).
    from repro.resilience import DEFAULT_DEADLINE_FACTOR, DeadlineDetector

    detector = DeadlineDetector(
        factor=deadline_factor or DEFAULT_DEADLINE_FACTOR)
    detector.note_recompile_boundary()
    flagged: List[dict] = []
    poisoned: List[int] = []
    lengths = jnp.full((batch,), prompt_len, jnp.int32)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out: List[np.ndarray] = [np.asarray(tok)]
    t0 = time.perf_counter()
    for i in range(gen - 1):
        key, sub = jax.random.split(key)
        t1 = time.perf_counter()
        tok, caches, bad = decode(params, tok, lengths, caches, sub)
        tok = jax.block_until_ready(tok)
        wall = time.perf_counter() - t1
        profiler.record(wall)
        det = detector.observe(wall * 1e6)
        if det is not None:
            flagged.append({"step": i, "wall_us": det.wall_us,
                            "deadline_us": det.deadline_us,
                            "overshoot_us": det.overshoot_us})
            profiler.flagged.append(i)
        if bool(bad):
            poisoned.append(i)
            profiler.poisoned.append(i)
        lengths = lengths + 1
        out.append(np.asarray(tok))
    decode_s = time.perf_counter() - t0
    tokens = np.concatenate(out, axis=1)

    report = profiler.report() if profiler.records else None
    if verbose:
        # the report's tokens_per_s is steady-state (warmup step dropped);
        # this one includes it, matching the returned decode_s
        tps = batch * (gen - 1) / decode_s if decode_s > 0 else 0.0
        print(f"prefill: {prefill_s*1e3:.1f} ms for {batch}x{prompt_len} "
              f"({batch*prompt_len/max(prefill_s,1e-9):.0f} tok/s)")
        print(f"decode : {decode_s*1e3:.1f} ms for {batch}x{gen-1} "
              f"({tps:.0f} tok/s)")
        if report:
            print("\n-- per-token overhead (paper methodology, §3) --")
            for line in report.lines():
                print("  " + line)
        for f in flagged:
            print(f"WARNING: decode step {f['step']} blew its deadline: "
                  f"{f['wall_us']:.0f}us > {f['deadline_us']:.0f}us")
        for i in poisoned:
            print(f"WARNING: decode step {i} produced non-finite logits")
    return ServeResult(
        tokens=tokens,
        prefill_s=prefill_s,
        decode_s=decode_s,
        tokens_per_s=batch * (gen - 1) / decode_s if decode_s > 0 else 0.0,
        report=report,
        flagged_steps=flagged,
        poisoned_steps=poisoned,
    )


def _grow_caches(model: Model, caches, batch: int, capacity: int):
    """Copy prefill caches (length = prompt_len) into capacity-sized buffers.

    Attention K/V grow along the sequence dim; SSM conv/ssd states are O(1)
    and pass through; cross-attn image caches are fixed-size too.
    """
    full = model.init_caches(batch, capacity)

    def leaf(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        # attention k/v: (reps, B, Hkv, S, hd) — prefix-copy along dim 3
        pads = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src.astype(dst.dtype), pads)

    return jax.tree.map(leaf, full, caches)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sample", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. '4:model'")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = None
    if args.mesh:
        dims, axes = args.mesh.split(":")
        mesh = make_host_mesh([int(d) for d in dims.split(",")],
                              axes.split(","))
    res = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen, mesh=mesh, greedy=not args.sample)
    print(f"\ngenerated tokens (first 2 rows): {res.tokens[:2].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
