"""Post-SPMD HLO census for the roofline: FLOPs, HBM bytes, collective bytes.

Why not ``compiled.cost_analysis()``? Verified on this container (see
EXPERIMENTS.md §Dry-run): XLA's cost analysis counts a ``while`` body ONCE,
not x trip-count — a 10-step scan of matmuls reports 1/10th of the FLOPs
actually executed. Since every model here scans over layers, we walk the HLO
text ourselves:

  * computations are parsed with a per-computation symbol table
    (result name -> shape) so operand shapes of ``dot``/collective ops
    resolve even though call sites print bare ``%name`` refs;
  * ``while`` bodies are multiplied by the trip count from the op's
    ``backend_config={"known_trip_count":{"n":...}}`` (fallback: the largest
    integer constant in the condition computation);
  * ``fusion``/``call``/``to_apply`` recurse into their callees for FLOPs;
    ``conditional`` takes the max over branches (one branch executes);
  * FLOPs: 2 x numel(result) x prod(lhs contracting dims) per ``dot``, plus
    numel(result) per elementwise arithmetic/transcendental op (VPU work —
    matters for the SSM/taskbench bodies);
  * HBM bytes: operand + result bytes of every *top-level* op per
    computation except free ops (parameter/tuple/gte/bitcast/constant) and
    control ops (their bodies are counted separately) — post-optimization
    top-level ops are fusions/dots/copies/collectives, so this approximates
    HBM traffic per device;
  * collective wire bytes per device use ring models on the operand size
    ``b`` with group size ``g``:
      all-reduce 2b(g-1)/g | all-gather (g-1)/g x result | reduce-scatter
      b(g-1)/g | all-to-all b(g-1)/g | collective-permute b
    async ``-start``/``-done`` pairs are counted once (on the start).

Byte counts are PER DEVICE (the SPMD module is the per-device program).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")

# elementwise ops counted as 1 FLOP/element (VPU estimate)
_EW_OPS = frozenset(
    "add subtract multiply divide maximum minimum abs negate compare select "
    "and or xor not exponential exponential-minus-one log log-plus-one rsqrt "
    "sqrt tanh logistic sine cosine power remainder atan2 sign floor ceil "
    "round-nearest-afz round-nearest-even clamp".split()
)
_FREE_OPS = frozenset(
    "parameter tuple get-tuple-element bitcast constant iota "
    "after-all partition-id replica-id".split()
)
_CONTROL_OPS = frozenset("while conditional call fusion async-start".split())

_SHAPE_TOK = re.compile(
    r"(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([\d,]*)\]"
)
# "%name = TYPE opcode(" — TYPE is a tuple "(...)" or a single token
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"(\((?:[^()]|\([^()]*\))*\)|[^\s(]+)\s+"
    r"([\w\-]+)(?:-start|-done)?\("
)
_OP_LINE_FULL = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"(\((?:[^()]|\([^()]*\))*\)|[^\s(]+)\s+"
    r"([\w\-]+)\("
)
_OPERANDS = re.compile(r"%([\w.\-]+)")
_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_HDR_PARAM = re.compile(r"([\w.\-]+):\s*(\((?:[^()]|\([^()]*\))*\)|[^\s,]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_INT = re.compile(r"constant\((\d+)\)")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BDIMS = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_REPL_GROUPS_ARR = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_REPL_GROUPS_LIST = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CALLED = re.compile(
    r"(?:calls|to_apply|body|condition)=\{?%?([\w.\-]+)"
)
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def _shape_bytes(type_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOK.findall(type_text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_numel(type_text: str) -> int:
    total = 0
    for _, dims in _SHAPE_TOK.findall(type_text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n
    return total


def _first_shape_dims(type_text: str) -> List[int]:
    m = _SHAPE_TOK.search(type_text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    symbols: Dict[str, str]  # %name -> type text


@dataclasses.dataclass
class HloCensus:
    flops: float  # trip-adjusted, per device
    dot_flops: float
    hbm_bytes: float  # trip-adjusted top-level operand+result bytes
    collective_wire_bytes: float  # ring-model bytes on the wire per device
    collective_bytes_by_kind: Dict[str, float]
    collective_ops_by_kind: Dict[str, int]  # static counts
    collective_bytes_by_group: Dict[str, float]  # "kind@g<size>" -> bytes

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes_by_kind.values())


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[str] = None
    header_line = ""
    lines: List[str] = []
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{"):
                m = _HEADER.match(line.strip())
                if m:
                    cur = m.group(1)
                    header_line = line
                    lines = []
        else:
            if line.strip() == "}":
                comp = Computation(cur, lines, {})
                # symbol table: results + header params
                hm = _HEADER.match(header_line.strip())
                if hm:
                    for pname, ptype in _HDR_PARAM.findall(hm.group(2)):
                        comp.symbols[pname] = ptype
                for ln in lines:
                    om = _OP_LINE_FULL.match(ln)
                    if om:
                        comp.symbols[om.group(1)] = om.group(2)
                comps[cur] = comp
                cur = None
            else:
                lines.append(line)
    return comps


def _entry_name(hlo: str) -> Optional[str]:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    return m.group(1) if m else None


def _group_size(line: str, default: int = 1) -> int:
    m = _REPL_GROUPS_ARR.search(line)
    if m:  # replica_groups=[num_groups,group_size]<=[total]
        return int(m.group(2))
    m = _REPL_GROUPS_LIST.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _opcode(line: str) -> Optional[Tuple[str, str, str, bool, bool]]:
    """Returns (result_name, type_text, opcode, is_start, is_done)."""
    m = _OP_LINE_FULL.match(line)
    if not m:
        return None
    name, type_text, op = m.groups()
    is_start = op.endswith("-start")
    is_done = op.endswith("-done")
    base = op[:-6] if is_start else (op[:-5] if is_done else op)
    return name, type_text, base, is_start, is_done


def _dot_flops(line: str, type_text: str, comp: Computation) -> float:
    numel = _shape_numel(type_text)
    # operands appear inside the op parens before ", lhs_..." metadata
    paren = line.find("(", line.find(" dot("))
    operands = _OPERANDS.findall(line[paren:line.find(")", paren)])
    contracting = 1
    m = _LHS_CDIMS.search(line)
    if m and operands:
        lhs_type = comp.symbols.get(operands[0])
        if lhs_type:
            dims = _first_shape_dims(lhs_type)
            idxs = [int(i) for i in m.group(1).split(",") if i]
            for i in idxs:
                if i < len(dims):
                    contracting *= dims[i]
    return 2.0 * numel * contracting


_SLICE_OPS = ("dynamic-slice", "slice", "gather")


def _fusion_param_effective(comp: Computation) -> Dict[int, float]:
    """Effective HBM bytes per fusion parameter index.

    A fused computation that reads parameter i ONLY through
    dynamic-slice/slice/gather ops touches just the sliced window, not the
    whole buffer — e.g. the backward layer-scan reads one layer's slice of
    the (n_layers, ...) stacked saved-activation carry. Charging the full
    stack inflated memory terms ~5x (EXPERIMENTS.md §Perf #1d).
    Returns {param_index: effective_bytes} for params where the cap applies.
    """
    # param name -> index, and collect uses
    params: Dict[str, int] = {}
    for ln in comp.lines:
        m = re.match(r"\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*\S+\s+parameter\((\d+)\)",
                     ln)
        if m:
            params[m.group(1)] = int(m.group(2))
    if not params:
        return {}
    eff: Dict[int, float] = {}
    use_ok: Dict[str, bool] = {p: True for p in params}
    use_bytes: Dict[str, float] = {p: 0.0 for p in params}
    for ln in comp.lines:
        parsed = _opcode(ln)
        if not parsed:
            continue
        rname, type_text, op, _, _ = parsed
        if op == "parameter":
            continue
        paren = ln.find("(")
        ops_txt = ln[paren + 1: ln.find(")", paren)] if paren > 0 else ""
        for o in _OPERANDS.findall(ops_txt):
            if o in params:
                if op in _SLICE_OPS:
                    use_bytes[o] = max(use_bytes[o], _shape_bytes(type_text))
                elif op == "bitcast":
                    pass  # free; the bitcast result's uses are not chased —
                    # conservative: treat as non-slice use
                else:
                    use_ok[o] = False
    for pname, idx in params.items():
        if use_ok[pname] and use_bytes[pname] > 0:
            eff[idx] = use_bytes[pname]
    return eff


def analyze_hlo(hlo: str) -> HloCensus:
    comps = _split_computations(hlo)
    entry = _entry_name(hlo)
    fusion_eff_memo: Dict[str, Dict[int, float]] = {}

    def fusion_eff(callee: str) -> Dict[int, float]:
        if callee not in fusion_eff_memo:
            fusion_eff_memo[callee] = (
                _fusion_param_effective(comps[callee])
                if callee in comps else {})
        return fusion_eff_memo[callee]

    def trip_count(line: str, cond_name: Optional[str]) -> int:
        m = _TRIP.search(line)
        if m:
            return int(m.group(1))
        best = 1
        if cond_name and cond_name in comps:
            for ln in comps[cond_name].lines:
                for c in _CONST_INT.findall(ln):
                    best = max(best, int(c))
        return best

    # memoized per-computation census (flops, dot_flops, bytes, coll dicts)
    memo: Dict[str, Tuple] = {}

    def walk(name: str, stack=()) -> Tuple:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return (0.0, 0.0, 0.0, {}, {}, {})
        comp = comps[name]
        flops = dot_flops = bytes_ = 0.0
        coll_bytes: Dict[str, float] = {}
        coll_ops: Dict[str, int] = {}
        coll_group: Dict[str, float] = {}

        def merge(res, mult=1.0):
            nonlocal flops, dot_flops, bytes_
            f, df, b, cb, co, cg = res
            flops += f * mult
            dot_flops += df * mult
            bytes_ += b * mult
            for k, v in cb.items():
                coll_bytes[k] = coll_bytes.get(k, 0.0) + v * mult
            for k, v in co.items():
                coll_ops[k] = coll_ops.get(k, 0) + v
            for k, v in cg.items():
                coll_group[k] = coll_group.get(k, 0.0) + v * mult

        for line in comp.lines:
            parsed = _opcode(line)
            if not parsed:
                continue
            _, type_text, op, is_start, is_done = parsed

            # ---- control flow ------------------------------------------
            if op == "while":
                called = dict(re.findall(r"(body|condition)=\{?%?([\w.\-]+)",
                                         line))
                t = trip_count(line, called.get("condition"))
                if called.get("body"):
                    merge(walk(called["body"], stack + (name,)), t)
                if called.get("condition"):
                    merge(walk(called["condition"], stack + (name,)), t)
                continue
            if op == "conditional":
                bm = _BRANCHES.search(line)
                if bm:
                    results = [walk(b.strip().lstrip("%"), stack + (name,))
                               for b in bm.group(1).split(",")]
                    if results:
                        best = max(results, key=lambda r: r[0] + r[2])
                        merge(best)
                continue
            if op in ("fusion", "call", "async"):
                for callee in _CALLED.findall(line):
                    merge(walk(callee, stack + (name,)))
                # fusion operands+result still move HBM bytes:
                if op == "fusion":
                    paren = line.find("(", line.find("fusion("))
                    ops_txt = line[paren + 1: line.find(")", paren)]
                    ob = [_shape_bytes(comp.symbols.get(o, ""))
                          for o in _OPERANDS.findall(ops_txt)]
                    callees = _CALLED.findall(line)
                    eff = fusion_eff(callees[0]) if callees else {}
                    ob = [min(b, eff[i]) if i in eff else b
                          for i, b in enumerate(ob)]
                    rb = _shape_bytes(type_text)
                    if "dynamic-update-slice" in parsed[0] and ob:
                        # in-place DUS: the aliased destination buffer is not
                        # re-streamed; traffic = the updated slice (readback +
                        # write), i.e. operands minus the largest (aliased).
                        bytes_ += 2.0 * (sum(ob) - max(ob))
                    else:
                        bytes_ += sum(ob) + rb
                continue

            # ---- collectives --------------------------------------------
            if op in COLLECTIVES:
                if is_done:
                    continue  # counted on the start (or sync) op
                g = _group_size(line, default=2)
                paren = line.find(f"{op}{'-start' if is_start else ''}(")
                paren = line.find("(", paren)
                ops_txt = line[paren + 1: line.find(")", paren)]
                operand_names = _OPERANDS.findall(ops_txt)
                in_bytes = sum(
                    _shape_bytes(comp.symbols.get(o, "")) for o in operand_names
                )
                out_bytes = _shape_bytes(type_text)
                if is_start and out_bytes > in_bytes:
                    # start result tuples carry (operand, result[, ...])
                    out_bytes = max(out_bytes - in_bytes, in_bytes)
                frac = (g - 1) / g if g > 1 else 0.0
                wire = {
                    "all-reduce": 2.0 * in_bytes * frac,
                    "all-gather": out_bytes * frac,
                    "reduce-scatter": in_bytes * frac,
                    "all-to-all": in_bytes * frac,
                    "ragged-all-to-all": in_bytes * frac,
                    "collective-permute": float(in_bytes),
                }[op]
                coll_bytes[op] = coll_bytes.get(op, 0.0) + wire
                coll_ops[op] = coll_ops.get(op, 0) + 1
                key = f"{op}@g{g}"
                coll_group[key] = coll_group.get(key, 0.0) + wire
                bytes_ += in_bytes + out_bytes  # collectives also touch HBM
                continue

            # ---- compute / data movement ---------------------------------
            if op == "dot":
                flops_d = _dot_flops(line, type_text, comp)
                flops += flops_d
                dot_flops += flops_d
                # dot reads operands, writes result
                paren = line.find("(", line.find(" dot("))
                ops_txt = line[paren + 1: line.find(")", paren)]
                for o in _OPERANDS.findall(ops_txt):
                    bytes_ += _shape_bytes(comp.symbols.get(o, ""))
                bytes_ += _shape_bytes(type_text)
                continue
            if op == "convolution":
                # rough: 2 * numel(result) * kernel numel / output channels
                flops_c = 2.0 * _shape_numel(type_text)
                flops += flops_c
                dot_flops += flops_c
                bytes_ += _shape_bytes(type_text) * 2
                continue
            if op in _EW_OPS:
                flops += _shape_numel(type_text)
                if name == entry or not name.startswith("fused"):
                    bytes_ += _shape_bytes(type_text) * 2
                continue
            if op in _FREE_OPS or op in _CONTROL_OPS:
                continue
            # other top-level data ops (copy, reduce, broadcast, reshape,
            # transpose, scatter, gather, dynamic-slice, pad, ...): bytes only
            if not name.startswith("fused"):
                paren = line.find("(")
                ops_txt = line[paren + 1: line.find(")", paren)] if paren > 0 else ""
                ob = [_shape_bytes(comp.symbols.get(o, ""))
                      for o in _OPERANDS.findall(ops_txt)]
                if op == "dynamic-update-slice" and ob:
                    bytes_ += 2.0 * (sum(ob) - max(ob))  # in-place aliasing
                elif op in _SLICE_OPS:
                    # reads only the sliced window, not the source buffer
                    bytes_ += 2.0 * _shape_bytes(type_text)
                else:
                    bytes_ += sum(ob) + _shape_bytes(type_text)
            if op == "reduce":
                flops += _shape_numel(type_text)

        memo[name] = (flops, dot_flops, bytes_, coll_bytes, coll_ops,
                      coll_group)
        return memo[name]

    if entry is None:
        return HloCensus(0, 0, 0, 0, {}, {}, {})
    f, df, b, cb, co, cg = walk(entry)
    return HloCensus(
        flops=f,
        dot_flops=df,
        hbm_bytes=b,
        collective_wire_bytes=sum(cb.values()),
        collective_bytes_by_kind=cb,
        collective_ops_by_kind=co,
        collective_bytes_by_group=cg,
    )


# --------------------------------------------------------------- back-compat


@dataclasses.dataclass
class CollectiveStats:
    operand_bytes: Dict[str, float]
    wire_bytes: float
    op_counts: Dict[str, int]

    @property
    def total_operand_bytes(self) -> float:
        return sum(self.operand_bytes.values())


def analyze_collectives(hlo: str) -> CollectiveStats:
    census = analyze_hlo(hlo)
    return CollectiveStats(
        operand_bytes=census.collective_bytes_by_kind,
        wire_bytes=census.collective_wire_bytes,
        op_counts=census.collective_ops_by_kind,
    )
