"""End-to-end trainer: config -> mesh -> sharded train loop with
checkpoint/restart, overhead instrumentation, and optional failure drill.

Runs the same code path at every scale: reduced configs on this container's
CPU (examples/tests), full configs on a real pod (the dry-run proves those
compile). The loop is deliberately framework-shaped:

  * data: deterministic synthetic pipeline, double-buffered (prefetch)
  * step: jit'd train_step under the cell's ShardingPolicy
  * fault tolerance: atomic async checkpoints every --ckpt-every, restart
    from latest on (injected) failure, elastic restore onto a new mesh
  * instrumentation: OverheadProfiler reports dispatch overhead, effective
    task granularity and step-METG — the paper's methodology applied to the
    production loop (DESIGN.md §3)

Usage (reduced, CPU):
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.elastic import FailureInjector, SimulatedFailure
from repro.configs.base import ModelConfig, ShapeConfig
from repro.configs.registry import ARCHS, get_config, get_shape
from repro.core.instrumentation import OverheadProfiler
from repro.data.pipeline import SyntheticTokenPipeline
from repro.distributed.api import sharding_context
from repro.distributed.sharding import ShardingPolicy
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models.model import Model
from repro.optim.optimizer import AdamW, AdamWConfig


@dataclasses.dataclass
class TrainResult:
    final_loss: float
    losses: list
    steps_run: int
    restarts: int
    report: Optional[Any]  # OverheadReport


def train(
    cfg: ModelConfig,
    shape: ShapeConfig,
    *,
    steps: int,
    batch: Optional[int] = None,
    seq: Optional[int] = None,
    mesh=None,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    fail_at: tuple = (),
    lr: float = 3e-4,
    seed: int = 0,
    log_every: int = 10,
    profile: bool = True,
    verbose: bool = True,
) -> TrainResult:
    model = Model(cfg)
    opt = AdamW(AdamWConfig(lr=lr, total_steps=max(steps, 2),
                            warmup_steps=max(steps // 10, 1)))
    B = batch or shape.global_batch
    S = seq or shape.seq_len

    policy = None
    if mesh is not None:
        policy = ShardingPolicy.for_step(cfg, shape, mesh)

    pipeline = SyntheticTokenPipeline(cfg, shape, seed=seed,
                                      batch_override=B, seq_override=S)
    step_fn = steps_lib.make_train_step(model, opt)

    if policy is not None:
        rules = policy.rules

        def wrapped(params, opt_state, data):
            with sharding_context(mesh, rules):
                return step_fn(params, opt_state, data)

        jitted = jax.jit(wrapped, donate_argnums=(0, 1))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    def fresh_state():
        params = model.init(jax.random.PRNGKey(seed))
        if policy is not None:
            params = jax.device_put(params, policy.param_shardings(params))
        opt_state = opt.init(params)
        if policy is not None:
            opt_state = jax.device_put(
                opt_state, opt.state_shardings(policy, params))
        return {"params": params, "opt": opt_state}

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    injector = FailureInjector(fail_at) if fail_at else None
    profiler = OverheadProfiler(
        devices=(mesh.size if mesh is not None else 1),
        tasks_per_step=1,
        flops_per_step=steps_lib.step_flops_estimate(cfg, shape)
        * (B * S) / (shape.global_batch * shape.seq_len),
    ) if profile else None

    restarts = 0
    losses: list = []
    while True:
        start = 0
        state = fresh_state()
        if ckpt is not None and ckpt.latest_step() is not None:
            state, extra = ckpt.restore(state)
            start = int(extra.get("step", ckpt.latest_step()))
            pipeline.load_state_dict(extra["pipeline"]) if "pipeline" in extra \
                else None
        pipeline.state.step = start
        try:
            t_all = time.perf_counter()
            for step in range(start, steps):
                if injector is not None:
                    injector.maybe_fail(step)
                data = pipeline.batch_at(step)
                t0 = time.perf_counter()
                params, opt_state, metrics = jitted(
                    state["params"], state["opt"], data)
                metrics = jax.block_until_ready(metrics)
                wall = time.perf_counter() - t0
                state = {"params": params, "opt": opt_state}
                if profiler is not None:
                    profiler.record(wall)
                loss = float(metrics["loss"])
                losses.append(loss)
                if verbose and (step % log_every == 0 or step == steps - 1):
                    print(f"step {step:5d}  loss {loss:.4f}  "
                          f"gnorm {float(metrics['grad_norm']):.3f}  "
                          f"lr {float(metrics['lr']):.2e}  "
                          f"wall {wall*1e3:.1f} ms", flush=True)
                nxt = step + 1
                if ckpt is not None and (
                    nxt % ckpt_every == 0 or nxt == steps
                ):
                    ckpt.async_save(nxt, state, {
                        "step": nxt, "pipeline": pipeline.state_dict()})
            if ckpt is not None:
                ckpt.wait()
            break
        except SimulatedFailure as e:
            restarts += 1
            if verbose:
                print(f"[failure] {e} -> restarting from latest checkpoint "
                      f"(restart #{restarts})", flush=True)
            if ckpt is not None:
                ckpt.wait()
            if restarts > 16:
                raise

    report = None
    if profiler is not None and profiler.records:
        report = profiler.report()
        if verbose:
            print("\n-- overhead report (paper methodology, §3) --")
            for line in report.lines():
                print("  " + line)
    return TrainResult(
        final_loss=losses[-1] if losses else float("nan"),
        losses=losses,
        steps_run=len(losses),
        restarts=restarts,
        report=report,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true",
                    help="use the tiny same-family smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject SimulatedFailure at these steps (drill)")
    ap.add_argument("--mesh", default=None,
                    help="host mesh e.g. '4:data' or '2,2:data,model'")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = get_shape(args.shape)
    mesh = None
    if args.mesh:
        dims, axes = args.mesh.split(":")
        mesh = make_host_mesh([int(d) for d in dims.split(",")],
                              axes.split(","))

    res = train(
        cfg, shape, steps=args.steps, batch=args.batch, seq=args.seq,
        mesh=mesh, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        fail_at=tuple(args.fail_at), lr=args.lr,
    )
    print(f"\nfinal loss {res.final_loss:.4f} after {res.steps_run} steps "
          f"({res.restarts} restarts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
