"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run entrypoint (dryrun.py) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE importing jax;
everything else sees the real (single-CPU) device set.

Topology model (TPU v5e-class): one pod = 16 x 16 = 256 chips on ICI
(~50 GB/s/link); the multi-pod mesh adds a leading "pod" axis whose
collectives cross the slower DCI — the hierarchical gradient reduction in
train_step keeps that hop to 1/16 of the gradient bytes.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh over host devices (tests / reduced dry-runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


# Hardware constants for the roofline (TPU v5e-class, per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
CHIP_HBM_BYTES = 16 * 1024**3
