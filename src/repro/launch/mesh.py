"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run entrypoint (dryrun.py) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE importing jax;
everything else sees the real (single-CPU) device set.

Topology model (TPU v5e-class): one pod = 16 x 16 = 256 chips on ICI
(~50 GB/s/link); the multi-pod mesh adds a leading "pod" axis whose
collectives cross the slower DCI — the hierarchical gradient reduction in
train_step keeps that hop to 1/16 of the gradient bytes.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

# jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist on
# newer jax; on 0.4.x every mesh axis is implicitly Auto, which is exactly
# what we request on newer versions — so omitting the kwarg is equivalent.
try:  # jax >= 0.5
    import inspect

    from jax.sharding import AxisType

    _AXIS_TYPE_KW = "axis_types" in inspect.signature(jax.make_mesh).parameters
except ImportError:  # jax 0.4.x
    AxisType = None
    _AXIS_TYPE_KW = False


def _make_mesh(shape, axes) -> Mesh:
    """`jax.make_mesh` with Auto axis types on every jax version."""
    if _AXIS_TYPE_KW:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh over host devices (tests / reduced dry-runs)."""
    return _make_mesh(tuple(shape), tuple(axes))


def make_row_member_mesh(devices, member_shards: int, *,
                         row_axis: str = "shard",
                         member_axis: str = "member") -> Mesh:
    """The 2D (row, member) mesh for K-sharded stacked ensembles.

    ``devices`` (an explicit device list, so runtimes pin their own
    subset) reshapes to (Dr, Dk) = (len(devices) // member_shards,
    member_shards): collectives over ``row_axis`` stay within one
    row-subgroup of Dr devices (halo/stride/gather transports never cross
    the member axis), while the K members split Dk ways along
    ``member_axis``.

    Mirrors ``_halo.exchange_stride_start``'s loud non-pow2 rejection:
    a Dk that does not divide the device count would otherwise surface as
    an opaque XLA reshape/shard_map error deep inside the launch, so the
    contract is enforced here with the fallback named.
    """
    import numpy as np

    devices = list(devices)
    count = len(devices)
    dk = int(member_shards)
    if dk < 1 or count % dk:
        raise ValueError(
            f"2D (row, member) mesh needs member_shards to divide the "
            f"device count: {count} devices cannot split into "
            f"(rows, members) = ({count / dk if dk else '?'}, {dk}). "
            f"Pass member_shards=1 (or a divisor of {count}) to fall "
            f"back to the replicated 1D row mesh.")
    return Mesh(np.asarray(devices).reshape(count // dk, dk),
                (row_axis, member_axis))


# Hardware constants for the roofline (TPU v5e-class, per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
CHIP_HBM_BYTES = 16 * 1024**3
