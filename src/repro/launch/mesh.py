"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run entrypoint (dryrun.py) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE importing jax;
everything else sees the real (single-CPU) device set.

Topology model (TPU v5e-class): one pod = 16 x 16 = 256 chips on ICI
(~50 GB/s/link); the multi-pod mesh adds a leading "pod" axis whose
collectives cross the slower DCI — the hierarchical gradient reduction in
train_step keeps that hop to 1/16 of the gradient bytes.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

# jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist on
# newer jax; on 0.4.x every mesh axis is implicitly Auto, which is exactly
# what we request on newer versions — so omitting the kwarg is equivalent.
try:  # jax >= 0.5
    import inspect

    from jax.sharding import AxisType

    _AXIS_TYPE_KW = "axis_types" in inspect.signature(jax.make_mesh).parameters
except ImportError:  # jax 0.4.x
    AxisType = None
    _AXIS_TYPE_KW = False


def _make_mesh(shape, axes) -> Mesh:
    """`jax.make_mesh` with Auto axis types on every jax version."""
    if _AXIS_TYPE_KW:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh over host devices (tests / reduced dry-runs)."""
    return _make_mesh(tuple(shape), tuple(axes))


# Hardware constants for the roofline (TPU v5e-class, per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
CHIP_HBM_BYTES = 16 * 1024**3
