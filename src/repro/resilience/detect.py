"""Deadline-based straggler detection (no hand-tuned timeouts).

A launch (or decode step) is flagged when its wall exceeds ``factor`` x
the EXPECTED wall. Two sources for the expectation, in precedence order:

  measured    the PR 6 CostModel prices the launch
              (kernels.schedule.launch_deadline_us) — the deadline exists
              from the first launch.
  observed    uncalibrated runs self-calibrate: after ``warmup`` clean
              observations the expectation is the running median of the
              walls seen so far. This is the analytic fallback — the
              analytic cost model carries only RATIOS (row-steps per
              exchange), never absolute microseconds, so it cannot price
              a deadline; the run's own walls can (DESIGN.md §11).

Flagged walls are NOT folded into the running median (a straggler must
not drag the baseline toward itself), recompile-boundary walls — marked
via ``note_recompile_boundary()`` before the first launch after a
(re)compile or membership change — are neither folded nor flagged (a
compile wall is expected to be slow; folding it would seed the warmup
median with an outlier), and the detector never *acts* — it reports
overshoot, and the caller decides (the engine records a tracer ``fault``
event; serve.py reports the step in ServeResult).
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import List, Optional

#: deadline = factor x expected wall. Generous by design: the cost is a
#: false *negative* (a straggler coasts), never a false positive killing
#: healthy work — detection only reports.
DEFAULT_DEADLINE_FACTOR = 8.0


@dataclasses.dataclass
class Detection:
    """One flagged wall: the overshoot is the detection latency — how far
    past the deadline completion arrived."""

    index: int
    wall_us: float
    deadline_us: float

    @property
    def overshoot_us(self) -> float:
        return self.wall_us - self.deadline_us


class DeadlineDetector:
    def __init__(
        self,
        *,
        factor: float = DEFAULT_DEADLINE_FACTOR,
        expected_us: Optional[float] = None,
        warmup: int = 3,
        min_deadline_us: float = 500.0,
    ):
        if factor <= 1.0:
            raise ValueError(f"deadline factor must exceed 1, got {factor}")
        self.factor = float(factor)
        self.expected_us = expected_us
        self.warmup = int(warmup)
        self.min_deadline_us = float(min_deadline_us)
        self._walls: List[float] = []
        self.detections: List[Detection] = []
        self._n = 0
        self._boundary_next = False
        #: boundary walls seen (compile/repack walls excluded from both
        #: the median and the detections) — exposed for tests/telemetry
        self.boundary_skips = 0

    def deadline_us(self) -> Optional[float]:
        """The current deadline, or None while still unpriceable (no
        model and fewer than ``warmup`` clean observations)."""
        if self.expected_us is not None and self.expected_us > 0:
            return max(self.factor * self.expected_us, self.min_deadline_us)
        if len(self._walls) >= self.warmup:
            return max(self.factor * statistics.median(self._walls),
                       self.min_deadline_us)
        return None

    def note_recompile_boundary(self) -> None:
        """Mark the NEXT observed wall as crossing a recompile/repack
        boundary (the cohort's first launch, or the first launch after any
        membership change). That wall carries compilation, not steady-state
        work: folding it into the self-calibration median would let one
        warmup-compile outlier seed the baseline and inflate every later
        deadline, and flagging it would report a healthy repack as a
        straggler — so it is neither folded nor flagged."""
        self._boundary_next = True

    def observe(self, wall_us: float) -> Optional[Detection]:
        """Record one wall; returns a Detection when it blew the deadline."""
        boundary, self._boundary_next = self._boundary_next, False
        deadline = self.deadline_us()
        idx = self._n
        self._n += 1
        if boundary:
            self.boundary_skips += 1
            return None
        if deadline is not None and wall_us > deadline:
            det = Detection(idx, wall_us, deadline)
            self.detections.append(det)
            return det
        self._walls.append(wall_us)
        return None

    @property
    def source(self) -> str:
        return "measured" if self.expected_us else "observed"
