"""Fault-tolerant ensemble runtime: injection, detection, recovery.

Public surface:

  faults   FaultPlan / FaultSpec / FaultState — seeded declarative fault
           schedules; install_chaos_impls() registers chaos+<base>
           transport wrappers; InjectedFault and friends.
  detect   DeadlineDetector — cost-model (or self-calibrated) deadline
           checks on launch walls.
  engine   run_resilient() — the host-stepped launch loop with transport
           retry, launch replay, act-mask member eviction, re-admission,
           and straggler flagging; RecoveryPolicy / ResilientResult.

Entry points: ``runtime.execute_ensemble_resilient(ensemble, plan=...)``
(core.runtimes.base), or call :func:`run_resilient` directly.
"""
from repro.resilience.detect import (  # noqa: F401
    DEFAULT_DEADLINE_FACTOR,
    DeadlineDetector,
    Detection,
)
from repro.resilience.engine import (  # noqa: F401
    FaultEvent,
    READMIT_SEED_OFFSET,
    RecoveryPolicy,
    ResilientResult,
    backoff_delay_s,
    run_resilient,
)
from repro.resilience.faults import (  # noqa: F401
    CHAOS_IMPL_PREFIX,
    FAULT_KINDS,
    FAULT_LAUNCH,
    FAULT_MEMBER,
    FAULT_STRAGGLER,
    FAULT_TRANSPORT,
    FaultPlan,
    FaultSpec,
    FaultState,
    InjectedFault,
    LaunchFault,
    MemberFault,
    TransientTransportFault,
    UnrecoverableFault,
    armed,
    armed_state,
    install_chaos_impls,
    transport_site,
)
