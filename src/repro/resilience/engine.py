"""Resilient host-stepped ensemble execution.

``run_resilient`` drives a runtime's :class:`EnsembleLaunchPlan` launch by
launch on the host. Host visibility at launch boundaries is what buys
fault tolerance — nothing can be detected, retried, or replayed inside
one opaque XLA program — and it is also the only cost: the clean path
runs the same kernels over the same operands as production, just with a
host dispatch per launch instead of one scan (the "armor tax" the chaos
benchmark measures).

Per launch, in order:

  gate      the injection hook: one predicate check against the armed
            FaultPlan (``plan=None`` skips everything — the zero-cost
            contract).
  dispatch  the launch, wall-timed. Transient transport faults raise
            here and retry in place with capped exponential backoff +
            jitter; launch faults raise (replay) or poison the output.
  verify    member faults evict (zero the member's act slot from this
            launch on, replay from the snapshot — survivors bit-identical,
            the dead member's rows frozen exactly where its mask ends);
            poisoned output replays from the snapshot; deadline overshoot
            is flagged (detection latency recorded), never re-executed.
  commit    keep the carry; the pre-launch snapshot ring (depth 1) rolls
            forward.

Replay is bit-identical because launch_fn is a pure, deterministic
function of (carry, act row) — replaying the same snapshot reproduces the
same bits, which the chaos property suite asserts per fault class.

All detection and recovery work lands in tracer ``fault``-category spans
(walls: backoff sleeps, replays) and zero-length ``fault`` records
(detections/verdicts), so a Chrome trace of a faulted run shows exactly
where the recovery tax went.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.tracer import CAT_FAULT, coerce_tracer
from repro.resilience import faults as _faults
from repro.resilience.detect import DeadlineDetector
from repro.resilience.faults import (
    FAULT_LAUNCH,
    FAULT_MEMBER,
    FAULT_STRAGGLER,
    FaultPlan,
    FaultState,
    LaunchFault,
    TransientTransportFault,
    UnrecoverableFault,
)

#: seed offset for the fresh member admitted into a freed slot, so the
#: re-admitted run is reproducible from the evicted member's own seed
READMIT_SEED_OFFSET = 7919


@dataclasses.dataclass
class RecoveryPolicy:
    """Recovery budgets and knobs (all deterministic given a plan seed)."""

    #: deadline = factor x expected launch wall (detect.py)
    deadline_factor: float = 8.0
    #: transient transport faults: attempts beyond the first
    max_transport_retries: int = 4
    backoff_base_s: float = 0.005
    backoff_cap_s: float = 0.25
    #: uniform jitter fraction added to each backoff delay
    backoff_jitter: float = 0.25
    #: replays (launch fault / poison / eviction) tolerated per launch
    max_replays_per_launch: int = 4
    #: scan launch output for NaN poison; None = only when a plan is
    #: armed (the no-fault path must not pay a device reduction per launch)
    check_poison: Optional[bool] = None
    #: admit a fresh member into an evicted slot at the next boundary
    readmit: bool = False


@dataclasses.dataclass
class FaultEvent:
    """One detection/recovery, as recorded (and JSON-exported by chaos)."""

    kind: str
    launch: int
    action: str  # "retried" | "replayed" | "evicted" | "readmitted" | "flagged"
    member: int = -1
    attempts: int = 0
    mode: str = ""
    #: recovery wall spent on this event (backoff sleeps, wasted launch)
    wall_us: float = 0.0
    #: deadline overshoot for flagged stragglers (detection latency)
    overshoot_us: Optional[float] = None


@dataclasses.dataclass
class ResilientResult:
    """What a resilient run returns: outputs matching execute_ensemble
    plus the full fault/recovery ledger."""

    outputs: Tuple[np.ndarray, ...]
    wall_s: float
    launches: int
    events: List[FaultEvent]
    retries: int = 0
    replays: int = 0
    stragglers: int = 0
    #: member slot -> effective steps its output froze at (masked rows)
    evicted: Dict[int, int] = dataclasses.field(default_factory=dict)
    #: member slot -> {"launch", "steps", "seed"} of the admitted member
    readmitted: Dict[int, Dict] = dataclasses.field(default_factory=dict)
    deadline_us: Optional[float] = None
    deadline_source: str = ""

    @property
    def faults_seen(self) -> int:
        return len(self.events)


def backoff_delay_s(policy: RecoveryPolicy, attempt: int,
                    rng: np.random.Generator) -> float:
    """Capped exponential backoff with uniform jitter: attempt 1 waits
    ~base, each further attempt doubles, never past the cap."""
    base = min(policy.backoff_base_s * (2.0 ** (attempt - 1)),
               policy.backoff_cap_s)
    return base * (1.0 + policy.backoff_jitter * float(rng.random()))


def _is_poisoned(carry) -> bool:
    return any(
        bool(jnp.isnan(leaf).any())
        for leaf in jax.tree_util.tree_leaves(carry))


def _poison(carry):
    return jax.tree_util.tree_map(
        lambda x: jnp.full_like(x, jnp.nan)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, carry)


class _Run:
    """One resilient execution (the mutable loop state run_resilient
    drives; split out so each recovery path stays readable)."""

    def __init__(self, runtime, ensemble, plan, policy, tracer):
        self.runtime = runtime
        self.ensemble = ensemble
        self.lp = runtime.build_ensemble_launches(ensemble)
        self.policy = policy
        self.tracer = tracer
        self.state = (FaultState(plan)
                      if plan is not None and plan.specs else None)
        self.check_poison = (policy.check_poison
                             if policy.check_poison is not None
                             else self.state is not None)
        self.detector = DeadlineDetector(
            factor=policy.deadline_factor,
            expected_us=self.lp.expected_launch_us)
        self.acts = np.array(self.lp.acts, dtype=np.float32, copy=True)
        self.rng = np.random.default_rng(
            plan.seed if plan is not None and plan.seed is not None else 0)
        self.events: List[FaultEvent] = []
        self.retries = 0
        self.replays = 0
        self.stragglers = 0
        self.evicted: Dict[int, int] = {}
        self.readmitted: Dict[int, Dict] = {}

    # ------------------------------------------------------------ pieces

    def _record(self, name: str, **attrs) -> None:
        """Zero-length fault record (the decision-record idiom, under the
        fault category so traces separate recovery from scheduling)."""
        t = self.tracer.now_us()
        self.tracer.add(name, CAT_FAULT, t, t, **attrs)

    def _dispatch(self, launch: int, carry, act_row):
        """One launch with in-place transport retry. Returns
        (wall_us, new_carry); raises LaunchFault (replay) or
        UnrecoverableFault (budget spent)."""
        lp, st, policy = self.lp, self.state, self.policy
        t0 = jnp.asarray(lp.launch_t0(launch), jnp.int32)
        attempt = 0
        backoff_us = 0.0
        while True:
            t_start = time.perf_counter()
            try:
                if st is not None and st.transport_should_fail(launch):
                    raise TransientTransportFault(
                        f"injected transport fault at launch {launch}")
                lspec = st.peek(FAULT_LAUNCH, launch) if st else None
                if lspec is not None and lspec.mode == "raise":
                    st.take(FAULT_LAUNCH, launch)
                    raise LaunchFault(
                        f"injected launch failure at launch {launch}")
                with _faults.transport_site(launch):
                    out = lp.launch_fn(carry, act_row, t0)
                sspec = st.take(FAULT_STRAGGLER, launch) if st else None
                if sspec is not None:
                    # completion arrives late: the stall is part of the wall
                    time.sleep(sspec.delay_s)
                out = jax.block_until_ready(out)
                wall_us = (time.perf_counter() - t_start) * 1e6
                if attempt:
                    self.events.append(FaultEvent(
                        "transport", launch, "retried", attempts=attempt,
                        wall_us=backoff_us))
                lspec = st.take(FAULT_LAUNCH, launch) if st else None
                if lspec is not None:  # mode == "poison"
                    out = _poison(out)
                return wall_us, out
            except TransientTransportFault as e:
                attempt += 1
                self.retries += 1
                self._record("transport_fault", launch=launch,
                             attempt=attempt, error=str(e))
                if attempt > policy.max_transport_retries:
                    raise UnrecoverableFault(
                        f"transport at launch {launch} still failing after "
                        f"{attempt} attempts") from e
                delay = backoff_delay_s(policy, attempt, self.rng)
                with self.tracer.span("backoff", CAT_FAULT, launch=launch,
                                      attempt=attempt, delay_s=delay):
                    time.sleep(delay)
                backoff_us += delay * 1e6

    def _evict(self, launch: int, member: int) -> None:
        """Freeze the member's act slot from this launch on: its rows
        stay exactly where the pre-launch snapshot left them (the masked
        rows), survivors never notice."""
        s = self.lp.steps_per_launch
        frozen = min(self.lp.member_steps[member],
                     self.lp.launch_t0(launch))
        self.acts[launch:, member, :] = 0.0
        self.evicted[member] = int(frozen)
        self._record("member_evicted", launch=launch, member=member,
                     frozen_steps=int(frozen), steps_per_launch=s)

    def _readmit(self, member: int, next_launch: int):
        """Admit a fresh member into the freed slot at the next launch
        boundary (the serving-fabric admission primitive): new init rows,
        fresh activity schedule starting at ITS OWN t=0."""
        lp = self.lp
        if lp.admit_fn is None or next_launch >= lp.num_launches:
            return None
        from repro.core.task_kernels import initial_state

        g = self.ensemble.members[member]
        seed = g.seed + READMIT_SEED_OFFSET
        init = initial_state(g.width, g.payload, seed)
        s = lp.steps_per_launch
        rem = lp.num_launches - next_launch
        tloc = 1 + (np.arange(rem)[:, None] * s + np.arange(s)[None, :])
        self.acts[next_launch:, member, :] = (
            tloc < g.steps).astype(np.float32)
        eff = int(min(g.steps, rem * s + 1))
        self.readmitted[member] = {
            "launch": int(next_launch), "steps": eff, "seed": int(seed)}
        self._record("member_readmitted", launch=next_launch,
                     member=member, steps=eff, seed=seed)
        return init

    def run_launch(self, launch: int, carry):
        """Run one launch to a committed carry (retry / replay / evict
        until it lands or the policy budget is spent)."""
        lp, st, policy = self.lp, self.state, self.policy
        snapshot = carry
        replays_here = 0
        admit_member: Optional[int] = None
        act_row = jnp.asarray(self.acts[launch])
        while True:
            try:
                wall_us, candidate = self._dispatch(launch, snapshot, act_row)
            except LaunchFault as e:
                replays_here += 1
                self.replays += 1
                self._record("launch_fault", launch=launch, mode="raise",
                             error=str(e))
                self.events.append(FaultEvent(
                    "launch", launch, "replayed", mode="raise"))
                if replays_here > policy.max_replays_per_launch:
                    raise UnrecoverableFault(
                        f"launch {launch} replay budget spent") from e
                continue
            mspec = st.take(FAULT_MEMBER, launch) if st else None
            if mspec is not None:
                # the member died during this launch: its slice of the
                # candidate is garbage. Evict and replay from the snapshot
                # with the slot masked — survivors recompute bit-identically,
                # the dead member's rows freeze at the snapshot.
                replays_here += 1
                self.replays += 1
                self._evict(launch, mspec.member)
                # membership changed: the next committed wall crosses a
                # repack boundary and must not seed the deadline median
                self.detector.note_recompile_boundary()
                self.events.append(FaultEvent(
                    "member", launch, "evicted", member=mspec.member,
                    wall_us=wall_us))
                if policy.readmit:
                    admit_member = mspec.member
                act_row = jnp.asarray(self.acts[launch])
                if replays_here > policy.max_replays_per_launch:
                    raise UnrecoverableFault(
                        f"launch {launch} replay budget spent")
                continue
            if self.check_poison and _is_poisoned(candidate):
                replays_here += 1
                self.replays += 1
                self._record("launch_poisoned", launch=launch)
                self.events.append(FaultEvent(
                    "launch", launch, "replayed", mode="poison",
                    wall_us=wall_us))
                if replays_here > policy.max_replays_per_launch:
                    raise UnrecoverableFault(
                        f"launch {launch} keeps returning poisoned output")
                continue
            det = self.detector.observe(wall_us)
            if det is not None:
                self.stragglers += 1
                self._record("straggler", launch=launch,
                             wall_us=wall_us, deadline_us=det.deadline_us,
                             overshoot_us=det.overshoot_us)
                self.events.append(FaultEvent(
                    "straggler", launch, "flagged", wall_us=wall_us,
                    overshoot_us=det.overshoot_us))
            carry = candidate
            break
        if admit_member is not None:
            init = self._readmit(admit_member, launch + 1)
            if init is not None:
                carry = self.lp.admit_fn(carry, admit_member, init)
                # the first wall after a re-admission is a repack
                # boundary (admit compiles on first use per slot)
                self.detector.note_recompile_boundary()
        return carry


def run_resilient(
    runtime,
    ensemble,
    *,
    plan: Optional[FaultPlan] = None,
    policy: Optional[RecoveryPolicy] = None,
    tracer=None,
) -> ResilientResult:
    """Execute the ensemble with fault injection/detection/recovery.

    ``runtime`` must implement ``build_ensemble_launches`` (pallas_step;
    base.Runtime documents the restart fallback for the rest). With
    ``plan=None`` nothing is armed: the per-launch hook is one ``is not
    None`` check and no poison scan runs — the zero-cost contract the
    chaos artifact's clean walls verify.
    """
    policy = policy or RecoveryPolicy()
    tracer = coerce_tracer(tracer) if tracer is not None else runtime.tracer
    run = _Run(runtime, ensemble, plan, policy, tracer)
    lp = run.lp
    inits = runtime._ensemble_inits(ensemble)
    t_start = time.perf_counter()
    with _faults.armed(run.state):
        carry = jax.block_until_ready(lp.init_fn(inits))
        for launch in range(lp.num_launches):
            carry = run.run_launch(launch, carry)
        outputs = jax.block_until_ready(lp.finalize(carry))
    wall_s = time.perf_counter() - t_start
    return ResilientResult(
        outputs=tuple(np.asarray(o) for o in outputs),
        wall_s=wall_s,
        launches=lp.num_launches,
        events=run.events,
        retries=run.retries,
        replays=run.replays,
        stragglers=run.stragglers,
        evicted=run.evicted,
        readmitted=run.readmitted,
        deadline_us=run.detector.deadline_us(),
        deadline_source=run.detector.source,
    )
