"""Attention module: GQA + RoPE + SWA/local:global + KV cache + cross-attn.

Modes:
  train    full-sequence causal attention, no cache, differentiable (jnp ref)
  prefill  same forward, also returns the populated KV cache (flash kernel)
  decode   one token: cache update at `lengths` + flash-decode read; when the
           active sharding rules put the cache's sequence dim on mesh axes,
           reads go through sequence-parallel lse-combine (collectives.py)

Self- and cross-attention share this module; cross (VLM image layers) skips
RoPE/causality and caches the projected image K/V at prefill.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import api as dapi
from repro.distributed.collectives import sequence_parallel_decode_attention
from repro.kernels import ops, ref
from repro.models.layers import dense_init, rmsnorm_fwd

Params = Dict[str, jax.Array]


def attn_init(key, cfg: ModelConfig, dtype) -> Params:
    d, hd = cfg.d_model, cfg.head_dim_
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, d, cfg.n_heads * hd, dtype),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dtype),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _window_for(cfg: ModelConfig, kind: str) -> int:
    if kind == "local":
        return cfg.local_window
    if kind == "global":
        return 0
    return cfg.window  # attn / hybrid-attn: arch-wide setting (0 = full)


def _project_qkv(p: Params, x: jax.Array, kv_src: jax.Array, cfg: ModelConfig):
    B, S = x.shape[0], x.shape[1]
    hd = cfg.head_dim_
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (kv_src @ p["wk"]).reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    v = (kv_src @ p["wv"]).reshape(B, kv_src.shape[1], cfg.n_kv_heads, hd)
    if cfg.use_qk_norm:
        q = rmsnorm_fwd(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_fwd(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def attn_fwd(
    p: Params,
    x: jax.Array,  # (B, S, D); S == 1 in decode
    *,
    cfg: ModelConfig,
    kind: str,  # attn | local | global | xattn
    mode: str,  # train | prefill | decode
    positions: Optional[jax.Array] = None,  # (B, S) absolute positions
    cache: Optional[Params] = None,  # {"k","v"}: (B, Hkv, S_max, hd)
    lengths: Optional[jax.Array] = None,  # (B,) tokens already in cache
    kv_src: Optional[jax.Array] = None,  # cross-attn source (B, I, D)
) -> Tuple[jax.Array, Optional[Params]]:
    from repro.models.layers import rope

    B, S, _ = x.shape
    hd = cfg.head_dim_
    cross = kind == "xattn"
    window = 0 if cross else _window_for(cfg, kind)
    causal = not cross
    differentiable = mode == "train"
    use_kernel = cfg.use_flash and not differentiable

    # ---------------------------------------------------------- decode path
    if mode == "decode":
        assert cache is not None and lengths is not None
        q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
        if cfg.use_qk_norm:
            q = rmsnorm_fwd(p["q_norm"], q, cfg.norm_eps)
        if cross:
            kc, vc = cache["k"], cache["v"]  # static image K/V from prefill
            new_cache = cache
            read_len = jnp.full((B,), kc.shape[2], jnp.int32)
        else:
            q = rope(q, positions, cfg.rope_theta)
            t_k = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
            t_v = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
            if cfg.use_qk_norm:
                t_k = rmsnorm_fwd(p["k_norm"], t_k, cfg.norm_eps)
            t_k = rope(t_k, positions, cfg.rope_theta)
            t_k = t_k.transpose(0, 2, 1, 3)  # (B, Hkv, 1, hd)
            t_v = t_v.transpose(0, 2, 1, 3)
            # write the new token at its position (per-sequence scatter)
            upd = jax.vmap(
                lambda c, t, l: jax.lax.dynamic_update_slice_in_dim(c, t, l, 1)
            )
            if "k_scale" in cache:  # int8 cache: quantize the new token
                qk, sk = _quantize_kv(t_k)
                qv, sv = _quantize_kv(t_v)
                new_cache = {
                    "k": upd(cache["k"], qk, lengths),
                    "v": upd(cache["v"], qv, lengths),
                    "k_scale": upd(cache["k_scale"], sk, lengths),
                    "v_scale": upd(cache["v_scale"], sv, lengths),
                }
                kc = _dequantize_kv(new_cache["k"], new_cache["k_scale"],
                                    x.dtype)
                vc = _dequantize_kv(new_cache["v"], new_cache["v_scale"],
                                    x.dtype)
            else:
                kc = upd(cache["k"], t_k, lengths)
                vc = upd(cache["v"], t_v, lengths)
                new_cache = {"k": kc, "v": vc}
            read_len = lengths + 1

        qd = q.reshape(B, cfg.n_heads, hd)
        mesh = dapi.current_mesh()
        rules = dapi.current_rules()
        seq_axes = rules.resolve("cache_seq") if rules else None
        if mesh is not None and seq_axes is not None \
                and kc.shape[2] % _axprod(mesh, seq_axes) == 0:
            out = sequence_parallel_decode_attention(
                qd, kc, vc, read_len,
                mesh=mesh, seq_axes=seq_axes,
                batch_axis=rules.resolve("batch")
                if kc.shape[0] % _axprod(mesh, rules.resolve("batch")) == 0
                else None,
                window=window, use_kernel=use_kernel,
            )
        else:
            out = ops.decode_attention(qd, kc, vc, read_len, window=window,
                                       use_kernel=use_kernel)
        out = out.reshape(B, 1, cfg.n_heads * hd)
        return out @ p["wo"], new_cache

    # ---------------------------------------------------- train / prefill
    src = kv_src if cross else x
    q, k, v = _project_qkv(p, x, src, cfg)
    if not cross:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    qh = dapi.constrain(q.transpose(0, 2, 1, 3), "batch", "heads", "seq_q", None)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)

    out = ops.flash_attention(qh, kh, vh, causal=causal, window=window,
                              use_kernel=use_kernel)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * hd)
    # row-parallel wo: partial sums over the model axis — constrain straight
    # to the seq-sharded residual layout so XLA emits reduce-scatter
    out = dapi.constrain(out @ p["wo"], "batch", "seq", None)

    new_cache = None
    if mode == "prefill":
        if cross:
            new_cache = {"k": kh, "v": vh}  # (B, Hkv, I, hd) image K/V
        elif cfg.kv_quant:
            qk, sk = _quantize_kv(kh)
            qv, sv = _quantize_kv(vh)
            new_cache = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
        else:
            new_cache = {"k": kh, "v": vh}  # (B, Hkv, S, hd); capacity == S
    return out, new_cache


def _axprod(mesh, ref_) -> int:
    if ref_ is None:
        return 1
    if isinstance(ref_, str):
        return mesh.shape[ref_]
    import math

    return math.prod(mesh.shape[a] for a in ref_)


def init_cache(cfg: ModelConfig, kind: str, batch: int, capacity: int,
               dtype) -> Params:
    hd = cfg.head_dim_
    cap = cfg.n_image_tokens if kind == "xattn" else capacity
    shape = (batch, cfg.n_kv_heads, cap, hd)
    if cfg.kv_quant and kind != "xattn":
        # int8 storage + per-(batch, head, position) bf16 scales:
        # hd=128 -> 132 B/position vs 256 B bf16 (~1.9x cache shrink and
        # halved read traffic; EXPERIMENTS.md §Perf #6)
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros((batch, cfg.n_kv_heads, cap, 1),
                                 jnp.bfloat16),
            "v_scale": jnp.zeros((batch, cfg.n_kv_heads, cap, 1),
                                 jnp.bfloat16),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quantize_kv(x: jax.Array):
    """x: (B, Hkv, S, hd) -> (int8 values, (B, Hkv, S, 1) bf16 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)
