"""Mixture-of-Experts layer: top-k router + capacity dispatch.

Dispatch is sort-free capacity bucketing (GShard-style positions computed by
a cumsum over expert one-hots, then a bounded scatter into (E, C, d) buckets),
so the O(N x E x C) one-hot dispatch tensor is never materialized. Expert FFNs
run as one batched einsum over stacked expert weights.

Sharding: expert weights are tensor-sharded over the per-expert hidden dim
("expert_ff" -> model axis) — robust for any expert count (40 experts on a
16-way axis can't expert-shard evenly). When n_experts divides the model axis
an expert-parallel variant ("experts" -> model) turns the bucket constraint
into an all_to_all dispatch; the sharding policy picks per arch.

Load-balancing aux loss (Switch-style) is returned for the trainer.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.api import constrain
from repro.models.layers import dense_init

Params = Dict[str, jax.Array]


def moe_init(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    ff = cfg.d_ff_expert or cfg.d_ff
    E = cfg.n_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    return {
        "router": dense_init(kr, d, E, jnp.float32),  # router math stays f32
        "gate": (jax.random.normal(kg, (E, d, ff), jnp.float32) * scale
                 ).astype(dtype),
        "up": (jax.random.normal(ku, (E, d, ff), jnp.float32) * scale
               ).astype(dtype),
        "down": (jax.random.normal(kd, (E, ff, d), jnp.float32)
                 / math.sqrt(ff)).astype(dtype),
    }


def _dispatch_groups(cfg: ModelConfig, N: int, mode: str) -> int:
    """Dispatch-group count: bucketing is computed independently per group
    so the scatter/gather stays LOCAL to each data shard (GShard-style
    per-group capacity). Without grouping, every token's bucket slot
    depends on a global cumsum and XLA lowers the dispatch to distributed
    scatter/gather — measured at 2.3 TB/device/step of all-reduce +
    collective-permute on mixtral train_4k (EXPERIMENTS.md §Perf #1)."""
    if mode == "decode":
        return 1
    from repro.distributed import api as dapi

    mesh = dapi.current_mesh()
    rules = dapi.current_rules()
    if mesh is None or rules is None:
        return 1
    ref = rules.resolve("batch")
    if ref is None:
        return 1
    axes = (ref,) if isinstance(ref, str) else ref
    g = 1
    for a in axes:
        g *= mesh.shape[a]
    return g if N % g == 0 else 1


def moe_fwd(p: Params, x: jax.Array, cfg: ModelConfig, mode: str = "train"
            ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    Grouped capacity dispatch: tokens are split into G groups (G = the DP
    shard count under a mesh, else 1); each group routes and buckets its
    own tokens with capacity C_g = ceil(N_g*K/E * capacity_factor), so
    dispatch indices never cross a group and the scatter/gather lower to
    purely local ops. Train/prefill use cfg.capacity_factor (token
    dropping under routing skew, as in GShard/Switch); decode uses exact
    no-drop capacity.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    G = _dispatch_groups(cfg, N, mode)
    Ng = N // G
    xt = x.reshape(G, Ng, D)
    xt = constrain(xt, "batch", None, None)

    logits = xt.astype(jnp.float32) @ p["router"]  # (G, Ng, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_v, top_e = jax.lax.top_k(logits, K)  # (G, Ng, K)
    gates = jax.nn.softmax(top_v, axis=-1).astype(x.dtype)

    # Switch aux loss: E * sum_e fraction_tokens_e * mean_prob_e
    assign_onehot = jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32)
    aux = E * jnp.mean(
        jnp.mean(assign_onehot, (0, 1)) * jnp.mean(probs, (0, 1)))

    # ---- per-group capacity bucketing -----------------------------------
    if mode == "decode":
        C = Ng * K  # exact: no drops possible
    else:
        C = int(math.ceil(Ng * K / E * cfg.capacity_factor))
    C = max(8, -(-C // 8) * 8)  # sublane-align
    flat_e = top_e.reshape(G, Ng * K)  # token-major assignment order
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (G, Ng*K, E)
    pos = jnp.cumsum(onehot, axis=1) - 1  # position within expert, per group
    slot = jnp.take_along_axis(pos, flat_e[..., None], 2)[..., 0]
    keep = slot < C

    tok_ids = jnp.arange(Ng * K) // K  # (Ng*K,) group-local
    e_idx = jnp.where(keep, flat_e, E)  # out-of-range rows drop
    s_idx = jnp.where(keep, slot, C)

    def bucketize(xg, eg, sg):  # per group: (Ng,D), (Ng*K,), (Ng*K,)
        b = jnp.zeros((E, C, D), x.dtype)
        # token k-copies are contiguous: xg[tok_ids] == repeat (broadcast +
        # reshape, no gather op)
        xk = jnp.broadcast_to(xg[:, None, :], (Ng, K, D)).reshape(Ng * K, D)
        return b.at[eg, sg].set(xk, mode="drop")

    buckets = jax.vmap(bucketize)(xt, e_idx, s_idx)  # (G, E, C, D)
    buckets = constrain(buckets, "batch", "experts", "cap", None)

    # ---- expert FFN (batched over G, E) ---------------------------------
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buckets, p["gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", buckets, p["up"])
    h = constrain(h, "batch", "experts", "cap", "expert_ff")
    y = jnp.einsum("gecf,efd->gecd", h, p["down"])  # (G, E, C, D)
    # the down-proj contracts the model-sharded ff dim -> its output carries
    # a partial-sum all-reduce; it has batch dims (g, e) so the dots policy
    # will NOT save it — name it so remat keeps the AR result instead of
    # re-firing the collective in the backward (EXPERIMENTS.md §Perf #2)
    from jax.ad_checkpoint import checkpoint_name

    y = checkpoint_name(y, "mixer_out")
    y = constrain(y, "batch", "experts", "cap", None)

    # ---- combine back (group-local gather) ------------------------------
    def degroup(yg, eg, sg, gg):  # (E,C,D), (Ng*K,), (Ng*K,), (Ng*K,)
        rows = yg[eg.clip(0, E - 1), sg.clip(0, C - 1)]  # (Ng*K, D)
        # tok_ids are contiguous K-blocks: segment_sum == reshape + sum —
        # a plain reduce instead of an f32 scatter-add (whose VJP is another
        # gather); measured 5+ TB/step of HBM traffic on granite top-8
        # (EXPERIMENTS.md §Perf #1c)
        return (rows * gg[:, None]).reshape(Ng, K, D).sum(axis=1)

    w = (gates.reshape(G, Ng * K)
         * keep.astype(x.dtype).reshape(G, Ng * K))
    out = jax.vmap(degroup)(y, e_idx, s_idx, w)  # (G, Ng, D)
    return out.reshape(B, S, D).astype(x.dtype), aux
