"""Decoder blocks: one per layer kind, composed by model.py's layer plan.

Kinds:
  attn / local / global   pre-norm self-attention + pre-norm SwiGLU MLP
  moe                     pre-norm self-attention + pre-norm MoE FFN
  ssm                     pre-norm Mamba-2 mixer (+ MLP only if d_ff > 0)
  hybrid                  Hymba: attention and SSM heads in parallel on the
                          same normed input, outputs normed + averaged; + MLP
  xattn                   Llama-Vision gated cross-attention layer + MLP

Every block returns (x, cache', aux) with a cache pytree whose STRUCTURE is
static per kind — required for lax.scan over stacked per-kind params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.api import constrain
from repro.models.attention import attn_fwd, attn_init, init_cache
from repro.models.layers import mlp_fwd, mlp_init, rmsnorm_fwd, rmsnorm_init
from repro.models.moe import moe_fwd, moe_init
from repro.models.ssm import ssm_cache_init, ssm_fwd, ssm_init

Params = Dict[str, Any]


@dataclasses.dataclass
class BlockCtx:
    mode: str  # train | prefill | decode
    positions: Optional[jax.Array] = None  # (B, S)
    lengths: Optional[jax.Array] = None  # (B,)
    image_embeds: Optional[jax.Array] = None  # (B, I, D)


def block_init(key, cfg: ModelConfig, kind: str, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p: Params = {"norm1": rmsnorm_init(d, dtype)}
    if kind in ("attn", "local", "global", "moe", "xattn", "hybrid"):
        p["attn"] = attn_init(ks[0], cfg, dtype)
    if kind == "hybrid":
        p["ssm"] = ssm_init(ks[1], cfg, dtype)
        p["fuse_norm_a"] = rmsnorm_init(d, dtype)
        p["fuse_norm_s"] = rmsnorm_init(d, dtype)
        p["fuse_a"] = jnp.asarray(0.5, jnp.float32)
        p["fuse_s"] = jnp.asarray(0.5, jnp.float32)
    if kind == "ssm":
        p["ssm"] = ssm_init(ks[1], cfg, dtype)
    if kind == "xattn":
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_mlp"] = jnp.zeros((), jnp.float32)
    # FFN sublayer
    if kind == "moe":
        p["norm2"] = rmsnorm_init(d, dtype)
        p["moe"] = moe_init(ks[2], cfg, dtype)
    elif kind == "ssm":
        if cfg.d_ff:
            p["norm2"] = rmsnorm_init(d, dtype)
            p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, dtype)
    else:
        p["norm2"] = rmsnorm_init(d, dtype)
        p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, dtype)
    return p


def block_cache_init(cfg: ModelConfig, kind: str, batch: int, capacity: int,
                     dtype) -> Optional[Params]:
    if kind in ("attn", "local", "global", "moe", "xattn"):
        return {"attn": init_cache(cfg, kind, batch, capacity, dtype)}
    if kind == "ssm":
        return {"ssm": ssm_cache_init(cfg, batch, dtype)}
    if kind == "hybrid":
        return {
            "attn": init_cache(cfg, kind, batch, capacity, dtype),
            "ssm": ssm_cache_init(cfg, batch, dtype),
        }
    raise ValueError(kind)


def block_fwd(
    p: Params,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    kind: str,
    ctx: BlockCtx,
    cache: Optional[Params] = None,
) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    eps = cfg.norm_eps
    aux = jnp.zeros((), jnp.float32)
    x = constrain(x, "batch", "seq", None)

    # ---------------------------------------------------------- mixer(s)
    h = rmsnorm_fwd(p["norm1"], x, eps)
    new_cache: Optional[Params] = None

    if kind in ("attn", "local", "global", "moe"):
        a, c_attn = attn_fwd(
            p["attn"], h, cfg=cfg, kind=kind, mode=ctx.mode,
            positions=ctx.positions, lengths=ctx.lengths,
            cache=cache.get("attn") if cache else None,
        )
        x = x + a
        if c_attn is not None:
            new_cache = {"attn": c_attn}
    elif kind == "xattn":
        a, c_attn = attn_fwd(
            p["attn"], h, cfg=cfg, kind=kind, mode=ctx.mode,
            positions=ctx.positions, lengths=ctx.lengths,
            cache=cache.get("attn") if cache else None,
            kv_src=ctx.image_embeds,
        )
        x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * a
        if c_attn is not None:
            new_cache = {"attn": c_attn}
    elif kind == "ssm":
        s, c_ssm = ssm_fwd(p["ssm"], h, cfg=cfg, mode=ctx.mode,
                           cache=cache.get("ssm") if cache else None,
                           lengths=ctx.lengths)
        x = x + s
        if c_ssm is not None:
            new_cache = {"ssm": c_ssm}
    elif kind == "hybrid":
        a, c_attn = attn_fwd(
            p["attn"], h, cfg=cfg, kind="attn", mode=ctx.mode,
            positions=ctx.positions, lengths=ctx.lengths,
            cache=cache.get("attn") if cache else None,
        )
        s, c_ssm = ssm_fwd(p["ssm"], h, cfg=cfg, mode=ctx.mode,
                           cache=cache.get("ssm") if cache else None,
                           lengths=ctx.lengths)
        fused = (
            p["fuse_a"].astype(jnp.float32)
            * rmsnorm_fwd(p["fuse_norm_a"], a, eps).astype(jnp.float32)
            + p["fuse_s"].astype(jnp.float32)
            * rmsnorm_fwd(p["fuse_norm_s"], s, eps).astype(jnp.float32)
        ).astype(x.dtype)
        x = x + fused
        if c_attn is not None or c_ssm is not None:
            new_cache = {"attn": c_attn, "ssm": c_ssm}
    else:
        raise ValueError(kind)

    # ------------------------------------------------------------- FFN
    if "moe" in p:
        h2 = rmsnorm_fwd(p["norm2"], x, eps)
        m, aux = moe_fwd(p["moe"], h2, cfg, mode=ctx.mode)
        x = x + m
    elif "mlp" in p:
        h2 = rmsnorm_fwd(p["norm2"], x, eps)
        m = mlp_fwd(p["mlp"], h2)
        if kind == "xattn":
            m = jnp.tanh(p["gate_mlp"]).astype(x.dtype) * m
        x = x + m

    x = constrain(x, "batch", "seq", None)
    return x, new_cache, aux
