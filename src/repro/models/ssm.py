"""Mamba-2 (SSD) block inner: in_proj -> causal conv -> SSD -> gated norm -> out.

Follows arXiv:2405.21060: the projection produces (z, x, B, C, dt); the short
causal depthwise conv runs over (x, B, C); the selective scan is the chunked
SSD from kernels/ (Pallas intra-chunk on no-grad paths, jnp ref when
differentiating); output is RMSNorm(y * silu(z)) @ out_proj.

Decode carries two states: the conv window (conv_w-1 last inputs) and the
(H, N, P) SSM state — both O(1) in sequence length, which is what makes the
ssm/hybrid archs long_500k-runnable.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models.layers import dense_init, rmsnorm_fwd

Params = Dict[str, jax.Array]


def _dims(cfg: ModelConfig):
    di = cfg.ssm_inner
    G, N, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_head_dim
    H = di // P
    conv_ch = di + 2 * G * N
    return di, G, N, P, H, conv_ch


def ssm_init(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    di, G, N, P, H, conv_ch = _dims(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(k1, d, 2 * di + 2 * G * N + H, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm_conv, conv_ch), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(k3, di, d, dtype),
    }


def _split(zxbcdt: jax.Array, cfg: ModelConfig):
    di, G, N, P, H, _ = _dims(cfg)
    z, xin, bm, cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1
    )
    return z, xin, bm, cm, dt


def _causal_conv(conv_in: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with taps w: (cw, C)."""
    cw = w.shape[0]
    pad = jnp.pad(conv_in, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(
        pad[:, i: i + conv_in.shape[1], :] * w[i][None, None, :]
        for i in range(cw)
    )
    return jax.nn.silu(out + b[None, None, :])


def ssm_fwd(
    p: Params,
    x: jax.Array,  # (B, S, D)
    *,
    cfg: ModelConfig,
    mode: str,
    cache: Optional[Params] = None,
    lengths: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Params]]:
    di, G, N, P, H, conv_ch = _dims(cfg)
    B, S, _ = x.shape
    A = -jnp.exp(p["A_log"])  # (H,) negative

    if mode == "decode":
        assert cache is not None
        zxbcdt = x @ p["in_proj"]  # (B, 1, ...)
        z, xin, bm, cm, dt = _split(zxbcdt, cfg)
        conv_in = jnp.concatenate([xin, bm, cm], axis=-1)  # (B, 1, conv_ch)
        win = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B, cw, ch)
        cw = p["conv_w"].shape[0]
        conv_out = jax.nn.silu(
            (win * p["conv_w"][None]).sum(axis=1) + p["conv_b"][None]
        )  # (B, conv_ch)
        xin, bm, cm = jnp.split(conv_out, [di, di + G * N], axis=-1)
        dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
        dta = dtv * A[None]
        xh = xin.reshape(B, H, P)
        state, y = ops.ssd_decode_step(
            cache["ssd"], xh, bm.reshape(B, G, N), cm.reshape(B, G, N),
            dta, dtv,
        )
        y = y + p["D"][None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(B, 1, di).astype(x.dtype)
        y = rmsnorm_fwd(p["norm_w"], y * jax.nn.silu(z), cfg.norm_eps)
        return y @ p["out_proj"], {"conv": win[:, 1:], "ssd": state}

    # ----------------------------------------------------- train / prefill
    zxbcdt = x @ p["in_proj"]
    z, xin, bm, cm, dt = _split(zxbcdt, cfg)
    conv_in = jnp.concatenate([xin, bm, cm], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xin, bm, cm = jnp.split(conv_out, [di, di + G * N], axis=-1)

    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, S, H)
    chunk = min(cfg.ssm_chunk, S)
    pad_s = (-S) % chunk
    if pad_s:
        # dt = 0 on padding => decay 1, contribution 0: state stays exact
        dtv = jnp.pad(dtv, ((0, 0), (0, pad_s), (0, 0)))
        xin = jnp.pad(xin, ((0, 0), (0, pad_s), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad_s), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad_s), (0, 0)))
    Sp = S + pad_s
    dta = dtv * A[None, None, :]
    xh = xin.reshape(B, Sp, H, P)

    # SSD intra-chunk work is embarrassingly parallel over sequence chunks;
    # the head count (e.g. 24) rarely divides the model axis, so carry the
    # model axis on seq ("heads" would replicate) — the tiny inter-chunk
    # state scan is the only cross-shard dependency (§Perf #3)
    from repro.distributed.api import constrain as _constrain

    xh = _constrain(xh, "batch", "seq_q", "heads", None)
    bmr = _constrain(bm.reshape(B, Sp, G, N), "batch", "seq_q", None, None)
    cmr = _constrain(cm.reshape(B, Sp, G, N), "batch", "seq_q", None, None)
    use_kernel = cfg.use_flash and mode != "train"  # kernel fwd-only
    y, final_state = ops.ssd(
        xh, bmr, cmr, dta, dtv,
        chunk=chunk, use_kernel=use_kernel,
    )
    y = y.astype(jnp.float32) + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, Sp, di)[:, :S].astype(x.dtype)
    y = rmsnorm_fwd(p["norm_w"], y * jax.nn.silu(z), cfg.norm_eps)
    out = y @ p["out_proj"]

    new_cache = None
    if mode == "prefill":
        cw = p["conv_w"].shape[0]
        tail = conv_in[:, S - (cw - 1): S, :] if S >= cw - 1 else jnp.pad(
            conv_in, ((0, 0), (cw - 1 - S, 0), (0, 0))
        )
        new_cache = {"conv": tail, "ssd": final_state}
    return out, new_cache


def ssm_cache_init(cfg: ModelConfig, batch: int, dtype) -> Params:
    di, G, N, P, H, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "ssd": jnp.zeros((batch, H, N, P), jnp.float32),
    }
