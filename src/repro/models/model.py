"""Top-level model: embedding -> scanned layer groups -> norm -> LM head.

Layer groups come from ModelConfig.layer_plan(): each group is a repeated
block of per-kind sub-layers and lowers as ONE lax.scan over stacked params,
so HLO size is O(#groups), not O(#layers) — llama-90B compiles as a 20-step
scan of 5 sub-layers. Train mode remats each scan body (per-layer-block
activation checkpointing).

Forward modes return:
  train    (logits-or-loss-inputs path) hidden states; loss() computes CE,
           optionally chunked over sequence for 256k-vocab heads
  prefill  (logits_last, caches)
  decode   (logits, caches') — one token
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.api import constrain
from repro.models.blocks import BlockCtx, block_cache_init, block_fwd, block_init
from repro.models.layers import _dtype, embed_init, rmsnorm_fwd, rmsnorm_init

Params = Dict[str, Any]


def _cast_group(params: Any, act_dtype) -> Any:
    """Mixed precision: weight MATRICES compute in the activation dtype
    (bf16 on the MXU); vectors/scalars (norms, A_log, dt_bias, gates) and the
    MoE router stay in storage dtype (f32 master copies live in the
    optimizer)."""

    def leaf(path, w):
        name = str(getattr(path[-1], "key", getattr(path[-1], "name", "")))
        if name == "router":
            return w
        if hasattr(w, "ndim") and w.ndim >= 2 and jnp.issubdtype(
            w.dtype, jnp.floating
        ):
            return w.astype(act_dtype)
        return w

    return jax.tree_util.tree_map_with_path(leaf, params)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan = cfg.layer_plan()

    # ------------------------------------------------------------- init

    def init(self, key) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        keys = jax.random.split(key, len(self.plan) + 2)
        params: Params = {}
        if not cfg.embed_inputs or cfg.tie_embeddings:
            params["embed"] = embed_init(keys[0], cfg.vocab, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            params["head"] = embed_init(keys[1], cfg.vocab, cfg.d_model, dtype).T
        params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)

        for gi, (kinds, reps) in enumerate(self.plan):
            gkey = keys[2 + gi]

            def init_block_seq(k):
                ks = jax.random.split(k, len(kinds))
                return {
                    f"sub{i}": block_init(ks[i], cfg, kind, dtype)
                    for i, kind in enumerate(kinds)
                }

            params[f"group{gi}"] = jax.vmap(init_block_seq)(
                jax.random.split(gkey, reps)
            )
        return params

    def init_caches(self, batch: int, capacity: int) -> List[Any]:
        cfg = self.cfg
        dtype = _dtype(cfg.dtype)
        caches = []
        for kinds, reps in self.plan:
            per_sub = tuple(
                jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (reps,) + x.shape),
                    block_cache_init(cfg, kind, batch, capacity, dtype),
                )
                for kind in kinds
            )
            caches.append(per_sub)
        return caches

    # ---------------------------------------------------------- forward

    def _embed(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        if cfg.embed_inputs:
            x = batch["embeds"]
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        return constrain(x.astype(_dtype(cfg.dtype)), "batch", "seq", None)

    def _head(self, params: Params, x: jax.Array) -> jax.Array:
        cfg = self.cfg
        w = params["embed"].T if cfg.tie_embeddings else params["head"]
        logits = x.astype(jnp.float32) @ w.astype(jnp.float32)
        return constrain(logits, "batch", "seq", "vocab")

    def forward(
        self,
        params: Params,
        batch: Dict[str, jax.Array],
        *,
        mode: str,
        lengths: Optional[jax.Array] = None,
        caches: Optional[List[Any]] = None,
    ) -> Tuple[jax.Array, Optional[List[Any]], jax.Array]:
        """Returns (hidden, caches', aux_loss). hidden: (B, S, D) post-norm."""
        cfg = self.cfg
        x = self._embed(params, batch)
        B, S, _ = x.shape
        if mode == "decode":
            assert lengths is not None
            positions = lengths[:, None]
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        ctx = BlockCtx(
            mode=mode,
            positions=positions,
            lengths=lengths,
            image_embeds=batch.get("image_embeds"),
        )

        aux = jnp.zeros((), jnp.float32)
        new_caches: Optional[List[Any]] = [] if mode != "train" else None
        act = _dtype(cfg.dtype)
        for gi, (kinds, reps) in enumerate(self.plan):
            gp = _cast_group(params[f"group{gi}"], act)
            gc = caches[gi] if caches is not None else None

            def body(carry, xs, kinds=kinds):
                xc, auxc = carry
                if gc is not None:
                    p_blk, cache_blk = xs
                else:
                    p_blk, cache_blk = xs, None
                outs = []
                for i, kind in enumerate(kinds):
                    xc, c_new, a = block_fwd(
                        p_blk[f"sub{i}"], xc, cfg=cfg, kind=kind, ctx=ctx,
                        cache=cache_blk[i] if cache_blk is not None else None,
                    )
                    outs.append(c_new)
                    auxc = auxc + a
                ys = tuple(outs) if mode != "train" else None
                return (xc, auxc), ys

            if mode == "train":
                # save MXU dots AND the named mixer outputs: the latter sit
                # downstream of TP partial-sum all-reduces, so saving them
                # keeps remat from re-firing collectives in the backward
                # (EXPERIMENTS.md §Perf #2)
                body = jax.checkpoint(
                    body,
                    policy=jax.checkpoint_policies.save_from_both_policies(
                        jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                        jax.checkpoint_policies.save_only_these_names(
                            "mixer_out"),
                    ),
                )
            xs = (gp, gc) if gc is not None else gp
            (x, aux), ys = jax.lax.scan(body, (x, aux), xs)
            if new_caches is not None:
                new_caches.append(ys)

        x = rmsnorm_fwd(params["final_norm"], x, cfg.norm_eps)
        return x, new_caches, aux

    # -------------------------------------------------------------- loss

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        """Mean next-token cross entropy (labels pre-shifted by the pipeline),
        plus 0.01 x MoE aux loss."""
        cfg = self.cfg
        hidden, _, aux = self.forward(params, batch, mode="train")
        labels = batch["labels"]  # (B, S) int32
        B, S, D = hidden.shape
        chunk = cfg.loss_chunk if cfg.loss_chunk and S % cfg.loss_chunk == 0 else S

        def ce_of(h, y):  # h (B, c, D), y (B, c)
            logits = self._head(params, h)
            lse = jax.nn.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
            return (lse - ll).sum()

        if chunk == S:
            total = ce_of(hidden, labels)
        else:
            hc = hidden.reshape(B, S // chunk, chunk, D).transpose(1, 0, 2, 3)
            yc = labels.reshape(B, S // chunk, chunk).transpose(1, 0, 2)

            def body(acc, xs):
                h, y = xs
                return acc + ce_of(h, y), None

            total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, yc))
        nll = total / (B * S)
        return nll + 0.01 * aux

    def logits(self, params: Params, hidden: jax.Array) -> jax.Array:
        return self._head(params, hidden)

    # ------------------------------------------------------- serve steps

    def prefill(self, params: Params, batch: Dict[str, jax.Array]):
        """Run the full prompt; returns (last-token logits, caches)."""
        hidden, caches, _ = self.forward(params, batch, mode="prefill")
        logits = self._head(params, hidden[:, -1:, :])
        return logits[:, 0], caches

    def decode_step(
        self,
        params: Params,
        token_batch: Dict[str, jax.Array],  # tokens/embeds of ONE position
        lengths: jax.Array,
        caches: List[Any],
    ):
        hidden, caches, _ = self.forward(
            params, token_batch, mode="decode", lengths=lengths, caches=caches
        )
        logits = self._head(params, hidden)
        return logits[:, 0], caches


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
