"""Model zoo: config-driven decoder stacks (dense/moe/ssm/hybrid/audio/vlm)."""
from repro.models.model import Model, build_model  # noqa: F401
