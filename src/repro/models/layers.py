"""Shared model layers: norms, RoPE, MLP, embeddings.

Functional style: ``init_*`` returns a param pytree (plain dicts); ``*_fwd``
applies it. Params carry logical sharding metadata via init-time constraint
application in model.py (param specs are declared in distributed/sharding.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.api import constrain


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jax.Array:
    scale = 1.0 / jnp.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
            ).astype(dtype)


# ------------------------------------------------------------------ RMSNorm


def rmsnorm_init(dim: int, dtype) -> jax.Array:
    return jnp.ones((dim,), dtype)


def rmsnorm_fwd(w: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------- RoPE


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, half-rotation convention.

    x: (B, S, H, D_head), positions: (B, S) absolute token positions.
    """
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- SwiGLU MLP


def mlp_init(key, d_model: int, d_ff: int, dtype) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "gate": dense_init(kg, d_model, d_ff, dtype),
        "up": dense_init(ku, d_model, d_ff, dtype),
        "down": dense_init(kd, d_ff, d_model, dtype),
    }


def mlp_fwd(p: dict, x: jax.Array) -> jax.Array:
    """x: (B, S, D). TP: gate/up column-sharded, down row-sharded (the
    constraint on the hidden activation makes XLA's choice explicit). The
    seq dim is deliberately unnamed: under sequence parallelism the stream
    is gathered over seq INSIDE the block, and "model" carries ff here."""
    h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    h = constrain(h, "batch", None, "ff")
    # row-parallel down-proj produces model-axis partial sums; constraining
    # the output to the seq-sharded residual layout HERE lets XLA lower the
    # reduction as reduce-scatter instead of all-reduce + slice (§Perf #4)
    return constrain(h @ p["down"], "batch", "seq", None)
