"""Elastic scaling + failure handling around the checkpointer.

Elasticity: checkpoints store full logical arrays, so restoring onto a
different mesh is re-placement, not re-layout — `reshard_restore` takes the
NEW policy's shardings and puts every leaf straight onto the new mesh. A job
that loses a pod restarts on (16,16) from a (2,16,16) checkpoint unchanged;
the data pipeline re-slices its stream from the restored step integer.

Failure drill: `FailureInjector` raises a SimulatedFailure at a chosen step;
`run_with_restarts` restarts the loop from the latest checkpoint. Tests
assert bit-identical final params vs an uninterrupted run — the
checkpoint/restart path provably loses nothing.

Straggler mitigation at scale (documented design, exercised in tests via the
overlap runtime): per-step work is overdecomposed (microbatches / Task Bench
points per device) so a slow participant delays only its own slice;
double-buffered input feeds + async checkpoint writes keep the critical path
free of host hiccups.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

from repro.checkpoint.checkpointer import Checkpointer
from repro.resilience.faults import InjectedFault


class SimulatedFailure(InjectedFault):
    """Whole-process node death (the coarse fault class this module
    recovers from; intra-run fault classes live in repro.resilience)."""


class FailureInjector:
    """Raises at the START of the given step indices (post-checkpoint)."""

    def __init__(self, fail_at: Tuple[int, ...] = ()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


def run_with_restarts(
    *,
    total_steps: int,
    ckpt: Checkpointer,
    ckpt_every: int,
    init_state: Callable[[], Any],
    step_fn: Callable[[Any, int], Any],
    injector: Optional[FailureInjector] = None,
    max_restarts: int = 8,
    extra_state: Optional[Dict] = None,
) -> Tuple[Any, int]:
    """Generic fault-tolerant loop: state -> step_fn -> state, checkpointing
    every `ckpt_every` and restarting from the latest checkpoint on failure.

    Returns (final_state, restarts_used). `state` is any pytree; step 0's
    state comes from init_state() or the latest checkpoint if one exists.

    A checkpoint that fails its content checksum (or is otherwise
    unreadable) is not fatal: restore walks BACKWARD through the retained
    steps until one verifies, and restarts from there — only if every
    retained checkpoint is corrupt does the loop fall back to step 0.
    """
    restarts = 0
    while True:
        state, start = None, 0
        for candidate in reversed(ckpt.all_steps()):
            try:
                state, _ = ckpt.restore(init_state(), step=candidate)
                start = candidate
                break
            except ValueError:
                continue  # corrupt/truncated: try the previous good one
        if state is None:
            state, start = init_state(), 0
        try:
            for step in range(start, total_steps):
                if injector is not None:
                    injector.maybe_fail(step)
                state = step_fn(state, step)
                nxt = step + 1
                if nxt % ckpt_every == 0 or nxt == total_steps:
                    ckpt.save(nxt, state, extra_state)
            ckpt.wait() if hasattr(ckpt, "wait") else None
            return state, restarts
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise


def reshard_restore(ckpt: Checkpointer, target: Any, policy) -> Tuple[Any, Dict]:
    """Restore the latest checkpoint onto the mesh described by `policy`
    (any shape — this is the elastic-scaling entry point)."""
    shardings = policy.param_shardings(target)
    return ckpt.restore(target, shardings=shardings)
