"""Atomic, async-capable pytree checkpointing (no orbax in this container).

Layout: <dir>/step_<n>/arrays.npz + manifest.json (tree structure, dtypes,
pipeline + RNG state), written to a tmp dir and atomically renamed — a
half-written checkpoint can never be restored. `keep` bounds disk usage;
`async_save` runs serialization on a worker thread so the train loop only
pays for the host transfer.

Restore targets an ABSTRACT tree (structure + ShapeDtypeStruct) so arrays
can be placed directly onto any mesh sharding — this is what makes restarts
elastic: a checkpoint written on a (2,16,16) mesh restores onto (16,16) or a
single CPU device unchanged (see elastic.py).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

#: manifest checksum algorithm (content digest of arrays.npz)
CHECKSUM_ALGO = "sha256"


def _file_digest(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _flatten(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- save

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None) -> str:
        host_tree = jax.tree.map(np.asarray, tree)  # device -> host
        return self._write(step, host_tree, extra or {})

    def async_save(self, step: int, tree: Any,
                   extra: Optional[Dict] = None) -> None:
        self.wait()  # one in flight at a time
        host_tree = jax.tree.map(np.asarray, tree)  # transfer on caller
        self._thread = threading.Thread(
            target=self._write, args=(step, host_tree, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree: Any, extra: Dict) -> str:
        flat, _ = _flatten(host_tree)
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        arrays_path = os.path.join(tmp, "arrays.npz")
        np.savez(arrays_path, **{k: v for k, v in flat})
        manifest = {
            "step": step,
            "keys": [k for k, _ in flat],
            "extra": extra,
            # content digest: restore refuses a checkpoint whose bytes
            # don't match what save() published (bit rot, torn copy)
            "checksum": {"algo": CHECKSUM_ALGO,
                         "digest": _file_digest(arrays_path)},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
        """target: pytree of arrays or ShapeDtypeStructs (structure donor)."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        arrays_path = os.path.join(path, "arrays.npz")
        # verify content digest BEFORE deserializing (pre-checksum
        # manifests — no "checksum" key — restore as before)
        recorded = manifest.get("checksum")
        if recorded is not None:
            actual = _file_digest(arrays_path)
            if actual != recorded["digest"]:
                raise ValueError(
                    f"corrupt checkpoint {arrays_path}: "
                    f"{recorded['algo']} digest {actual} != recorded "
                    f"{recorded['digest']}")
        try:
            data = np.load(arrays_path)
        except Exception as e:
            raise ValueError(
                f"corrupt checkpoint {arrays_path}: unreadable npz "
                f"({e})") from e
        flat, treedef = _flatten(target)
        sh_flat = (_flatten(shardings)[0] if shardings is not None
                   else [(k, None) for k, _ in flat])
        leaves = []
        for (key, tgt), (_, sh) in zip(flat, sh_flat):
            arr = data[key]
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"checkpoint leaf {key}: shape {arr.shape} != {tgt.shape}"
                )
            arr = arr.astype(tgt.dtype)
            leaves.append(jax.device_put(arr, sh) if sh is not None else
                          jax.device_put(arr))
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        return tree, manifest["extra"]
