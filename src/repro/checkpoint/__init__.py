"""checkpoint substrate."""
