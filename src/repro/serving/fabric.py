"""The continuous-batching fabric: cohort lifecycle over launch plans.

One cohort = one ``EnsembleLaunchPlan`` whose (K, S) act-mask slots serve
MANY requests over time. At every launch boundary the fabric

  1. retires slots with no remaining active work (snapshot the member's
     final state, record completion),
  2. evicts slots past their deadline (zero the slot's act rows from this
     launch on — the PR 8 eviction edit — and record the frozen step),
  3. re-admits queued compatible requests into freed slots via the plan's
     ``admit_fn`` (stacked cohorts only: their operand tables are
     time-invariant and shared across slots by the packer's cohort key,
     so a fresh member's t=0 state is the only thing that changes), and
  4. dispatches the launch, feeding the wall to a DeadlineDetector whose
     post-membership-change walls are recompile-boundary-skipped.

No recompile across membership churn: launch shapes never change (only
mask/state VALUES do), which the plan's ``compile_counter`` asserts.

Bit-identity: every request's output must equal "serial execution of the
same seeded request". The exact oracle is the SAME-K uniform ensemble —
``execute_ensemble(GraphEnsemble((graph,) * K))[slot]`` with the
request's effective steps — because the megakernel's reduction lowering
is shape-dependent (K=1 vs K=2 differ in final-ulp rounding at S=1) but
value-independent across slots (each member's rows depend only on its own
slice; the packer guarantees identical operand tables). This is the same
same-K convention test_chaos_property.py's eviction oracle uses.

Clocks: the fabric is generic over a clock so the hypothesis property
suite can run DETERMINISTICALLY. ``WallClock`` is real time (the driver's
latency numbers); ``LaunchClock`` is virtual time advancing 1.0 per
dispatched launch, making arrival/deadline interleavings a pure function
of the request list.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import GraphEnsemble, TaskGraph
from repro.core.task_kernels import initial_state
from repro.kernels import schedule as _schedule
from repro.resilience.detect import DeadlineDetector
from repro.serving.packer import cohort_key, order_key
from repro.serving.request import Request


class WallClock:
    """Real elapsed seconds since construction. Launches advance it by
    themselves; waiting sleeps."""

    def __init__(self) -> None:
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def advance_launch(self) -> None:
        pass  # real time already passed during the launch

    def wait_until(self, t: float) -> None:
        delta = t - self.now()
        if delta > 0:
            time.sleep(delta)

    def launch_unit_s(self, lp, detector: DeadlineDetector
                      ) -> Optional[float]:
        """Expected seconds per launch: the measured cost model's pricing
        when the plan carries one, else the detector's self-calibrated
        median (deadline / factor), else unpriceable."""
        if lp.expected_launch_us:
            return lp.expected_launch_us * 1e-6
        d = detector.deadline_us()
        if d is not None:
            return (d / detector.factor) * 1e-6
        return None


class LaunchClock:
    """Virtual clock: time is a launch count. Every dispatched launch
    costs exactly 1.0, so arrival/retire/admit interleavings — and
    priced deadlines — are deterministic functions of the request list
    (the property suite's requirement)."""

    def __init__(self) -> None:
        self._t = 0.0

    def now(self) -> float:
        return self._t

    def advance_launch(self) -> None:
        self._t += 1.0

    def wait_until(self, t: float) -> None:
        self._t = max(self._t, t)

    def launch_unit_s(self, lp, detector: DeadlineDetector
                      ) -> Optional[float]:
        del lp, detector
        return 1.0


@dataclasses.dataclass
class RequestOutcome:
    """One request's fate through the fabric."""

    rid: int
    status: str  # "completed" | "deadline_evicted"
    effective_steps: int  # steps actually executed (== T unless evicted)
    arrival_s: float
    admitted_s: float
    finished_s: float
    cohort: int
    slot: int
    admitted_mid_run: bool
    deadline_s: Optional[float]
    graph: Optional[TaskGraph] = None  # what ran (oracle input)
    bit_identical: Optional[bool] = None  # None until verified
    output: Optional[np.ndarray] = None

    @property
    def latency_s(self) -> float:
        return self.finished_s - self.arrival_s


@dataclasses.dataclass
class CohortReport:
    """One cohort's census: what ran, how it churned, whether the
    no-recompile contract held."""

    index: int
    key: str
    kind: str  # EnsembleLaunchPlan.kind: "stacked" | "stepwise"
    reason: str  # stacking_verdict's reason string
    slots: int
    steps_per_launch: int
    launches_run: int
    requests: int
    admitted_mid_run: int
    deadline_evictions: int
    membership_changes: int  # retire-then-readmit + evictions
    recompiles: Optional[int]  # launch-cache growth after 1st launch
    slot_utilization: float  # active-slot-launches / (K * launches_run)


@dataclasses.dataclass
class ServeReport:
    outcomes: List[RequestOutcome]
    cohorts: List[CohortReport]
    wall_s: float

    @property
    def completed(self) -> List[RequestOutcome]:
        return [o for o in self.outcomes if o.status == "completed"]

    @property
    def bit_identical(self) -> Optional[bool]:
        """True when every verified request matched its serial oracle;
        None when verification was off."""
        verdicts = [o.bit_identical for o in self.outcomes
                    if o.bit_identical is not None]
        if not verdicts:
            return None
        return all(verdicts)

    def latency_percentiles_s(self, qs=(50, 95, 99)) -> Dict[str, float]:
        lats = [o.latency_s for o in self.completed]
        if not lats:
            return {f"p{q}": float("nan") for q in qs}
        return {f"p{q}": float(np.percentile(lats, q)) for q in qs}


@dataclasses.dataclass
class _Slot:
    req: Request
    l0: int  # launch index of admission (0 for cohort founders)
    admitted_s: float
    deadline_s: Optional[float]
    mid_run: bool


class ServingFabric:
    """Continuous-batching executor over one runtime.

    ``runtime`` must expose ``build_ensemble_launches`` /
    ``stacking_verdict`` / ``plan_for`` (pallas_step). ``max_slots`` is K
    per cohort; ``deadline_factor`` scales priced deadlines (the PR 6
    DEADLINE_FACTOR convention: deadline = factor x expected service);
    ``verify=True`` checks every outcome against its serial same-K oracle
    after serving (compile-heavy — tests and --smoke only)."""

    def __init__(self, runtime, *, max_slots: int = 4,
                 deadline_factor: float = _schedule.DEADLINE_FACTOR,
                 verify: bool = False, clock=None):
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        self.runtime = runtime
        self.max_slots = int(max_slots)
        self.deadline_factor = float(deadline_factor)
        self.verify = bool(verify)
        self.clock = clock if clock is not None else WallClock()
        self._oracle_cache: Dict[Tuple, np.ndarray] = {}

    # ------------------------------------------------------------- serving

    def serve(self, requests: List[Request]) -> ServeReport:
        """Run every request to completion (or deadline eviction)."""
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("request rids must be unique")
        pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
        outcomes: List[RequestOutcome] = []
        cohorts: List[CohortReport] = []
        t_start = time.perf_counter()
        while pending:
            now = self.clock.now()
            ready = [r for r in pending if r.arrival_s <= now]
            if not ready:
                self.clock.wait_until(min(r.arrival_s for r in pending))
                continue
            ready.sort(key=order_key)
            key = cohort_key(self.runtime, ready[0].graph)
            batch = [r for r in ready
                     if cohort_key(self.runtime, r.graph) == key]
            batch = batch[: self.max_slots]
            for r in batch:
                pending.remove(r)
            cohorts.append(self._run_cohort(
                len(cohorts), key, batch, pending, outcomes))
        wall_s = time.perf_counter() - t_start
        if self.verify:
            self._verify(outcomes, cohorts)
        return ServeReport(outcomes=outcomes, cohorts=cohorts,
                           wall_s=wall_s)

    # -------------------------------------------------------------- cohort

    def _run_cohort(self, index: int, key, batch: List[Request],
                    pending: List[Request],
                    outcomes: List[RequestOutcome]) -> CohortReport:
        import jax
        import jax.numpy as jnp

        rt = self.runtime
        ens = GraphEnsemble(tuple(r.graph for r in batch))
        ok, reason = rt.stacking_verdict(ens)
        lp = rt.build_ensemble_launches(ens)
        stacked = lp.kind == "stacked"
        K = len(batch)
        S = lp.steps_per_launch
        acts = np.array(lp.acts, copy=True)
        detector = DeadlineDetector(factor=self.deadline_factor,
                                    expected_us=lp.expected_launch_us)
        # the cohort's first launch carries its compile
        detector.note_recompile_boundary()
        now = self.clock.now()
        slots: List[Optional[_Slot]] = [
            _Slot(req=r, l0=0, admitted_s=now,
                  deadline_s=self._price_deadline(r, lp, detector, S),
                  mid_run=False)
            for r in batch
        ]
        carry = jax.block_until_ready(lp.init_fn(rt._ensemble_inits(ens)))
        membership_changes = 0
        admitted_mid_run = 0
        deadline_evictions = 0
        launches_run = 0
        util_active = 0
        compile_base: Optional[int] = None
        served = len(batch)

        def snapshot(slot: int) -> np.ndarray:
            return np.array(np.asarray(lp.finalize(carry)[slot]), copy=True)

        def close(slot: int, status: str, eff: int) -> None:
            st = slots[slot]
            outcomes.append(RequestOutcome(
                rid=st.req.rid, status=status, effective_steps=eff,
                arrival_s=st.req.arrival_s, admitted_s=st.admitted_s,
                finished_s=self.clock.now(), cohort=index, slot=slot,
                admitted_mid_run=st.mid_run, deadline_s=st.deadline_s,
                graph=st.req.graph, output=snapshot(slot)))
            slots[slot] = None

        l = 0
        while l < acts.shape[0]:
            now = self.clock.now()
            # 1. retire slots whose remaining schedule is empty
            for slot in range(K):
                st = slots[slot]
                if st is not None and not acts[l:, slot, :].any():
                    close(slot, "completed", st.req.graph.steps)
            # 2. deadline-miss evictions (the act-mask freeze: zero the
            # slot's rows from this launch on; state stays at the frozen
            # step, exactly the engine's _evict edit)
            for slot in range(K):
                st = slots[slot]
                if (st is not None and st.deadline_s is not None
                        and now > st.deadline_s):
                    frozen = int(min(st.req.graph.steps,
                                     1 + (l - st.l0) * S))
                    acts[l:, slot, :] = 0.0
                    deadline_evictions += 1
                    membership_changes += 1
                    detector.note_recompile_boundary()
                    close(slot, "deadline_evicted", frozen)
            # 3. re-admit queued compatible requests into freed slots.
            # Stacked plans only: their tables are time-invariant and
            # slot-uniform, so admit_fn's fresh t=0 rows are sound at any
            # boundary; stepwise plans are time-indexed — fixed membership.
            if stacked and lp.admit_fn is not None:
                free = [k for k in range(K) if slots[k] is None]
                if free:
                    queue = sorted(
                        (r for r in pending
                         if r.arrival_s <= now
                         and cohort_key(rt, r.graph) == key),
                        key=order_key)
                    for r, slot in zip(queue, free):
                        acts = self._admit_acts(acts, l, slot, r.graph, S)
                        init = initial_state(r.graph.width,
                                             r.graph.payload, r.graph.seed)
                        carry = jax.block_until_ready(
                            lp.admit_fn(carry, slot, jnp.asarray(init)))
                        pending.remove(r)
                        slots[slot] = _Slot(
                            req=r, l0=l, admitted_s=now,
                            deadline_s=self._price_deadline(
                                r, lp, detector, S),
                            mid_run=True)
                        served += 1
                        admitted_mid_run += 1
                        membership_changes += 1
                        detector.note_recompile_boundary()
            # 4. done? (all remaining act rows dead and nothing admitted)
            if not acts[l:].any():
                break
            # 5. dispatch (an all-zero act row is a semantic no-op — the
            # mask freezes every slot — so skip it without dispatching)
            if acts[l].any():
                t1 = time.perf_counter()
                carry = jax.block_until_ready(lp.launch_fn(
                    carry, jnp.asarray(acts[l]),
                    jnp.asarray(lp.launch_t0(l), jnp.int32)))
                detector.observe((time.perf_counter() - t1) * 1e6)
                launches_run += 1
                util_active += int((acts[l] > 0).any(axis=-1).sum())
                if compile_base is None and lp.compile_counter is not None:
                    compile_base = int(lp.compile_counter())
                self.clock.advance_launch()
            l += 1
        for slot in range(K):
            if slots[slot] is not None:
                close(slot, "completed", slots[slot].req.graph.steps)
        recompiles: Optional[int] = None
        if compile_base is not None:
            recompiles = int(lp.compile_counter()) - compile_base
            if recompiles:
                raise RuntimeError(
                    f"cohort {index}: launch executable recompiled "
                    f"{recompiles}x across membership churn — the "
                    f"no-recompile contract of act-mask evict/admit is "
                    f"broken (shapes must be membership-invariant)")
        return CohortReport(
            index=index, key=repr(key), kind=lp.kind, reason=reason,
            slots=K, steps_per_launch=S, launches_run=launches_run,
            requests=served, admitted_mid_run=admitted_mid_run,
            deadline_evictions=deadline_evictions,
            membership_changes=membership_changes,
            recompiles=recompiles,
            slot_utilization=(util_active / (K * launches_run)
                              if launches_run else 1.0),
        )

    # ------------------------------------------------------------- pricing

    def _price_deadline(self, req: Request, lp, detector: DeadlineDetector,
                        S: int) -> Optional[float]:
        """Per-request completion deadline: the explicit SLO when the
        request carries one, else factor x the priced service time —
        launches-to-completion x the expected launch wall (PR 6 cost
        model via the plan's expected_launch_us, detector median
        fallback). Unpriceable (analytic model, uncalibrated detector)
        means best-effort: no deadline."""
        if req.deadline_s is not None:
            return req.deadline_s
        unit = self.clock.launch_unit_s(lp, detector)
        if unit is None:
            return None
        launches = (1 + -(-(req.graph.steps - 1) // S)
                    if req.graph.steps > 1 else 1)
        return req.arrival_s + self.deadline_factor * launches * unit

    # ----------------------------------------------------------- admission

    @staticmethod
    def _admit_acts(acts: np.ndarray, l: int, slot: int, graph: TaskGraph,
                    S: int) -> np.ndarray:
        """Write the admitted member's local act schedule into its slot
        from launch ``l`` on, extending the horizon with all-zero launch
        rows when the request outlives the cohort's current schedule
        (all-zero rows freeze every slot, so pre-extension schedules are
        unchanged semantically)."""
        need = -(-(graph.steps - 1) // S) if graph.steps > 1 else 0
        rem = acts.shape[0] - l
        if need > rem:
            pad = np.zeros((need - rem,) + acts.shape[1:], acts.dtype)
            acts = np.concatenate([acts, pad], axis=0)
            rem = need
        tloc = 1 + (np.arange(rem)[:, None] * S + np.arange(S)[None, :])
        acts[l:, slot, :] = (tloc < graph.steps).astype(acts.dtype)
        return acts

    # -------------------------------------------------------- verification

    def _oracle(self, graph: TaskGraph, eff: int, K: int,
                slot: int) -> np.ndarray:
        """Serial same-K oracle: the request alone, truncated to its
        effective steps, through the production ensemble executor at the
        cohort's K (see module docstring for why same-K is the exact
        comparison)."""
        g = dataclasses.replace(graph, steps=eff)
        ck = (g, K, slot)
        if ck not in self._oracle_cache:
            out = self.runtime.execute_ensemble(GraphEnsemble((g,) * K))
            self._oracle_cache[ck] = np.asarray(out[slot])
        return self._oracle_cache[ck]

    def _verify(self, outcomes: List[RequestOutcome],
                cohorts: List[CohortReport]) -> None:
        slots_of = {c.index: c.slots for c in cohorts}
        for o in outcomes:
            ref = self._oracle(o.graph, o.effective_steps,
                               slots_of[o.cohort], o.slot)
            o.bit_identical = bool(np.array_equal(o.output, ref))
