"""Plan-aware request packing: which requests may share a stacked cohort.

Mixed-plan tuple ensembles silently pin cadence to per-step dispatch
(``pallas_step.stacking_verdict`` names why), so the packer never builds
one: requests group by ``cohort_key`` — FULL operand-table identity, not
just the stacked path's structural minimum — and incompatible requests
form separate cohorts instead of one degraded tuple.

The key is deliberately stricter than ``stacking_verdict`` requires
(which only needs uniform (width, payload, kernel) + every member on the
halo plan). Same (plan, width, payload, kernel, pattern, radius, fanout)
— plus the graph seed for seed-structured patterns — means every cohort
member shares bit-identical baked idx/wgt tables, which is what makes
MID-RUN admission sound: any freed (K, S) act-mask slot can host any
queued cohort request, because the slot's operand slice is already the
admitted request's operand slice. Only (steps, seed, deadline, priority)
vary within a cohort, and the seed only feeds ``initial_state``.
"""
from __future__ import annotations

from typing import List, Tuple

from repro.core.graph import TaskGraph
from repro.serving.request import Request

#: patterns whose graph seed bakes into the dependency tables themselves
#: (not just the initial state) — the seed joins the cohort key for them
SEED_STRUCTURED_PATTERNS = frozenset({"random_nearest"})


def cohort_key(runtime, graph: TaskGraph) -> Tuple:
    """The compatibility class of ``graph`` under ``runtime``.

    Two graphs with equal keys resolve the same plan kind, the same block
    shape, and bit-identical operand tables, so they may share one
    stacked launch AND one act-mask slot across time. Raises when the
    runtime cannot place the graph on any plan (nothing to pack)."""
    plan, why = runtime.plan_for(graph)
    if plan is None:
        raise ValueError(
            f"unpackable request graph {graph.describe()}: {why}")
    seed = graph.seed if graph.pattern in SEED_STRUCTURED_PATTERNS else None
    return (plan, graph.width, graph.payload, graph.kernel, graph.pattern,
            graph.radius, graph.fanout, seed)


def order_key(req: Request) -> Tuple:
    """Admission order: priority first (higher wins), then earliest
    deadline, then arrival, then rid as the deterministic tiebreak."""
    deadline = req.deadline_s if req.deadline_s is not None else float("inf")
    return (-req.priority, deadline, req.arrival_s, req.rid)


def pack(runtime, requests: List[Request],
         max_slots: int) -> List[List[Request]]:
    """Static packing preview: admission-ordered requests greedily split
    into compatibility cohorts of at most ``max_slots``.

    The fabric itself packs DYNAMICALLY (arrivals interleave with
    retirements and freed slots re-admit), but the grouping rule is this
    one; tests and the driver use this to predict the cohort census a
    request mix should produce."""
    if max_slots < 1:
        raise ValueError(f"max_slots must be >= 1, got {max_slots}")
    cohorts: List[List[Request]] = []
    for req in sorted(requests, key=order_key):
        key = cohort_key(runtime, req.graph)
        placed = False
        for cohort in cohorts:
            if (len(cohort) < max_slots
                    and cohort_key(runtime, cohort[0].graph) == key):
                cohort.append(req)
                placed = True
                break
        if not placed:
            cohorts.append([req])
    return cohorts
