"""Serving requests: one TaskGraph run with arrival/deadline/priority.

A request is the serving unit the fabric admits into an ensemble slot: it
names WHAT to compute (a seeded TaskGraph — pattern, T, W, payload,
kernel) and HOW urgently (arrival time, optional absolute completion
deadline, priority). The graph's seed drives ``initial_state``, so two
requests with the same shape but different seeds are different work — the
bit-identity property the fabric asserts is per-request, per-seed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.graph import TaskGraph
from repro.core.task_kernels import KernelSpec


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request.

    ``arrival_s`` / ``deadline_s`` are absolute times on the fabric's
    clock (seconds for the wall clock, launch counts for the virtual
    LaunchClock the deterministic tests use). ``deadline_s=None`` asks
    the fabric to PRICE a deadline off the cost model at admission
    (``ServingFabric._price_deadline``); an explicit value is an SLO the
    fabric enforces as-is. Higher ``priority`` admits first.
    """

    rid: int
    graph: TaskGraph
    arrival_s: float = 0.0
    deadline_s: Optional[float] = None
    priority: int = 0

    def __post_init__(self):
        if self.graph.steps < 1:
            raise ValueError(f"request {self.rid}: steps must be >= 1")
        if self.deadline_s is not None and self.deadline_s < self.arrival_s:
            raise ValueError(
                f"request {self.rid}: deadline {self.deadline_s} precedes "
                f"arrival {self.arrival_s}")


def make_request(rid: int, *, steps: int, width: int = 8,
                 pattern: str = "stencil_1d", payload: int = 16,
                 kernel: Optional[KernelSpec] = None, radius: int = 1,
                 fanout: int = 3, seed: int = 0, arrival_s: float = 0.0,
                 deadline_s: Optional[float] = None,
                 priority: int = 0) -> Request:
    """Convenience constructor mirroring TaskGraph's knobs."""
    return Request(
        rid=rid,
        graph=TaskGraph(
            steps=steps, width=width, pattern=pattern, payload=payload,
            kernel=kernel or KernelSpec("compute_bound", 4),
            radius=radius, fanout=fanout, seed=seed),
        arrival_s=arrival_s,
        deadline_s=deadline_s,
        priority=priority,
    )
