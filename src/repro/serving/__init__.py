"""Continuous-batching serving fabric over the pallas_step megakernel.

The serving analogue of the paper's per-task-overhead question: decode
steps are tasks, ensemble members are requests, and per-request overhead
under continuous arrival is the METG of a serving system. Requests carry
(pattern, T, W, deadline, priority) and a seed; the plan-aware packer
(`packer.py`) groups operand-compatible requests into stacked cohorts;
the fabric (`fabric.py`) runs each cohort through the runtime's
EnsembleLaunchPlan with dynamic membership — retiring members free their
(K, S) act-mask slots (the PR 8 eviction primitive) and queued requests
are re-admitted into freed slots mid-run via ``admit_fn``, no recompile,
bit-identity preserved. DESIGN.md §13 documents the compatibility rules,
the cohort lifecycle, and the deadline pricing.
"""
from repro.serving.fabric import (
    CohortReport,
    LaunchClock,
    RequestOutcome,
    ServeReport,
    ServingFabric,
    WallClock,
)
from repro.serving.packer import cohort_key, order_key, pack
from repro.serving.request import Request, make_request

__all__ = [
    "CohortReport",
    "LaunchClock",
    "Request",
    "RequestOutcome",
    "ServeReport",
    "ServingFabric",
    "WallClock",
    "cohort_key",
    "make_request",
    "order_key",
    "pack",
]
