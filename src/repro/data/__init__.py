"""data substrate."""
