"""Deterministic synthetic token pipeline with sharded device placement.

Tokens are generated statelessly from (seed, step, position) via JAX's
threefry — no storage, perfectly reproducible across restarts and across any
number of data-loading hosts (each host materializes only its shard). The
iterator state is a single integer, which makes the data pipeline trivially
checkpointable and elastic (restarting with a different DP degree re-slices
the same global batch stream).

Double buffering: `prefetch()` builds batch t+1 on host while step t runs —
the straggler/latency-hiding trick from the paper applied to the input feed.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class PipelineState:
    step: int = 0


class SyntheticTokenPipeline:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeConfig,
        seed: int = 0,
        batch_override: Optional[int] = None,
        seq_override: Optional[int] = None,
        shardings: Optional[Any] = None,  # pytree of NamedShardings
    ):
        self.cfg = cfg
        self.batch = batch_override or shape.global_batch
        self.seq = seq_override or shape.seq_len
        self.seed = seed
        self.shardings = shardings
        self.state = PipelineState()

    # ------------------------------------------------------------------

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        """Materialize the global batch for `step` (pure function of step)."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        kt, ke, ki = jax.random.split(key, 3)
        # tokens over a zipf-ish distribution: square a uniform to skew low ids
        u = jax.random.uniform(kt, (self.batch, self.seq + 1))
        toks = (u * u * cfg.vocab).astype(jnp.int32)
        batch: Dict[str, jax.Array] = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:],
        }
        if cfg.embed_inputs:
            batch["embeds"] = 0.02 * jax.random.normal(
                ke, (self.batch, self.seq, cfg.d_model), jnp.float32
            ).astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        if cfg.n_image_tokens:
            batch["image_embeds"] = 0.02 * jax.random.normal(
                ki, (self.batch, cfg.n_image_tokens, cfg.d_model), jnp.float32
            ).astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
        if self.shardings is not None:
            batch = {
                k: jax.device_put(v, self.shardings[k]) if k in self.shardings
                else v
                for k, v in batch.items()
            }
        return batch

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        while True:
            b = self.batch_at(self.state.step)
            self.state.step += 1
            yield b

    def prefetch(self) -> Iterator[Dict[str, jax.Array]]:
        """One-deep host-side prefetch (double buffering)."""
        it = iter(self)
        nxt = next(it)
        while True:
            cur, nxt = nxt, next(it)
            yield cur

    # -------------------------------------------------------- checkpoint

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.state.step, "seed": self.seed}

    def load_state_dict(self, d: Dict[str, int]) -> None:
        assert d["seed"] == self.seed, "pipeline seed mismatch on restore"
        self.state.step = int(d["step"])
