"""stablelm-3b — dense MHA decoder [hf:stabilityai/stablelm-3b; unverified].

32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=6912,
    vocab=50304,
)
