"""Architecture registry: --arch <id> resolution for launchers/tests/benches."""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs import (
    gemma3_4b,
    granite_moe_3b_a800m,
    hymba_1_5b,
    internlm2_1_8b,
    llama_3_2_vision_90b,
    mamba2_130m,
    minitron_8b,
    mixtral_8x7b,
    musicgen_medium,
    stablelm_3b,
    taskbench,
)
from repro.configs.base import SHAPE_BY_NAME, SHAPES, ModelConfig, ShapeConfig

_MODULES = (
    hymba_1_5b,
    mixtral_8x7b,
    granite_moe_3b_a800m,
    musicgen_medium,
    gemma3_4b,
    internlm2_1_8b,
    minitron_8b,
    stablelm_3b,
    llama_3_2_vision_90b,
    mamba2_130m,
)

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_config(name: str) -> ModelConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}") from None


def list_archs() -> List[str]:
    return list(ARCHS)


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPE_BY_NAME[name]
    except KeyError:
        raise KeyError(
            f"unknown shape {name!r}; known: {sorted(SHAPE_BY_NAME)}"
        ) from None


def cells(include_skips: bool = True) -> List[Tuple[ModelConfig, ShapeConfig, bool]]:
    """All (arch x shape) cells; the bool marks runnable (False = documented
    long-context skip for pure full-attention archs, DESIGN.md §6)."""
    out = []
    for cfg in ARCHS.values():
        for shape in SHAPES:
            runnable = shape.name != "long_500k" or cfg.supports_long_context
            if runnable or include_skips:
                out.append((cfg, shape, runnable))
    return out
