"""Architecture + shape configs. See registry.py for --arch resolution."""
