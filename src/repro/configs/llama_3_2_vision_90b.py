"""llama-3.2-vision-90b — cross-attention image layers
[hf:meta-llama/Llama-3.2-90B-Vision; unverified tier].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. Every 5th layer is
a gated cross-attention layer over vision tokens. The ViT frontend is a stub:
input_specs() provides precomputed patch embeddings (B, 1600, d_model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    cross_attn_period=5,   # 4 self-attn + 1 cross-attn, x20 blocks
    n_image_tokens=1600,
    rope_theta=500000.0,
    loss_chunk=1024,
)
