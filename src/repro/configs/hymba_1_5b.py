"""hymba-1.5b — hybrid parallel attention+Mamba heads [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Hymba runs attention heads and SSM heads in parallel on the same input and
fuses their (normalized) outputs; most layers use SWA (window 1024).
Meta-tokens are omitted (stub note: DESIGN.md §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    window=1024,          # SWA layers (hybrid decode stays O(1)/O(w))
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
)
