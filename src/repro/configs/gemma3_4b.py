"""gemma3-4b — 5:1 local:global attention, 128k context
[hf:google/gemma-3-4b-pt; unverified tier].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, head_dim=256,
QK-norm, tied embeddings. local_global_period=6 => 5 local (window 1024)
+ 1 global per block.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262144,
    local_global_period=6,
    local_window=1024,
    use_qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e6,
    loss_chunk=1024,  # 262k-vocab logits are CE'd in sequence chunks
)
