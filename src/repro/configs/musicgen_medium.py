"""musicgen-medium — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284; hf]. Backbone only: the EnCodec/conditioning frontend is a
stub; input_specs() provides precomputed frame embeddings (B, S, d_model).

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    embed_inputs=True,
)
