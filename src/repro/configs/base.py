"""Config system: model architecture + input-shape configs.

Every assigned architecture gets one module in this package defining a
``ModelConfig`` with the exact published hyperparameters; ``reduced()``
derives the tiny same-family config used by CPU smoke tests. Input shapes
(train_4k / prefill_32k / decode_32k / long_500k) are global and paired with
every arch (registry.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # -- attention structure --------------------------------------------
    window: int = 0  # sliding-window size for ALL attn layers; 0 = full
    local_global_period: int = 0  # p: (p-1) local + 1 global per block
    local_window: int = 1024  # window of "local" layers when period > 0
    use_qk_norm: bool = False

    # -- MoE --------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0  # per-expert hidden; 0 -> d_ff
    capacity_factor: float = 1.25

    # -- SSM (Mamba-2 / SSD) ----------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # -- VLM (cross-attention image layers; stub patch-embedding frontend) -
    cross_attn_period: int = 0  # every p-th layer is cross-attn; 0 = none
    n_image_tokens: int = 0

    # -- audio (stub frame-embedding frontend) -----------------------------
    embed_inputs: bool = False  # True: inputs are (B,S,D) embeddings

    # -- misc ---------------------------------------------------------------
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"  # storage dtype (bf16 for dry-runs)
    tie_embeddings: bool = False
    loss_chunk: int = 0  # compute logits+CE in seq chunks; 0 = whole seq
    use_flash: bool = True  # Pallas kernels on no-grad paths
    kv_quant: bool = False  # int8 KV cache (per-position scales) for decode

    # ------------------------------------------------------------------

    def __post_init__(self):
        if self.family not in ("dense", "moe", "ssm", "hybrid", "audio", "vlm"):
            raise ValueError(f"unknown family {self.family}")
        if self.family != "ssm":
            if self.n_heads % max(self.n_kv_heads, 1):
                raise ValueError("n_heads must be divisible by n_kv_heads")

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def attends_globally(self) -> bool:
        """True if any layer runs unwindowed full attention."""
        if self.family == "ssm":
            return False
        if self.local_global_period > 0:
            return True  # the global layers
        return self.window == 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic prefill & bounded/linear decode reads.

        SSM/hybrid: state-space decode is O(1). SWA: O(window) per token.
        local:global (gemma3): global layers are linear-per-token in decode
        and the config is assigned long_500k per DESIGN.md §6.
        """
        if self.family in ("ssm", "hybrid"):
            return True
        if self.window > 0:
            return True
        if self.local_global_period > 0:
            return True
        return False

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks + head)."""
        d = self.d_model
        total = self.vocab * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab * d  # lm head
        for kind in self.layer_plan_flat():
            total += self._layer_params(kind)
        total += d  # final norm
        return total

    def _layer_params(self, kind: str) -> int:
        d = self.d_model
        hd = self.head_dim_
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        mlp = 3 * d * self.d_ff
        norms = 2 * d
        if kind in ("attn", "local", "global"):
            return attn + mlp + norms
        if kind == "moe":
            ff = self.d_ff_expert or self.d_ff
            return attn + self.n_experts * 3 * d * ff + d * self.n_experts + norms
        if kind == "ssm":
            di, nh, ns = self.ssm_inner, self.ssm_heads, self.ssm_state
            in_proj = d * (2 * di + 2 * self.ssm_groups * ns + nh)
            conv_ch = di + 2 * self.ssm_groups * ns
            conv = conv_ch * self.ssm_conv + conv_ch  # taps + bias
            out = di * d + di + 3 * nh  # out_proj + gate norm + A,D,dt_bias
            mlp_p = 3 * d * self.d_ff if self.d_ff else 0
            return in_proj + conv + out + mlp_p + norms
        if kind == "hybrid":
            # attn(+mlp+2 norms) + ssm core(+mlp+2 norms) - one duplicate mlp
            # + 2 fuse scalars; the two extra fuse norms replace the ssm
            # branch's norm pair, so norm counts balance.
            return (self._layer_params("attn") + self._layer_params("ssm")
                    - 3 * d * self.d_ff + 2)
        if kind == "xattn":
            return attn + mlp + norms + 2  # + gates
        raise ValueError(kind)

    # -- layer plan ------------------------------------------------------

    def layer_plan(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Scan groups: ((kinds-per-block), repeats), preserving layer order.

        Kinds: attn | local | global | moe | ssm | hybrid | xattn.
        """
        L = self.n_layers
        if self.family == "ssm":
            return ((("ssm",), L),)
        if self.family == "hybrid":
            return ((("hybrid",), L),)
        if self.family == "moe":
            return ((("moe",), L),)
        if self.family == "vlm" and self.cross_attn_period > 0:
            p = self.cross_attn_period
            blocks, rem = divmod(L, p)
            plan = [(tuple(["attn"] * (p - 1) + ["xattn"]), blocks)]
            if rem:
                plan.append((("attn",), rem))
            return tuple(plan)
        if self.local_global_period > 0:
            p = self.local_global_period
            blocks, rem = divmod(L, p)
            plan = [(tuple(["local"] * (p - 1) + ["global"]), blocks)]
            if rem:
                plan.append((("local",), rem))
            return tuple(plan)
        return ((("attn",), L),)

    def layer_plan_flat(self) -> Tuple[str, ...]:
        out = []
        for kinds, reps in self.layer_plan():
            out.extend(list(kinds) * reps)
        return tuple(out)

    # -- reduced smoke config ---------------------------------------------

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        p = max(self.local_global_period, self.cross_attn_period)
        n_layers = max(2, p) if p else 2
        if self.cross_attn_period:
            n_layers = self.cross_attn_period
        kv = min(self.n_kv_heads, 2) or 1
        heads = max(2 * kv if self.n_heads != self.n_kv_heads else kv, kv)
        return dataclasses.replace(
            self,
            n_layers=n_layers,
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            d_ff_expert=32 if self.n_experts else 0,
            vocab=128,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_head_dim=8,
            ssm_chunk=8,
            n_image_tokens=8 if self.n_image_tokens else 0,
            local_window=8,
            window=8 if self.window else 0,
            dtype="float32",
            param_dtype="float32",
            loss_chunk=0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def step_name(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step",
                "decode": "serve_step"}[self.kind]


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}
