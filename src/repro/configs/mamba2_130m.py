"""mamba2-130m — attention-free SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768 vocab=50280, ssm_state=128, head_dim=64, expand=2.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,       # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,          # pure Mamba blocks, no MLP
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    tie_embeddings=True,
)
