"""Task Bench workload configs (the paper's own experiment grid).

The paper runs the stencil pattern for 1000 timesteps, 5 reps per point, on
48-core nodes, with overdecomposition {1, 8, 16} (Table 2) and grain sweeps
(Fig 1). These presets scale the grid to this container while keeping the
protocol identical; benchmarks/ uses them.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class TaskBenchConfig:
    name: str
    pattern: str = "stencil_1d"
    steps: int = 1000
    payload: int = 64
    overdecomposition: Tuple[int, ...] = (1, 8, 16)
    grains: Tuple[int, ...] = (1, 4, 16, 64, 256, 1024, 4096, 16384)
    reps: int = 5
    runtimes: Tuple[str, ...] = ("fused", "serialized", "bsp", "bsp_scan",
                                 "overlap", "pallas_step")
    #: K values for concurrent multi-graph ensembles (Task Bench `-and`,
    #: paper §6.2): K independent graphs per run, each width = devices x od.
    ensemble_sizes: Tuple[int, ...] = (1, 2, 4, 8)


# The paper's protocol (1000 steps, 5 reps) — heavyweight on 1 CPU core.
PAPER = TaskBenchConfig(name="paper")

# Scaled preset used by `python -m benchmarks.run` so the suite finishes in
# minutes on this container; same shape of sweep, shorter graph.
QUICK = TaskBenchConfig(
    name="quick",
    steps=50,
    overdecomposition=(1, 8),
    grains=(1, 16, 256, 4096, 65536),
    reps=3,
    runtimes=("fused", "serialized", "bsp", "bsp_scan", "overlap",
              "pallas_step"),
    ensemble_sizes=(1, 2, 4),
)

# Latency-hiding sweep (benchmarks/fig4_latency_hiding.py): smallest grains
# so per-step overhead is NOT negligible, enough steps that per-dispatch cost
# dominates timing noise, K = 1..8 concurrent graphs, overlap-vs-bsp.
FIG4 = TaskBenchConfig(
    name="fig4",
    steps=100,
    overdecomposition=(8,),
    grains=(1, 8, 64),
    reps=5,
    runtimes=("overlap", "bsp", "bsp_scan", "pallas_step"),
    ensemble_sizes=(1, 2, 4, 8),
)

# Fused-timestep floor check (benchmarks/pallas_floor.py): iterations=1 —
# the grain where per-step op count, not arithmetic, sets the wall — over
# widths wide enough that the masked-mean's extra passes show; pallas_step's
# single prefolded gather+combine+body launch must undercut fused.
FLOOR = TaskBenchConfig(
    name="floor",
    steps=200,
    overdecomposition=(1,),
    grains=(1,),
    reps=5,
    runtimes=("fused", "pallas_step"),
    ensemble_sizes=(1,),
)

PRESETS = {c.name: c for c in (PAPER, QUICK, FIG4, FLOOR)}
