"""Per-category wall attribution + the overlap verdict, from span evidence.

Attribution contract
--------------------
``category_walls`` unions each category's span intervals (nested or
overlapping spans of one category never double-count) over the real
(non-probe) spans. ``idle`` is derived: the run's extent minus the union
of ALL attributed intervals.

Composite ``launch`` spans (pipelined pallas_step: boundary + exchange +
interior fused into ONE XLA program, so no host boundary exists between
the phases) are *apportioned* using probe spans — separately measured
amortized per-launch phase costs carried in span attrs::

    attrs = {"probe": True, "phase": "exchange", "per_launch_us": E, ...}

Given phase costs Bd (boundary), E (exchange), I (interior) and a
combined launch wall C, the split charges the phases in data-dependence
order and the *visible* remainder to exchange::

    b       = min(Bd, C)
    i       = min(I,  C - b)
    visible = clamp(C - b - i, 0, E)      # exchange wall NOT hidden
    hidden  = E - visible                  # exchange that rode under compute
    other   = C - b - i - visible          # host/dispatch slack, if any

The **overlap verdict** aggregates hidden/E over the launches: the
fraction of the total exchange wall that was actually hidden under
compute — the paper's latency-hiding question, answered from measured
intervals rather than a pipe/nopipe wall ratio. The rationale for the
combined-program design (separately dispatched phase programs would
serialize on the device queue and destroy the overlap being measured)
lives in DESIGN.md §10.

Probe spans are EXCLUDED from interval attribution — they record the
probe measurement's own wall, which is setup, not run.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.obs.tracer import CAT_DECISION, CAT_LAUNCH, CATEGORIES, Span

#: decomposition summary schema (rides inside benchmark rows/artifacts)
DECOMPOSE_SCHEMA_VERSION = 1


def _is_probe(s: Span) -> bool:
    return bool(s.attrs.get("probe"))


def merged_intervals(
    intervals: Iterable[Tuple[float, float]]
) -> List[Tuple[float, float]]:
    """Sorted, overlap-merged copy of ``intervals``."""
    ivs = sorted((a, b) for a, b in intervals if b > a)
    out: List[Tuple[float, float]] = []
    for a, b in ivs:
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return out


def union_us(intervals: Iterable[Tuple[float, float]]) -> float:
    return sum(b - a for a, b in merged_intervals(intervals))


def probe_costs(spans: Sequence[Span]) -> Dict[str, float]:
    """phase -> amortized per-launch microseconds, from probe spans."""
    out: Dict[str, float] = {}
    for s in spans:
        if _is_probe(s) and "phase" in s.attrs and "per_launch_us" in s.attrs:
            out[str(s.attrs["phase"])] = float(s.attrs["per_launch_us"])
    return out


def _split_launch(c_us: float, costs: Dict[str, float]) -> Dict[str, float]:
    """Apportion one combined launch wall using the probe costs."""
    bd = costs.get("boundary", 0.0)
    ex = costs.get("exchange", 0.0)
    it = costs.get("interior", 0.0)
    b = min(bd, c_us)
    i = min(it, c_us - b)
    visible = min(max(c_us - b - i, 0.0), ex)
    other = max(c_us - b - i - visible, 0.0)
    return {
        "compute.boundary": b,
        "compute.interior": i,
        "exchange": visible,
        "dispatch": other,
        "hidden_exchange": max(ex - visible, 0.0),
    }


def category_walls(spans: Sequence[Span]) -> Dict[str, float]:
    """Per-category attributed wall (us). Direct categories are interval
    unions; composite launch spans contribute their probe-cost split (a
    launch's phases never overlap another launch, so summing is exact);
    ``idle`` is the run extent minus everything attributed."""
    walls = {c: 0.0 for c in CATEGORIES}
    by_cat: Dict[str, List[Tuple[float, float]]] = {}
    all_ivs: List[Tuple[float, float]] = []
    costs = probe_costs(spans)
    for s in spans:
        if _is_probe(s) or s.category == CAT_DECISION:
            continue
        if s.category == CAT_LAUNCH:
            split = _split_launch(s.duration_us, costs)
            for cat in ("compute.boundary", "compute.interior",
                        "exchange", "dispatch"):
                walls[cat] += split[cat]
            all_ivs.append((s.start_us, s.end_us))
            continue
        by_cat.setdefault(s.category, []).append((s.start_us, s.end_us))
        all_ivs.append((s.start_us, s.end_us))
    for cat, ivs in by_cat.items():
        walls[cat] = walls.get(cat, 0.0) + union_us(ivs)
    extent = wall_extent_us(spans)
    walls["idle"] = walls.get("idle", 0.0) + max(
        extent - union_us(all_ivs), 0.0)
    return walls


def wall_extent_us(spans: Sequence[Span]) -> float:
    """Run extent: earliest start to latest end over real (non-probe,
    non-decision) spans."""
    real = [s for s in spans
            if not _is_probe(s) and s.category != CAT_DECISION]
    if not real:
        return 0.0
    return max(s.end_us for s in real) - min(s.start_us for s in real)


def overlap_verdict(spans: Sequence[Span]) -> Optional[Dict]:
    """How much exchange wall was hidden under compute, from the composite
    launch spans + phase probes. None when the trace has no launch spans
    (nothing was pipelined); a dict with ``verdict: "unavailable"`` when
    launches exist but the probes are missing."""
    launches = [s for s in spans if s.category == CAT_LAUNCH
                and not _is_probe(s)]
    if not launches:
        return None
    costs = probe_costs(spans)
    ex = costs.get("exchange")
    if not ex or ex <= 0.0:
        return {"verdict": "unavailable",
                "reason": "no exchange probe span recorded",
                "launches": len(launches)}
    hidden = 0.0
    visible = 0.0
    for s in launches:
        split = _split_launch(s.duration_us, costs)
        hidden += split["hidden_exchange"]
        visible += split["exchange"]
    total = ex * len(launches)
    frac = hidden / total if total > 0 else 0.0
    return {
        "verdict": "hidden" if frac > 0.5 else "visible",
        "launches": len(launches),
        "exchange_per_launch_us": ex,
        "boundary_per_launch_us": costs.get("boundary", 0.0),
        "interior_per_launch_us": costs.get("interior", 0.0),
        "combined_launch_us": sum(s.duration_us for s in launches),
        "exchange_total_us": total,
        "exchange_hidden_us": hidden,
        "exchange_visible_us": visible,
        "hidden_fraction": frac,
    }


def decision_records(spans: Sequence[Span]) -> List[Dict]:
    return [dict(s.attrs, name=s.name) for s in spans
            if s.category == CAT_DECISION]


def summarize(spans: Sequence[Span]) -> Dict:
    """JSON-safe decomposition of one traced run (what benchmark rows
    carry across the worker subprocess boundary)."""
    walls = category_walls(spans)
    extent = wall_extent_us(spans)
    total = sum(walls.values())
    fractions = {c: (w / total if total > 0 else 0.0)
                 for c, w in walls.items()}
    return {
        "schema": DECOMPOSE_SCHEMA_VERSION,
        "span_count": len(spans),
        "wall_us": extent,
        "categories_us": walls,
        "fractions": fractions,
        "overlap": overlap_verdict(spans),
        "decisions": decision_records(spans),
    }
