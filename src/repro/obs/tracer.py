"""Low-overhead span recorder for runtime telemetry.

The paper's contribution is *quantifying* where a runtime spends time;
this module is the in-process evidence source. A :class:`Tracer` records
nested :class:`Span` intervals with monotonic microsecond timestamps and
a category tag from the fixed taxonomy:

  dispatch           host work issuing device programs (per launch / step /
                     task — the quantity `serialized` maximizes)
  exchange           halo / stride transport walls (tagged with impl+depth)
  compute.boundary   the pipelined boundary phase (2*S*r edge rows)
  compute.interior   interior / whole-block kernel walls
  gather             full-state all-gather walls (the allgather plan)
  fault              fault handling: detection, retry/backoff sleeps, launch
                     replays, evictions (repro.resilience) — the recovery
                     tax, attributed like any other wall so a faulted run's
                     decomposition shows exactly where recovery spent time
  idle               wall not covered by any recorded span (derived by
                     decompose.py, but recordable explicitly too)

Two non-wall categories exist for structured records:

  launch             a COMPOSITE interval — one pipelined launch whose
                     boundary/exchange/interior phases ran inside a single
                     XLA program (splitting them into separate dispatches
                     would serialize the very overlap being measured).
                     decompose.py apportions these using probe spans.
  decision           zero-length records (scheduler verdicts etc.); their
                     attrs are the payload, they carry no wall.

Tracing is OFF by default: runtimes hold the shared :data:`NULL_TRACER`,
whose ``span()`` returns one reusable no-op context (no allocation, no
timestamp) — the <1%-overhead contract tests/test_obs.py asserts.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Tuple, Union

#: The attribution taxonomy (every microsecond of wall lands in one).
CATEGORIES = (
    "dispatch",
    "exchange",
    "compute.boundary",
    "compute.interior",
    "gather",
    "fault",
    "idle",
)

#: Wall category for fault detection/recovery work (repro.resilience).
CAT_FAULT = "fault"

#: Composite interval: one pipelined launch, phases fused in-program.
CAT_LAUNCH = "launch"
#: Zero-length structured record (scheduler decisions etc.).
CAT_DECISION = "decision"

_KNOWN = set(CATEGORIES) | {CAT_LAUNCH, CAT_DECISION}


@dataclasses.dataclass
class Span:
    """One recorded interval. Timestamps are microseconds on the
    ``time.perf_counter`` monotonic clock (comparable within a process,
    meaningless across processes)."""

    name: str
    category: str
    start_us: float
    end_us: float
    depth: int = 0
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def duration_us(self) -> float:
        return self.end_us - self.start_us


class _SpanCtx:
    """Context manager for one enabled span (kept tiny: two clock reads
    plus one list append per span)."""

    __slots__ = ("_tr", "_name", "_category", "_attrs", "_start")

    def __init__(self, tr: "Tracer", name: str, category: str, attrs):
        self._tr = tr
        self._name = name
        self._category = category
        self._attrs = attrs

    def __enter__(self) -> "_SpanCtx":
        self._tr._depth += 1
        self._start = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        end = time.perf_counter()
        tr = self._tr
        tr._depth -= 1
        tr.spans.append(Span(self._name, self._category,
                             self._start * 1e6, end * 1e6,
                             tr._depth, self._attrs))
        return False


class Tracer:
    """Records spans. One instance per traced runtime / run."""

    enabled = True

    def __init__(self) -> None:
        self.spans: List[Span] = []
        self._depth = 0

    @staticmethod
    def now_us() -> float:
        return time.perf_counter() * 1e6

    def span(self, name: str, category: str, **attrs) -> _SpanCtx:
        """Context manager recording [enter, exit] under ``category``."""
        if category not in _KNOWN:
            raise ValueError(
                f"unknown span category {category!r}; known: {sorted(_KNOWN)}")
        return _SpanCtx(self, name, category, attrs)

    def add(self, name: str, category: str, start_us: float, end_us: float,
            **attrs) -> None:
        """Record an interval with explicit timestamps (e.g. a probe wall
        measured around someone else's timing loop)."""
        if category not in _KNOWN:
            raise ValueError(
                f"unknown span category {category!r}; known: {sorted(_KNOWN)}")
        self.spans.append(Span(name, category, start_us, end_us,
                               self._depth, attrs))

    def instant(self, name: str, **attrs) -> None:
        """Zero-length decision record; ``attrs`` are the payload."""
        t = self.now_us()
        self.spans.append(Span(name, CAT_DECISION, t, t, self._depth, attrs))

    def clear(self) -> None:
        self.spans.clear()
        self._depth = 0


class _NullSpanCtx:
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> bool:
        return False


_NULL_CTX = _NullSpanCtx()


class NullTracer:
    """The disabled fast path: every call is a no-op and ``span()`` returns
    ONE preallocated context — no allocation, no clock read. ``__slots__``
    is empty so the instance cannot even grow state by accident."""

    __slots__ = ()
    enabled = False
    spans: Tuple[Span, ...] = ()

    def span(self, name: str, category: str, **attrs) -> _NullSpanCtx:
        return _NULL_CTX

    def add(self, *a, **k) -> None:
        return None

    def instant(self, *a, **k) -> None:
        return None

    def clear(self) -> None:
        return None

    @staticmethod
    def now_us() -> float:
        return 0.0


#: The shared disabled tracer (runtimes default to this).
NULL_TRACER = NullTracer()

TracerLike = Union[Tracer, NullTracer]


def coerce_tracer(opt) -> TracerLike:
    """The ``trace=`` runtime option -> a tracer.

    None/False (default)  -> NULL_TRACER (provably near-zero cost)
    True / "on" / 1       -> a fresh Tracer
    a Tracer/NullTracer   -> itself (callers share one recorder)
    """
    if opt is None or opt is False:
        return NULL_TRACER
    if isinstance(opt, (Tracer, NullTracer)):
        return opt
    if opt is True or opt == 1 or (isinstance(opt, str)
                                   and opt.lower() in ("on", "true", "1")):
        return Tracer()
    raise ValueError(f"cannot interpret trace option {opt!r}: use "
                     f"True/False, 'on', or a Tracer instance")
