"""Trace exporters: Chrome ``trace_event`` JSON + JSONL dumps.

The Chrome format (load via chrome://tracing or https://ui.perfetto.dev)
uses complete events (``ph: "X"``, ts/dur in microseconds); zero-length
decision records become instant events (``ph: "i"``). JSONL is one span
dict per line — the grep/pandas-friendly raw form.

``TRACE_SCHEMA_VERSION`` stamps both so downstream consumers (floor_guard's
trace leg, the decomposition benchmark) can refuse drifted files loudly.
"""
from __future__ import annotations

import json
from typing import Dict, List, Sequence

from repro.obs.tracer import CAT_DECISION, Span

TRACE_SCHEMA_VERSION = 1


def span_dict(s: Span) -> Dict:
    return {
        "name": s.name,
        "category": s.category,
        "start_us": s.start_us,
        "end_us": s.end_us,
        "depth": s.depth,
        "attrs": s.attrs,
    }


def span_dicts(spans: Sequence[Span]) -> List[Dict]:
    return [span_dict(s) for s in spans]


def to_chrome_trace(spans: Sequence[Span], *, pid: int = 0,
                    process_name: str = "repro") -> Dict:
    events: List[Dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": process_name},
    }]
    for s in spans:
        args = {k: v for k, v in s.attrs.items()}
        args["category"] = s.category
        if s.category == CAT_DECISION or s.end_us <= s.start_us:
            events.append({
                "name": s.name, "cat": s.category, "ph": "i", "s": "t",
                "ts": s.start_us, "pid": pid, "tid": s.depth, "args": args,
            })
        else:
            events.append({
                "name": s.name, "cat": s.category, "ph": "X",
                "ts": s.start_us, "dur": s.duration_us,
                "pid": pid, "tid": s.depth, "args": args,
            })
    return {
        "schemaVersion": TRACE_SCHEMA_VERSION,
        "displayTimeUnit": "ms",
        "traceEvents": events,
    }


def write_chrome_trace(path: str, spans: Sequence[Span], *,
                       process_name: str = "repro") -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(spans, process_name=process_name), f)
    return path


def write_jsonl(path: str, spans: Sequence[Span]) -> str:
    with open(path, "w") as f:
        f.write(json.dumps({"schema": TRACE_SCHEMA_VERSION}) + "\n")
        for s in spans:
            f.write(json.dumps(span_dict(s)) + "\n")
    return path
