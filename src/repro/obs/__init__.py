"""Structured runtime telemetry (DESIGN.md §10).

tracer.py     span recorder (categories, monotonic us timestamps, the
              off-by-default NULL_TRACER fast path)
export.py     Chrome trace_event JSON + JSONL dumps
decompose.py  per-category wall attribution + the overlap verdict
"""
from repro.obs.decompose import (
    DECOMPOSE_SCHEMA_VERSION,
    category_walls,
    decision_records,
    overlap_verdict,
    probe_costs,
    summarize,
    union_us,
    wall_extent_us,
)
from repro.obs.export import (
    TRACE_SCHEMA_VERSION,
    span_dicts,
    to_chrome_trace,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.tracer import (
    CAT_DECISION,
    CAT_FAULT,
    CAT_LAUNCH,
    CATEGORIES,
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    coerce_tracer,
)

__all__ = [
    "CATEGORIES", "CAT_DECISION", "CAT_FAULT", "CAT_LAUNCH",
    "NULL_TRACER", "NullTracer",
    "Span", "Tracer", "coerce_tracer",
    "TRACE_SCHEMA_VERSION", "span_dicts", "to_chrome_trace",
    "write_chrome_trace", "write_jsonl",
    "DECOMPOSE_SCHEMA_VERSION", "category_walls", "decision_records",
    "overlap_verdict", "probe_costs", "summarize", "union_us",
    "wall_extent_us",
]
