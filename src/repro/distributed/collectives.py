"""Collective building blocks for the model's distributed paths.

The centerpiece is sequence-parallel decode attention: for decode shapes the
KV cache is sharded along the *sequence* axis (decode_32k: over "model";
long_500k: over "data" and "model" — batch=1 leaves both axes free), each
shard runs the local flash-decode kernel over its cache slice, and the
partial (o, m, l) softmax stats are combined with one tiny all-reduce —
FlashDecoding's split-K reduction mapped onto mesh axes.

This is exactly a Task Bench `all_to_all`-class dependence carried by a
psum-sized message (stats + per-head output), i.e. the communication term it
adds to the roofline is O(B x Hq x D) per layer, independent of cache length.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.kernels import ops

AxisRef = Union[str, Tuple[str, ...]]


def _axes_tuple(ref: AxisRef) -> Tuple[str, ...]:
    return (ref,) if isinstance(ref, str) else tuple(ref)


def sequence_parallel_decode_attention(
    q: jax.Array,        # (B, Hq, D) — replicated over the seq axes
    k_cache: jax.Array,  # (B, Hkv, S, D) — S sharded over `seq_axes`
    v_cache: jax.Array,
    lengths: jax.Array,  # (B,) global valid length
    *,
    mesh: Mesh,
    seq_axes: AxisRef,
    batch_axis: Optional[AxisRef] = None,
    window: int = 0,
    sm_scale: Optional[float] = None,
    use_kernel: bool = True,
) -> jax.Array:
    """Distributed flash-decode with lse-combine across `seq_axes`."""
    seq_axes = _axes_tuple(seq_axes)
    batch_axes = _axes_tuple(batch_axis) if batch_axis else ()
    n_seq_shards = 1
    for a in seq_axes:
        n_seq_shards *= mesh.shape[a]
    S = k_cache.shape[2]
    if S % n_seq_shards:
        raise ValueError(f"cache length {S} not divisible by {n_seq_shards}")
    S_local = S // n_seq_shards

    bspec = batch_axes[0] if len(batch_axes) == 1 else (batch_axes or None)
    sspec = seq_axes[0] if len(seq_axes) == 1 else seq_axes
    cache_spec = P(bspec, None, sspec, None)
    q_spec = P(bspec, None, None)
    len_spec = P(bspec)

    def local(qx, kc, vc, ln):
        # global offset of this shard's cache slice
        idx = 0
        for a in seq_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        offset = idx * S_local
        # Lengths in local coordinates, deliberately UNclipped: lengths' =
        # ln - offset. Validity pos < lengths' and (window) pos >= lengths' -
        # window both shift correctly; lengths' <= 0 masks the whole shard
        # (l = 0, handled by the combine), lengths' > S_local keeps it fully
        # visible — both are exactly right globally.
        local_len = (ln - offset).astype(jnp.int32)
        o, m, l = ops.decode_attention(
            qx, kc, vc, local_len,
            window=window, sm_scale=sm_scale, return_stats=True,
            use_kernel=use_kernel,
        )
        # cross-shard lse combine over the sequence axes
        m_g = jax.lax.pmax(m, seq_axes)  # (B, Hq)
        scale = l * jnp.exp(m - m_g)
        num = jax.lax.psum(o.astype(jnp.float32) * scale[..., None], seq_axes)
        den = jax.lax.psum(scale, seq_axes)
        den = jnp.where(den == 0.0, 1.0, den)
        # psum output is invariant over seq_axes, matching the replicated
        # out_spec (every shard returns the same combined attention output).
        return (num / den[..., None]).astype(qx.dtype)

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(q_spec, cache_spec, cache_spec, len_spec),
        out_specs=q_spec,
        # pallas_call inside shard_map cannot declare vma on its out_shape
        check_vma=False,
    )
    return fn(q, k_cache, v_cache, lengths)


def hierarchical_psum_spec(axes: Sequence[str]) -> Tuple[str, ...]:
    """Gradient-reduction axis order: innermost (fast ICI) axis first so the
    inter-pod (DCI) hop carries the already-reduced tensor once."""
    return tuple(axes)
