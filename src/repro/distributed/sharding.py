"""ShardingPolicy: logical-axis rule sets + param PartitionSpec trees.

One policy per (arch x step kind). The production meshes are
(data=16, model=16) and (pod=2, data=16, model=16); see launch/mesh.py.

Strategy summary (DESIGN.md §5):
  train/prefill  DP over (pod, data); Megatron TP over model (qkv/gate/up
                 column, o/down row); sequence-parallel residual stream over
                 model; vocab-sharded embedding/head/logits; MoE expert FFNs
                 tensor-sharded over model ("expert_ff"); optional FSDP
                 (params additionally sharded over data, gathered per scanned
                 layer block).
  decode         batch over (pod, data); cache sequence-sharded over model
                 (long_500k: over data AND model — batch=1 frees both), read
                 via the lse-combine shard_map; TP over model for projections.

Param specs are derived from pytree paths — the table below is the single
source of truth for which dim of which weight carries which logical axis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.api import ShardingRules

# (submodule, leaf) -> logical names per dim (without the scan-stack dim).
# "fsdp" marks the dim that FSDP additionally shards over data.
_PARAM_TABLE: Dict[Tuple[str, str], Tuple[Optional[str], ...]] = {
    ("", "embed"): ("vocab", "embed_fsdp"),
    ("", "head"): ("embed_fsdp", "vocab"),
    ("", "final_norm"): (None,),
    ("attn", "wq"): ("fsdp", "heads_out"),
    ("attn", "wk"): ("fsdp", "kv_out"),
    ("attn", "wv"): ("fsdp", "kv_out"),
    ("attn", "wo"): ("heads_out", "fsdp"),
    ("attn", "q_norm"): (None,),
    ("attn", "k_norm"): (None,),
    ("mlp", "gate"): ("fsdp", "ff"),
    ("mlp", "up"): ("fsdp", "ff"),
    ("mlp", "down"): ("ff", "fsdp"),
    ("moe", "router"): ("fsdp", None),
    ("moe", "gate"): ("experts", "fsdp", "expert_ff"),
    ("moe", "up"): ("experts", "fsdp", "expert_ff"),
    ("moe", "down"): ("experts", "expert_ff", "fsdp"),
    ("ssm", "in_proj"): ("fsdp", "ssm_inner"),
    ("ssm", "out_proj"): ("ssm_inner", "fsdp"),
    ("ssm", "conv_w"): (None, "ssm_inner"),
    ("ssm", "conv_b"): ("ssm_inner",),
    ("ssm", "A_log"): (None,),
    ("ssm", "D"): (None,),
    ("ssm", "dt_bias"): (None,),
    ("ssm", "norm_w"): ("ssm_inner",),
}
_NORMS = ("norm1", "norm2", "fuse_norm_a", "fuse_norm_s")


@dataclasses.dataclass
class ShardingPolicy:
    mesh: Mesh
    rules: ShardingRules
    fsdp: bool

    # ---------------------------------------------------------- factories

    @staticmethod
    def for_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 fsdp: Optional[bool] = None) -> "ShardingPolicy":
        multi_pod = "pod" in mesh.shape
        dp = ("pod", "data") if multi_pod else ("data",)
        if fsdp is None:
            # params bf16 per model shard > ~4 GB -> gather-per-block FSDP
            fsdp = cfg.param_count() * 2 / mesh.shape["model"] > 4e9
        common = {
            "heads_out": "model", "kv_out": "model", "ff": "model",
            "vocab": "model", "expert_ff": "model", "experts": None,
            "ssm_inner": "model", "embed_fsdp": None,
            "fsdp": dp if fsdp else None,
            "heads": "model", "batch": dp,
        }
        if shape.kind in ("train", "prefill"):
            rules = ShardingRules({
                **common,
                "seq": "model",  # sequence-parallel residual stream
                # attention q rows / SSD chunks: "heads" is named first on
                # those tensors, so when the head count divides the axis TP
                # carries it and seq_q is dropped (de-dup guard); when it
                # does NOT divide (granite 24H, hymba 25H, musicgen 24H,
                # mamba2 24 ssd heads) the inner compute would replicate
                # 16x — seq_q picks the axis up instead (§Perf #3)
                "seq_q": "model",
                "cap": dp,  # MoE buckets: capacity over DP axes
                "cache_seq": None,
            })
        else:  # decode
            long_ctx = shape.global_batch < mesh.shape["data"]
            rules = ShardingRules({
                **common,
                "fsdp": None,  # decode never FSDPs (no grads/opt state)
                "seq": None,
                "seq_q": None,
                "cap": None,
                "cache_seq": ("data", "model") if long_ctx else "model",
            })
            fsdp = False
        return ShardingPolicy(mesh=mesh, rules=rules, fsdp=fsdp)

    # ------------------------------------------------------- param specs

    def _leaf_spec(self, path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        leaf_name = names[-1]
        stacked = any(str(n).startswith("group") for n in names[:-1])
        sub = ""
        for n in names[:-1]:
            if n in ("attn", "mlp", "moe", "ssm"):
                sub = n
        if leaf_name in _NORMS or leaf_name in (
            "fuse_a", "fuse_s", "gate_attn", "gate_mlp"
        ):
            logical: Tuple[Optional[str], ...] = (None,) * (
                leaf.ndim - (1 if stacked else 0)
            )
        else:
            key = (sub, leaf_name)
            if key not in _PARAM_TABLE:
                raise KeyError(f"no sharding rule for param {names}")
            logical = _PARAM_TABLE[key]
        parts = []
        for dim, name in zip(leaf.shape[1:] if stacked else leaf.shape, logical):
            ref = self.rules.resolve(name)
            if ref is not None:
                import math as _m

                size = (self.mesh.shape[ref] if isinstance(ref, str)
                        else _m.prod(self.mesh.shape[a] for a in ref))
                if dim % size != 0:
                    ref = None
            parts.append(ref)
        if stacked:
            parts = [None] + parts
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)

    def param_specs(self, params: Any) -> Any:
        return jax.tree_util.tree_map_with_path(self._leaf_spec, params)

    def param_shardings(self, params: Any) -> Any:
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), self.param_specs(params)
        )

    # ------------------------------------------------- input/cache specs

    def batch_spec(self) -> P:
        return P(self.rules.resolve("batch"))

    def batch_shardings(self, batch_tree: Any) -> Any:
        dp = self.rules.resolve("batch")
        mesh = self.mesh
        import math as _m

        dp_size = 1 if dp is None else (
            mesh.shape[dp] if isinstance(dp, str)
            else _m.prod(mesh.shape[a] for a in dp))

        def leaf(x):
            # divisibility guard: long_500k has global_batch=1 — replicate
            ref = dp if (dp and x.shape[0] % dp_size == 0) else None
            parts = [ref] + [None] * (x.ndim - 1)
            return NamedSharding(self.mesh, P(*parts))

        return jax.tree.map(leaf, batch_tree)

    def cache_shardings(self, caches: Any) -> Any:
        """Attention k/v (stack, B, Hkv, S, hd): batch over DP + S over
        cache_seq. SSM ssd state (stack, B, H, N, P): batch + H over model.
        SSM conv window (stack, B, cw-1, ch): batch + channels over model."""
        dp = self.rules.resolve("batch")
        seq = self.rules.resolve("cache_seq")
        mesh = self.mesh
        import math as _m

        def fits(ref, dim):
            if ref is None:
                return None
            size = (mesh.shape[ref] if isinstance(ref, str)
                    else _m.prod(mesh.shape[a] for a in ref))
            return ref if dim % size == 0 else None

        def leaf(path, x):
            names = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
            kind = next((n for n in ("conv", "ssd", "k_scale", "v_scale",
                                     "k", "v") if n in names), "")
            if kind in ("k", "v", "k_scale", "v_scale"):
                # (stack, B, Hkv, S, hd) / scales (stack, B, Hkv, S, 1)
                spec = P(None, fits(dp, x.shape[1]), None,
                         fits(seq, x.shape[3]), None)
            elif kind == "ssd":  # (stack, B, H, N, P)
                spec = P(None, fits(dp, x.shape[1]),
                         fits(self.rules.resolve("heads"), x.shape[2]))
            elif kind == "conv":  # (stack, B, cw-1, ch)
                spec = P(None, fits(dp, x.shape[1]), None,
                         fits(self.rules.resolve("ssm_inner"), x.shape[3]))
            else:
                spec = P(*([None] * x.ndim))
            return NamedSharding(mesh, spec)

        return jax.tree_util.tree_map_with_path(leaf, caches)

    def replicated(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())
