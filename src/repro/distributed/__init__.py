"""Distributed runtime: logical-axis sharding, collectives, pipeline, ZeRO."""
