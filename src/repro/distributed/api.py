"""Logical-axis sharding context used throughout the model code.

Model code annotates activations/params with *logical* axis names
("batch", "heads", "ff", ...). A ``ShardingRules`` mapping — chosen per
(arch x step kind) by distributed/sharding.py — resolves them to mesh axes.
Outside any mesh context the constraints are no-ops, so the same model code
runs single-device smoke tests and 512-chip dry-runs unchanged.

Divisibility guard: a logical dim whose size does not divide the mesh axis
product resolves to None (replicated) instead of failing — e.g. hymba's 5 KV
heads on a 16-way model axis stay replicated while its 25 q heads... also not
divisible; both replicate, and the FF/vocab dims carry the TP instead.
"""
from __future__ import annotations

import contextlib
import math
from contextvars import ContextVar
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

AxisRef = Union[None, str, Tuple[str, ...]]

_ACTIVE: ContextVar = ContextVar("repro_sharding_ctx", default=None)


class ShardingRules:
    """logical axis name -> mesh axis (or tuple of axes, or None)."""

    def __init__(self, mapping: Dict[str, AxisRef]):
        self.mapping = dict(mapping)

    def resolve(self, name: Optional[str]) -> AxisRef:
        if name is None:
            return None
        return self.mapping.get(name)

    def override(self, **kw: AxisRef) -> "ShardingRules":
        m = dict(self.mapping)
        m.update(kw)
        return ShardingRules(m)


@contextlib.contextmanager
def sharding_context(mesh: Optional[Mesh], rules: Optional[ShardingRules]):
    token = _ACTIVE.set((mesh, rules) if mesh is not None else None)
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def current_mesh() -> Optional[Mesh]:
    ctx = _ACTIVE.get()
    return ctx[0] if ctx else None


def current_rules() -> Optional[ShardingRules]:
    ctx = _ACTIVE.get()
    return ctx[1] if ctx else None


def _axis_size(mesh: Mesh, ref: AxisRef) -> int:
    if ref is None:
        return 1
    if isinstance(ref, str):
        return mesh.shape[ref]
    return math.prod(mesh.shape[a] for a in ref)


def spec_for(shape: Sequence[int], names: Sequence[Optional[str]]) -> Optional[P]:
    """Resolve logical names to a PartitionSpec under the active context."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return None
    mesh, rules = ctx
    parts = []
    used: set = set()
    for dim, name in zip(shape, names):
        ref = rules.resolve(name)
        if ref is not None and dim % _axis_size(mesh, ref) != 0:
            ref = None  # replicate instead of failing (documented guard)
        if ref is not None:
            # one mesh axis may appear once per spec; first dim wins (e.g.
            # logits (batch, seq, vocab) under SP: seq takes "model", vocab
            # replicates)
            axes = (ref,) if isinstance(ref, str) else tuple(ref)
            if any(a in used for a in axes):
                ref = None
            else:
                used.update(axes)
        parts.append(ref)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Apply with_sharding_constraint per the active logical rules (no-op
    outside a mesh context)."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    mesh, _ = ctx
    if len(names) != x.ndim:
        raise ValueError(f"{len(names)} names for rank-{x.ndim} array")
    spec = spec_for(x.shape, names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_of(shape: Sequence[int], names: Sequence[Optional[str]]):
    """NamedSharding for an input/param with the given logical names (or None
    outside a mesh context) — used to build in_shardings for jit."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return None
    mesh, _ = ctx
    return NamedSharding(mesh, spec_for(shape, names))
