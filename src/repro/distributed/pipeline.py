"""Pipeline parallelism over a mesh axis (collective-permute schedule).

GPipe-style microbatch pipeline expressed in shard_map: stage s holds the
stacked params slice for its layers; activations flow stage->stage+1 via
ppermute once per tick. With M microbatches and S stages the schedule runs
M + S - 1 ticks; each device computes on M of them (utilization M/(M+S-1) —
overdecomposition again: more microbatches per stage hide the bubble, the
paper's §6.2 story in pipeline form).

The assigned production meshes use DP x TP, so PP is an optional axis here:
it is exercised by tests (equivalence vs sequential apply, on an
8-device virtual mesh). The same ppermute schedule is what a
`dom`-pattern Task Bench graph measures (DESIGN.md §3).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,  # leaves stacked (n_stages, ...) and sharded over axis
    x: jax.Array,  # (M, mb, ...) microbatched input
    *,
    mesh: Mesh,
    axis: str = "stage",
) -> jax.Array:
    """Run x through n_stages sequential stages, pipelined over `axis`."""
    S = mesh.shape[axis]
    M = x.shape[0]
    ticks = M + S - 1
    fwd = [(d, (d + 1) % S) for d in range(S)]

    def local(params_local, xs_local):
        # params_local: this stage's params (leading stacked dim of size 1)
        params_local = jax.tree.map(lambda a: a[0], params_local)
        stage = jax.lax.axis_index(axis)
        # pad the microbatch stream to the tick count
        pad = jnp.zeros((ticks - M,) + xs_local.shape[1:], xs_local.dtype)
        stream = jnp.concatenate([xs_local, pad], axis=0)

        def tick(carry, t):
            recv, outs = carry
            inject = jax.lax.dynamic_index_in_dim(stream, jnp.minimum(t, M - 1),
                                                  0, keepdims=False)
            inp = jnp.where(stage == 0, inject, recv)
            out = stage_fn(params_local, inp)
            nxt = jax.lax.ppermute(out, axis, fwd)
            # last stage banks its result for microbatch m = t - (S - 1)
            m = t - (S - 1)
            outs = jax.lax.cond(
                (stage == S - 1) & (m >= 0),
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, out, jnp.maximum(m, 0), 0),
                lambda o: o,
                outs,
            )
            return (nxt, outs), None

        recv0 = jnp.zeros_like(stage_fn(params_local, stream[0]))
        outs0 = jnp.zeros((M,) + recv0.shape, recv0.dtype)
        (_, outs), _ = jax.lax.scan(tick, (recv0, outs0), jnp.arange(ticks))
        # broadcast final outputs from the last stage to all stages (masked
        # psum — ppermute cannot fan out) so out_specs can be replicated
        outs = jax.lax.psum(
            jnp.where(stage == S - 1, outs, jnp.zeros_like(outs)), axis
        )
        return outs

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(axis), P()),  # params stage-sharded; stream replicated
        out_specs=P(),
        check_vma=False,  # ppermute fan-out breaks the static VMA analysis
    )
    return fn(stage_params, x)
