"""Task Bench in JAX — the paper's primary contribution as a composable module.

Public API:
    TaskGraph, KernelSpec           workload definition
    GraphEnsemble                   K concurrent graphs (Task Bench `-and`)
    PATTERNS                        dependence pattern names
    get_runtime, available_runtimes execution backends (the systems under test)
    compute_metg, GrainSample       the METG metric
    combine_grain_samples           ensemble-aggregate samples for METG
    OverheadProfiler                the methodology applied to production loops
"""
from repro.core.graph import GraphEnsemble, TaskGraph
from repro.core.instrumentation import OverheadProfiler, measure_dispatch_overhead
from repro.core.metg import (
    DEFAULT_THRESHOLD,
    GrainSample,
    MetgResult,
    combine_grain_samples,
    compute_metg,
    default_grain_schedule,
    efficiency_curve,
)
from repro.core.patterns import PATTERNS
from repro.core.task_kernels import KernelSpec

# importing the backends registers them
from repro.core.runtimes.base import Runtime, available_runtimes, get_runtime
from repro.core.runtimes import fused as _fused  # noqa: F401
from repro.core.runtimes import serialized as _serialized  # noqa: F401
from repro.core.runtimes import bsp as _bsp  # noqa: F401
from repro.core.runtimes import overlap as _overlap  # noqa: F401
from repro.core.runtimes import pallas_step as _pallas_step  # noqa: F401

__all__ = [
    "TaskGraph",
    "GraphEnsemble",
    "KernelSpec",
    "combine_grain_samples",
    "PATTERNS",
    "Runtime",
    "get_runtime",
    "available_runtimes",
    "GrainSample",
    "MetgResult",
    "compute_metg",
    "efficiency_curve",
    "default_grain_schedule",
    "DEFAULT_THRESHOLD",
    "OverheadProfiler",
    "measure_dispatch_overhead",
]
