"""Task graph abstraction — the heart of Task Bench.

A task graph is ``steps`` timesteps x ``width`` parallel points. Each point at
timestep ``t`` depends on a pattern-defined set of points at timestep ``t-1``.
Executing the graph means executing every task (t, p) after its dependencies,
with each task running a grain-size-parameterized kernel (see task_kernels.py).

This mirrors Task Bench (Slaughter et al., SC'20) as used by the paper
"Quantifying Overheads in Charm++ and HPX using Task Bench": the graph is the
*workload*, the runtime (see runtimes/) is the *system under test*, and METG
(see metg.py) is the *metric*.

Dependence sets are materialized as padded index/mask arrays so that every
runtime backend (fused jit, per-task dispatch, shard_map BSP, overlapped) can
consume the same graph and must produce bit-identical dataflow. The arrays have
a leading ``period`` dimension: patterns whose dependences change per timestep
(fft, tree) repeat with period log2(width), so we store one period and index by
``t % period`` instead of materializing all ``steps`` slices.
"""
from __future__ import annotations

import dataclasses
import math
from functools import cached_property
from typing import Sequence, Tuple

import numpy as np

from repro.core import patterns as _patterns
from repro.core.task_kernels import KernelSpec


def _is_pow2(n: int) -> bool:
    return n > 0 and (n & (n - 1)) == 0


@dataclasses.dataclass(frozen=True)
class TaskGraph:
    """A parameterized Task Bench task graph.

    Attributes:
      steps:   number of timesteps (T). The paper uses 1000.
      width:   number of parallel points (W); typically #cores x overdecomposition.
      pattern: dependence pattern name, one of ``patterns.PATTERNS``.
      kernel:  grain-size-parameterized task body.
      payload: floats of output state per point (task output size).
      radius:  neighborhood radius for nearest/random_nearest.
      fanout:  dependence count for spread.
      seed:    RNG seed for random_nearest (deterministic graphs).
    """

    steps: int
    width: int
    pattern: str = "stencil_1d"
    kernel: KernelSpec = dataclasses.field(default_factory=KernelSpec)
    payload: int = 64
    radius: int = 1
    fanout: int = 3
    seed: int = 0

    def __post_init__(self):
        if self.pattern not in _patterns.PATTERNS:
            raise ValueError(
                f"unknown pattern {self.pattern!r}; known: {sorted(_patterns.PATTERNS)}"
            )
        if self.pattern in ("fft", "tree") and not _is_pow2(self.width):
            raise ValueError(f"pattern {self.pattern} requires power-of-two width")
        if self.steps < 1 or self.width < 1:
            raise ValueError("steps and width must be >= 1")
        if self.payload < 1:
            raise ValueError("payload must be >= 1")

    # ------------------------------------------------------------------ deps

    def dependencies(self, t: int, p: int) -> Tuple[int, ...]:
        """Points at timestep t-1 that task (t, p) consumes. Empty at t=0."""
        if t == 0:
            return ()
        if not 0 <= p < self.width:
            raise IndexError(f"point {p} outside [0, {self.width})")
        return _patterns.dependencies(self, t, p)

    def reverse_dependencies(self, t: int, p: int) -> Tuple[int, ...]:
        """Points at timestep t+1 that consume task (t, p)."""
        if t >= self.steps - 1:
            return ()
        return tuple(
            q for q in range(self.width) if p in _patterns.dependencies(self, t + 1, q)
        )

    @cached_property
    def period(self) -> int:
        """Timestep periodicity of the dependence sets."""
        return _patterns.period(self)

    @cached_property
    def max_deps(self) -> int:
        return _patterns.max_deps(self)

    def dependency_arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        """Padded dependence arrays.

        Returns:
          idx:  int32 (period, width, max_deps) — dependency point ids, padded
                with 0 where masked out.
          mask: float32 (period, width, max_deps) — 1.0 for live deps, else 0.0.

        Timestep ``t >= 1`` uses slice ``(t - 1) % period`` (t=0 has no deps).
        """
        P, W, D = self.period, self.width, self.max_deps
        idx = np.zeros((P, W, D), dtype=np.int32)
        mask = np.zeros((P, W, D), dtype=np.float32)
        for s in range(P):
            t = s + 1  # slice s serves timesteps t with (t-1) % period == s
            for p in range(W):
                deps = _patterns.dependencies(self, t, p)
                for j, d in enumerate(deps):
                    idx[s, p, j] = d
                    mask[s, p, j] = 1.0
        return idx, mask

    # ----------------------------------------------------------------- stats

    @property
    def num_tasks(self) -> int:
        return self.steps * self.width

    @cached_property
    def num_dependencies(self) -> int:
        """Total dependence edges in the graph."""
        _, mask = self.dependency_arrays()
        per_period = mask.sum(axis=(1, 2))
        total = 0.0
        for t in range(1, self.steps):
            total += per_period[(t - 1) % self.period]
        return int(total)

    def flops_per_task(self) -> int:
        return self.kernel.flops(self.payload)

    def bytes_per_task(self) -> int:
        return self.kernel.bytes(self.payload)

    def total_flops(self) -> int:
        return self.num_tasks * self.flops_per_task()

    def describe(self) -> str:
        return (
            f"TaskGraph({self.pattern}, T={self.steps}, W={self.width}, "
            f"payload={self.payload}, kernel={self.kernel.kind}"
            f"@{self.kernel.iterations}it, deps<= {self.max_deps}, "
            f"period={self.period})"
        )


@dataclasses.dataclass(frozen=True)
class GraphEnsemble:
    """K independent task graphs executed concurrently (Task Bench ``-and``).

    This is the paper's §6.2 latency-hiding workload: give each core more
    than one graph's worth of tasks so the runtime can execute a ready task
    from graph A while graph B's messages are in flight. Members may differ
    in pattern, grain, payload, width, AND ``steps``: the interleaved
    backends drive all members from ONE timestep loop of ``max(steps)``
    iterations (the lockstep composition Task Bench itself uses for
    ``-and``), and a member whose own T is exhausted is *frozen by masking*
    — it carries its final state unchanged through the remaining lockstep
    iterations, executing no further tasks.

    There is no dataflow between members — every runtime backend must
    produce, for each member, exactly the final state that running that
    member alone under ``fused`` would produce. Backends differ only in how
    much scheduling freedom they grant across members:

      fused / bsp_scan / overlap   all K graphs inside one jitted timestep
                                   loop: XLA's latency-hiding scheduler may
                                   interleave members freely (AMT analogue).
      bsp / serialized             round-robin host dispatch per step (per
                                   task): one program per superstep/task, so
                                   the compiler can never overlap members —
                                   the BSP analogue.
    """

    members: Tuple[TaskGraph, ...]

    def __init__(self, members: Sequence[TaskGraph]):
        object.__setattr__(self, "members", tuple(members))
        if not self.members:
            raise ValueError("ensemble needs at least one member graph")

    def __len__(self) -> int:
        return len(self.members)

    def __iter__(self):
        return iter(self.members)

    @property
    def steps(self) -> int:
        """Lockstep iteration count: the longest member's T."""
        return max(g.steps for g in self.members)

    @property
    def member_steps(self) -> Tuple[int, ...]:
        """Each member's own T; members are frozen once t reaches theirs."""
        return tuple(g.steps for g in self.members)

    @property
    def heterogeneous_steps(self) -> bool:
        return len({g.steps for g in self.members}) > 1

    @property
    def num_tasks(self) -> int:
        return sum(g.num_tasks for g in self.members)

    def total_flops(self) -> int:
        return sum(g.total_flops() for g in self.members)

    @cached_property
    def stackable(self) -> bool:
        """Whether members can share one (K, W, payload) state tensor.

        True when every member has the same width and payload; the stacked
        layout lets the fused backend drive all members through ONE
        vmapped gather/combine per timestep (maximal interleaving freedom).
        """
        return (
            len({g.width for g in self.members}) == 1
            and len({g.payload for g in self.members}) == 1
        )

    def dependency_arrays(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Member dep arrays padded to a common (K, Pmax, W, Dmax) shape.

        Only defined for ``stackable`` ensembles (uniform width). Each
        member's (period, W, max_deps) arrays are tiled cyclically along the
        period axis up to Pmax = max member period, so slice
        ``idx[k, (t - 1) % Pmax]`` is correct for every member whose period
        divides Pmax, and ``(t - 1) % periods[k]`` indexing stays correct
        otherwise (consumers index per member with ``periods``).

        Returns:
          idx:     int32 (K, Pmax, W, Dmax)
          mask:    float32 (K, Pmax, W, Dmax)
          periods: int32 (K,) — each member's true period.
        """
        if not self.stackable:
            raise ValueError(
                "dependency_arrays requires a stackable ensemble "
                "(uniform width/payload)"
            )
        K = len(self.members)
        W = self.members[0].width
        Pmax = max(g.period for g in self.members)
        Dmax = max(g.max_deps for g in self.members)
        idx = np.zeros((K, Pmax, W, Dmax), dtype=np.int32)
        mask = np.zeros((K, Pmax, W, Dmax), dtype=np.float32)
        periods = np.array([g.period for g in self.members], dtype=np.int32)
        for k, g in enumerate(self.members):
            gi, gm = g.dependency_arrays()  # (period, W, D_k)
            P, _, D = gi.shape
            for s in range(Pmax):
                idx[k, s, :, :D] = gi[s % P]
                mask[k, s, :, :D] = gm[s % P]
        return idx, mask, periods

    def describe(self) -> str:
        inner = "; ".join(g.describe() for g in self.members)
        return f"GraphEnsemble(K={len(self.members)}, T={self.steps}: {inner})"
