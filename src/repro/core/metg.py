"""METG — Minimum Effective Task Granularity (Task Bench's metric).

METG(50%) is the smallest *average task granularity* at which a system still
sustains >= 50% of its peak FLOP/s (paper §4). Protocol, exactly as in the
paper §6.1:

  1. Sweep grain size (kernel iterations per task) over a task graph.
  2. Peak FLOP/s = the maximum rate observed over the sweep (all systems reach
     (near-)peak at large grain — paper Fig 1a).
  3. efficiency(g) = rate(g) / peak.
  4. task granularity(g) = wall_time x cores / num_tasks   (paper §6.1).
  5. METG = granularity at the intersection of the efficiency curve with the
     50% line (log-interpolated between bracketing samples — the paper reads
     it off the plotted intersection, Fig 1b).

The module is deliberately independent of the runtimes: anything that yields
(grain, wall_time) samples — a Task Bench backend or a production training
loop — can be scored. `repro.core.instrumentation.OverheadProfiler` reuses it
to report the step-METG of the real trainer.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Optional, Sequence

DEFAULT_THRESHOLD = 0.5  # the paper's 50% choice


@dataclasses.dataclass(frozen=True)
class GrainSample:
    """One point of a granularity sweep."""

    iterations: int  # grain knob value
    wall_time: float  # seconds for the whole graph execution (best of reps)
    total_flops: float  # useful FLOPs executed by all tasks
    num_tasks: int
    cores: int  # devices participating (paper: cores)

    @property
    def flops_per_second(self) -> float:
        return self.total_flops / self.wall_time if self.wall_time > 0 else 0.0

    @property
    def granularity_us(self) -> float:
        """Average task granularity in microseconds: wall x cores / tasks."""
        return self.wall_time * self.cores / self.num_tasks * 1e6


def combine_grain_samples(
    samples: Sequence[GrainSample], wall_time: Optional[float] = None
) -> GrainSample:
    """Aggregate per-member samples of one concurrently executed ensemble.

    The members of a GraphEnsemble run inside a single measured execution,
    so the aggregate keeps ONE wall time (by default the max across inputs;
    pass ``wall_time`` when the ensemble wall was measured directly) while
    FLOPs and task counts sum. ``iterations`` becomes the task-weighted mean
    grain, so the aggregate lands at the ensemble's *average task
    granularity* — the x-axis Task Bench uses, which is well-defined even
    for mixed-grain ensembles. ``cores`` must agree across members (they
    share the device set).
    """
    if not samples:
        raise ValueError("cannot combine an empty sample list")
    cores = {s.cores for s in samples}
    if len(cores) > 1:
        raise ValueError(f"members ran on different core counts: {sorted(cores)}")
    tasks = sum(s.num_tasks for s in samples)
    mean_iters = sum(s.iterations * s.num_tasks for s in samples) / tasks
    return GrainSample(
        iterations=int(round(mean_iters)),
        wall_time=wall_time if wall_time is not None
        else max(s.wall_time for s in samples),
        total_flops=sum(s.total_flops for s in samples),
        num_tasks=tasks,
        cores=cores.pop(),
    )


@dataclasses.dataclass(frozen=True)
class EfficiencyPoint:
    iterations: int
    granularity_us: float
    flops_per_second: float
    efficiency: float


@dataclasses.dataclass(frozen=True)
class MetgResult:
    metg_us: Optional[float]  # None if the curve never reaches the threshold
    peak_flops_per_second: float
    threshold: float
    curve: List[EfficiencyPoint]

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        m = "unreached" if self.metg_us is None else f"{self.metg_us:.2f} us"
        return (
            f"METG({int(self.threshold * 100)}%) = {m} "
            f"(peak {self.peak_flops_per_second / 1e9:.3f} GFLOP/s, "
            f"{len(self.curve)} samples)"
        )


def efficiency_curve(
    samples: Sequence[GrainSample], peak: Optional[float] = None
) -> List[EfficiencyPoint]:
    """Efficiency vs granularity, sorted by ascending granularity."""
    if not samples:
        return []
    pk = peak if peak is not None else max(s.flops_per_second for s in samples)
    pk = max(pk, 1e-30)
    pts = [
        EfficiencyPoint(
            iterations=s.iterations,
            granularity_us=s.granularity_us,
            flops_per_second=s.flops_per_second,
            efficiency=s.flops_per_second / pk,
        )
        for s in samples
    ]
    pts.sort(key=lambda p: p.granularity_us)
    return pts


def compute_metg(
    samples: Sequence[GrainSample],
    threshold: float = DEFAULT_THRESHOLD,
    peak: Optional[float] = None,
) -> MetgResult:
    """Extract METG from a granularity sweep.

    The efficiency curve (ascending granularity) is scanned for the *first*
    crossing from below-threshold to >=threshold; METG is the log-space
    interpolated granularity at the crossing. If even the smallest granularity
    sample meets the threshold, METG is that sample's granularity (an upper
    bound — the paper reports it the same way when the curve never dips).
    """
    curve = efficiency_curve(samples, peak)
    pk = peak if peak is not None else (
        max((s.flops_per_second for s in samples), default=0.0)
    )
    if not curve:
        return MetgResult(None, pk, threshold, curve)

    if curve[0].efficiency >= threshold:
        return MetgResult(curve[0].granularity_us, pk, threshold, curve)

    for lo, hi in zip(curve, curve[1:]):
        if lo.efficiency < threshold <= hi.efficiency:
            # log-interpolate granularity between the bracketing samples
            g0, g1 = math.log(lo.granularity_us), math.log(hi.granularity_us)
            e0, e1 = lo.efficiency, hi.efficiency
            frac = (threshold - e0) / max(e1 - e0, 1e-12)
            return MetgResult(math.exp(g0 + frac * (g1 - g0)), pk, threshold, curve)

    return MetgResult(None, pk, threshold, curve)


def default_grain_schedule(
    min_iters: int = 1, max_iters: int = 1 << 16, points_per_decade: int = 3
) -> List[int]:
    """Geometric grain-size schedule like the paper's sweeps."""
    grains: List[int] = []
    g = float(min_iters)
    ratio = 10.0 ** (1.0 / points_per_decade)
    while g <= max_iters:
        v = int(round(g))
        if not grains or v > grains[-1]:
            grains.append(v)
        g *= ratio
    return grains
