"""Runtime backend ABC + timing harness.

A *runtime* executes a TaskGraph. Each backend models one of the paper's
systems-under-test (DESIGN.md §2 has the full mapping):

  fused       whole-graph single jit + lax.scan      (OpenMP / static analogue)
  serialized  one host dispatch per task             (per-task spawn overhead)
  bsp         shard_map + per-step host dispatch     (MPI analogue)
  bsp_scan    shard_map + in-jit timestep loop       (MPI, amortized dispatch)
  overlap     overdecomposed, halo/compute overlap   (Charm++ / HPX analogue)

All backends must produce *identical* final states for the same graph — the
dataflow semantics live in task_kernels.combine_* and are shared. Tests
enforce cross-backend allclose; this is the system's core invariant.
"""
from __future__ import annotations

import abc
import dataclasses
import time
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.graph import GraphEnsemble, TaskGraph
from repro.core.metg import GrainSample, combine_grain_samples
from repro.obs import coerce_tracer


def _fresh(x: jax.Array) -> jax.Array:
    """A copy safe to hand to a donating executable."""
    import jax.numpy as jnp

    return jnp.array(x, copy=True)


@dataclasses.dataclass(frozen=True)
class TimingStats:
    best: float
    mean: float
    walls: Tuple[float, ...]
    dispatches: int  # host->device dispatch count for one graph execution


@dataclasses.dataclass
class EnsembleLaunchPlan:
    """A host-steppable launch schedule for one ensemble run.

    The resilience engine (repro.resilience.engine) needs host visibility
    at launch boundaries — faults cannot be detected, retried, or replayed
    inside one opaque XLA program — so runtimes that can expose their
    launch structure build one of these instead of a single fused
    executor. Every launch_fn call is a pure, deterministic function of
    (carry, act row): replaying it from the pre-launch carry snapshot is
    bit-identical, which is the recovery guarantee the chaos suite locks
    in.

    ``acts`` is the host (L, K, S) activity schedule (the PR 3 act-mask
    machinery); the engine EDITS its own copy to evict a failed member
    (zero the (K, S) slot from the eviction launch on) or re-admit a
    fresh one into a freed slot.
    """

    #: lockstep timesteps advanced per launch (the blocked cadence)
    steps_per_launch: int
    #: each member's own horizon T_k (eviction reports freeze points
    #: against these)
    member_steps: Tuple[int, ...]
    #: (L, K, S) float32 per-depth activity masks, host-side
    acts: np.ndarray
    #: per-member initial states (sequence) -> device carry (the t=0
    #: body-only launch)
    init_fn: Callable[[Sequence[jax.Array]], Any]
    #: (carry, act_row (K, S) device array, t0 int32 scalar array) ->
    #: next carry; t0 is the launch's first lockstep timestep (ignored by
    #: schedules with time-invariant tables)
    launch_fn: Callable[[Any, jax.Array, jax.Array], Any]
    #: carry -> tuple of per-member (W_k, P_k) final states
    finalize: Callable[[Any], Tuple[jax.Array, ...]]
    #: (carry, slot, init state) -> carry with the slot's rows replaced by
    #: the fresh member's post-t0 state (re-admission); None when the
    #: schedule cannot replace rows in place
    admit_fn: Optional[Callable[[Any, int, jax.Array], Any]] = None
    #: measured-model expected per-launch wall (deadline basis); None when
    #: the cost model cannot price absolute walls
    expected_launch_us: Optional[float] = None
    #: descriptive schedule kind ("stacked" / "stepwise")
    kind: str = ""
    #: zero-arg callable reporting the launch executable's compile-cache
    #: entry count (jit ``_cache_size`` when the jax build exposes it);
    #: the serving fabric asserts it stays flat across membership churn —
    #: the no-recompile contract of act-mask evict/admit. None when the
    #: schedule cannot count compiles.
    compile_counter: Optional[Callable[[], int]] = None

    @property
    def num_launches(self) -> int:
        return int(self.acts.shape[0])

    def launch_t0(self, launch: int) -> int:
        """First lockstep timestep executed by launch ``launch``."""
        return 1 + launch * self.steps_per_launch


class Runtime(abc.ABC):
    """Executes task graphs under one scheduling/communication strategy."""

    #: registry name; subclasses set this
    name: str = "abstract"

    def __init__(self, devices: Optional[Sequence[jax.Device]] = None, **options):
        self.devices = list(devices) if devices is not None else jax.devices()
        self.options = options
        #: span recorder for `trace_once` (the ``trace=`` option; defaults
        #: to the shared NULL_TRACER — the timed `measure`/`execute` paths
        #: never touch it, so tracing-off cannot perturb measurements)
        self.tracer = coerce_tracer(options.get("trace"))

    # -- capabilities ------------------------------------------------------

    def supports(self, graph: TaskGraph) -> Tuple[bool, str]:
        """Whether this backend can run the graph (and why not, if not)."""
        return True, ""

    def supports_ensemble(self, ensemble: GraphEnsemble) -> Tuple[bool, str]:
        """Whether this backend can run every member of the ensemble."""
        for i, g in enumerate(ensemble.members):
            ok, why = self.supports(g)
            if not ok:
                return False, f"member {i} ({g.describe()}): {why}"
        return True, ""

    def _require_support(self, graph: TaskGraph) -> None:
        ok, why = self.supports(graph)
        if not ok:
            raise ValueError(f"runtime {self.name} cannot run {graph.describe()}: {why}")

    def _require_ensemble_support(self, ensemble: GraphEnsemble) -> None:
        ok, why = self.supports_ensemble(ensemble)
        if not ok:
            raise ValueError(f"runtime {self.name} cannot run ensemble: {why}")

    # -- execution ---------------------------------------------------------

    @abc.abstractmethod
    def build(self, graph: TaskGraph) -> Callable[[jax.Array], Any]:
        """Compile an executor: initial (W, payload) state -> final state."""

    @abc.abstractmethod
    def build_ensemble(
        self, ensemble: GraphEnsemble
    ) -> Callable[[Tuple[jax.Array, ...]], Tuple[jax.Array, ...]]:
        """Compile a concurrent executor for K independent member graphs.

        Takes / returns one (W_k, payload_k) state per member. Member
        dataflows never mix; the backend only decides how much cross-member
        scheduling freedom exists (see GraphEnsemble docstring).
        """

    def dispatches_per_run(self, graph: TaskGraph) -> int:
        """Host->device dispatch count for one execution (overhead model)."""
        return 1

    def ensemble_dispatches_per_run(self, ensemble: GraphEnsemble) -> int:
        """Dispatch count for one ensemble execution.

        Round-robin backends pay every member's dispatches; single-program
        backends override this to 1.
        """
        return sum(self.dispatches_per_run(g) for g in ensemble.members)

    def _ensemble_inits(self, ensemble: GraphEnsemble) -> Tuple[jax.Array, ...]:
        from repro.core.task_kernels import initial_state

        return tuple(
            initial_state(g.width, g.payload, g.seed) for g in ensemble.members
        )

    def execute(self, graph: TaskGraph, init: Optional[jax.Array] = None) -> np.ndarray:
        """Run the graph once, returning the final (width, payload) state."""
        from repro.core.task_kernels import initial_state

        self._require_support(graph)
        if init is None:
            init = initial_state(graph.width, graph.payload, graph.seed)
        fn = self.build(graph)
        out = fn(_fresh(init))
        return np.asarray(jax.block_until_ready(out))

    def execute_ensemble(
        self,
        ensemble: GraphEnsemble,
        inits: Optional[Sequence[jax.Array]] = None,
    ) -> Tuple[np.ndarray, ...]:
        """Run all members concurrently; returns each member's final state."""
        self._require_ensemble_support(ensemble)
        if inits is None:
            inits = self._ensemble_inits(ensemble)
        elif len(inits) != len(ensemble.members):
            raise ValueError(
                f"got {len(inits)} initial states for "
                f"{len(ensemble.members)} ensemble members"
            )
        fn = self.build_ensemble(ensemble)
        outs = fn(tuple(_fresh(x) for x in inits))
        outs = jax.block_until_ready(outs)
        return tuple(np.asarray(o) for o in outs)

    # -- resilience --------------------------------------------------------

    def build_ensemble_launches(
        self, ensemble: GraphEnsemble
    ) -> EnsembleLaunchPlan:
        """A host-steppable launch schedule for resilient execution.

        Backends whose whole run is one opaque XLA program cannot expose
        launch boundaries — fault recovery for them is whole-run restart
        (checkpoint/elastic.py). pallas_step overrides this with its real
        blocked-launch structure.
        """
        raise NotImplementedError(
            f"runtime {self.name} has no launch-granular schedule; "
            f"resilient execution needs pallas_step (or whole-run restart "
            f"via checkpoint.elastic.run_with_restarts)")

    def execute_ensemble_resilient(
        self,
        ensemble: GraphEnsemble,
        *,
        plan=None,
        policy=None,
    ):
        """Run the ensemble under the resilience engine.

        ``plan`` is a repro.resilience FaultPlan (None = no injection; the
        engine's per-launch hook is a single predicate check, so the
        no-fault path adds no work beyond the host-stepped dispatch).
        Returns a repro.resilience.ResilientResult whose ``outputs`` match
        ``execute_ensemble``.
        """
        from repro.resilience import run_resilient

        self._require_ensemble_support(ensemble)
        return run_resilient(self, ensemble, plan=plan, policy=policy)

    # -- tracing -----------------------------------------------------------

    def _build_traced(self, graph: TaskGraph) -> Callable[[jax.Array], Any]:
        """An executor that records spans into ``self.tracer`` as it runs.

        Default (fused / bsp_scan / overlap — backends whose whole loop
        lives in one jit, opaque to host-side tracing): two run-level
        spans — ``dispatch`` is the host call issuing the program(s),
        ``compute.interior`` the wait for the device to drain. Backends
        with real host boundaries (bsp, serialized, pallas_step) override
        this with per-step / per-launch / per-phase spans.
        """
        fn = self.build(graph)
        tr = self.tracer
        dispatches = self.dispatches_per_run(graph)

        def run(arg):
            with tr.span("run_dispatch", "dispatch", runtime=self.name,
                         dispatches=dispatches):
                out = fn(arg)
            with tr.span("device_drain", "compute.interior",
                         runtime=self.name):
                out = jax.block_until_ready(out)
            return out

        return run

    def trace_once(self, graph: TaskGraph,
                   init: Optional[jax.Array] = None) -> np.ndarray:
        """Run the graph once recording spans (a SEPARATE execution from
        `measure` — the timed path stays untouched). The traced executor
        is warmed up first and the warmup's spans dropped, so compile time
        never pollutes the attribution; build-time decision records
        survive. With the null tracer this is just `execute`."""
        tr = self.tracer
        if not tr.enabled:
            return self.execute(graph, init)
        from repro.core.task_kernels import initial_state

        self._require_support(graph)
        if init is None:
            init = initial_state(graph.width, graph.payload, graph.seed)
        init = jax.block_until_ready(jax.device_put(init))
        fn = self._build_traced(graph)
        mark = len(tr.spans)
        jax.block_until_ready(fn(_fresh(init)))  # compile + probe warmup
        del tr.spans[mark:]
        out = fn(_fresh(init))
        return np.asarray(jax.block_until_ready(out))

    # -- measurement -------------------------------------------------------

    def measure(
        self,
        graph: TaskGraph,
        *,
        reps: int = 3,
        warmup: int = 1,
        init: Optional[jax.Array] = None,
    ) -> Tuple[GrainSample, TimingStats]:
        """Timed execution -> a GrainSample for the METG machinery."""
        from repro.core.task_kernels import initial_state

        self._require_support(graph)
        if init is None:
            init = initial_state(graph.width, graph.payload, graph.seed)
        init = jax.block_until_ready(jax.device_put(init))
        fn = self.build(graph)

        # backends may donate their input buffers; each invocation gets a
        # fresh copy, made OUTSIDE the timed region
        for _ in range(max(warmup, 1)):
            jax.block_until_ready(fn(_fresh(init)))
        walls: List[float] = []
        for _ in range(reps):
            arg = jax.block_until_ready(_fresh(init))
            t0 = time.perf_counter()
            jax.block_until_ready(fn(arg))
            walls.append(time.perf_counter() - t0)

        stats = TimingStats(
            best=min(walls),
            mean=sum(walls) / len(walls),
            walls=tuple(walls),
            dispatches=self.dispatches_per_run(graph),
        )
        sample = GrainSample(
            iterations=graph.kernel.iterations,
            wall_time=stats.best,
            total_flops=float(graph.total_flops()),
            num_tasks=graph.num_tasks,
            cores=len(self.devices),
        )
        return sample, stats

    def measure_ensemble(
        self,
        ensemble: GraphEnsemble,
        *,
        reps: int = 3,
        warmup: int = 1,
    ) -> Tuple[GrainSample, TimingStats]:
        """Timed concurrent execution of all members -> one aggregate sample.

        The aggregate GrainSample (see metg.combine_grain_samples) sums
        FLOPs/tasks across members against the single measured ensemble
        wall, so `compute_metg` works unchanged on ensemble sweeps.
        """
        self._require_ensemble_support(ensemble)
        inits = tuple(
            jax.block_until_ready(jax.device_put(x))
            for x in self._ensemble_inits(ensemble)
        )
        fn = self.build_ensemble(ensemble)
        for _ in range(max(warmup, 1)):
            jax.block_until_ready(fn(tuple(_fresh(x) for x in inits)))
        walls: List[float] = []
        for _ in range(reps):
            args = jax.block_until_ready(tuple(_fresh(x) for x in inits))
            t0 = time.perf_counter()
            jax.block_until_ready(fn(args))
            walls.append(time.perf_counter() - t0)

        stats = TimingStats(
            best=min(walls),
            mean=sum(walls) / len(walls),
            walls=tuple(walls),
            dispatches=self.ensemble_dispatches_per_run(ensemble),
        )
        members = [
            GrainSample(
                iterations=g.kernel.iterations,
                wall_time=stats.best,
                total_flops=float(g.total_flops()),
                num_tasks=g.num_tasks,
                cores=len(self.devices),
            )
            for g in ensemble.members
        ]
        return combine_grain_samples(members, wall_time=stats.best), stats

    def measure_launch_plan(
        self,
        ensemble: GraphEnsemble,
        *,
        reps: int = 3,
        warmup: int = 1,
    ) -> Tuple[GrainSample, TimingStats]:
        """Timed host-stepped execution of ``build_ensemble_launches``.

        One dispatch + host sync per launch — the cadence of the
        resilience engine and the serving loop, where a per-launch
        collective is paid at every host boundary instead of amortizing
        inside one scanned program. Transport choices that only differ
        in per-dispatch cost (gather impls, async halo transports) are
        invisible to `measure`'s fused executor and measurable here.
        """
        import jax.numpy as jnp

        self._require_ensemble_support(ensemble)
        lp = self.build_ensemble_launches(ensemble)
        inits = tuple(
            jax.block_until_ready(jax.device_put(x))
            for x in self._ensemble_inits(ensemble)
        )
        acts = np.asarray(lp.acts, dtype=np.float32)
        t0s = [jnp.asarray(lp.launch_t0(l), jnp.int32)
               for l in range(lp.num_launches)]

        def run_once():
            carry = jax.block_until_ready(
                lp.init_fn(tuple(_fresh(x) for x in inits)))
            for l in range(lp.num_launches):
                carry = jax.block_until_ready(
                    lp.launch_fn(carry, acts[l], t0s[l]))
            return lp.finalize(carry)

        for _ in range(max(warmup, 1)):
            jax.block_until_ready(run_once())
        walls: List[float] = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(run_once())
            walls.append(time.perf_counter() - t0)

        stats = TimingStats(
            best=min(walls),
            mean=sum(walls) / len(walls),
            walls=tuple(walls),
            dispatches=1 + lp.num_launches,
        )
        members = [
            GrainSample(
                iterations=g.kernel.iterations,
                wall_time=stats.best,
                total_flops=float(g.total_flops()),
                num_tasks=g.num_tasks,
                cores=len(self.devices),
            )
            for g in ensemble.members
        ]
        return combine_grain_samples(members, wall_time=stats.best), stats


# ----------------------------------------------------------------- registry

_REGISTRY: dict = {}


def register(cls):
    _REGISTRY[cls.name] = cls
    return cls


def get_runtime(name: str, **kwargs) -> Runtime:
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown runtime {name!r}; known: {sorted(_REGISTRY)}") from None
    return cls(**kwargs)


def available_runtimes() -> List[str]:
    return sorted(_REGISTRY)
