"""`pallas_step` runtime — fused megakernel launches, temporally blockable.

The sixth rung of the backend ladder: like `bsp_scan` the whole timestep
loop lives in one jit (shard_map over devices, lax.scan over launches), but
where every other backend emits one gather + one combine + one body op per
dependency slot per step, this backend lowers the ENTIRE step — gather the
padded dependency slots from the previous-state buffer, masked-mean
combine, grain-size body — into a single `pallas_call`
(repro.kernels.taskbench_step). At fine grain the other backends' floor
measures XLA op-dispatch overhead; this one's floor is the kernel itself,
which is the fused per-task control path Task Bench (SC'20) shows is needed
for sub-microsecond METG.

Temporal blocking (``steps_per_launch=S``): after PR 2 the remaining
per-step cost was one kernel launch plus one ring halo exchange PER STEP.
Since every halo-expressible pattern advances at most ``r`` rows of
influence per step, exchanging a deep halo of ``S*r`` rows once lets each
device advance S full timesteps locally before communicating again — the
classic deep-halo stencil optimization applied to the whole Task Bench
step. The loop becomes ``ceil((T-1)/S)`` launches; each launch's kernel
iterates combine + body S times on a working buffer whose valid region
shrinks by ``r`` rows per inner step (kernels/taskbench_step.py has the
kernel-side contract). Per-row combine weights ride along: they are
indexed by fixed global row id, so ONE deep exchange of the weight (and,
for gather/onehot, relative-offset) tables before the scan gives every
working row its exact edge-clipped weights at every depth. Heterogeneous
``steps`` freeze at launch granularity through a per-depth activity mask
baked host-side into the scan inputs — the final partial launch of any run
is the same mask (the "masked tail"). ``steps_per_launch`` accepts an int,
``"auto"`` (VMEM-budget tuner, kernels/schedule.py), and defaults to 1
(the PR-2 per-step behavior).

Dataflow: points are block-distributed like `bsp`; halo-expressible
patterns exchange ``S*r`` edge rows per ring direction
(`_halo.exchange_halos`, multi-hop when the depth exceeds a block), and the
megakernel gathers from the halo-EXTENDED local block through
host-precomputed (idx, wgt) operands — weights pre-normalized to
1/live-count and zero-dep rows self-padded, so the kernel has no
edge/wrap/empty branches.

Ensembles: a stackable ensemble with a uniform KernelSpec runs ALL K
members' combines and bodies in the SAME launch (the megakernel's leading K
axis); one deep ring exchange moves every member's halos for S steps at
once. Mixed-spec or ragged-shape ensembles fall back to one launch per
member inside the same jitted scan.

Double-buffered deep-halo pipeline (``pipeline=True``, the default): with
blocking alone every deep exchange still sits serially between launches, so
at fine grain the wall/step floor measures ring latency. The pipelined
schedule splits each blocked launch into a boundary phase (the 2*S*r edge
rows whose S-step light cone touches the incoming halo) and an interior
phase (everything else), and issues the NEXT launch's exchange on the
boundary outputs — which are exactly the rows the neighbors need — before
running the interior, so in steady state the exchange of launch l+1 is in
flight under the interior compute of launch l (`_halo.exchange_edges_start`
/ the HaloHandle carried in the scan are the double-buffered halo slots).
``pipeline=False`` is the serial-exchange ablation, mirroring the overlap
runtime's ``overlap=False``; blocks with no interior (B <= 2*S*r, where
splitting buys nothing and costs a second launch) fall back to it
automatically. The scan's final iteration issues one dead exchange (uniform
bodies); its cost is 1/L of the exchanges and it keeps the loop rolled.

Beyond halos — the pattern→plan dispatch (DESIGN.md §7): non-local
dependence patterns have no bounded per-step reach, so ``supports`` routes
every graph to one of three PLANS instead of refusing anything non-halo:

  halo       halo-expressible period-1 patterns — everything above.
  stride     butterfly patterns (fft/tree). Step t pairs p with
             p XOR 2^(t-1 mod log2 W): in-block strides materialize the
             partner rows with an XOR layout shuffle (reshape + pair
             swap, no gather), block strides with one XOR collective
             permute (`_halo.exchange_stride_start/join`) delivering the
             partner block; the megakernel then combines the stacked
             [x | partner] halves with the gather-free "pair" mode —
             elementwise (a+b)*0.5, bit-identical to the fused oracle
             (gather/onehot stay selectable as ablations). One launch +
             at most one collective per step; per-step by construction
             (temporal blocking a stride plan needs the XOR-subgroup
             closure of the launch window, which is the full gather — so
             EXPLICITLY blocked requests route to:)
  allgather  global patterns (spread, all_to_all) and blocked butterfly,
             for widths <= ``gather_width_cap``: one full-state gather
             per launch (`_halo.gather_global`), every gathered row
             advances exactly (no valid-span shrink), and TIME-VARYING
             (S, W, D) idx/wgt tables — butterfly slots selected per
             depth, spread's rotation computed in-scan — drive the
             onehot combine at each depth. Blocking trades replicated
             compute for 1/S the collectives; kernels/schedule.py's
             ``gathered_pays_off`` gates "auto".

Options: combine="window"|"gather"|"onehot" (see taskbench_step.py; the
non-halo plans cannot window — the default resolves to "pair" on the
stride plan and, on the allgather plan, to "gather" off-TPU / "onehot"
on TPU, with explicit "gather"/"onehot" honored as ablations — see
``_plan_combine``), steps_per_launch=int|"auto", pipeline=True|False,
block_rows, unroll, gather_width_cap=int, halo_impl="xla"|"ppermute".
"""
from __future__ import annotations

from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import patterns as _patterns
from repro.core.graph import GraphEnsemble, TaskGraph
from repro.core.runtimes import _halo
from repro.core.runtimes.base import EnsembleLaunchPlan, register
from repro.core.runtimes.bsp import AXIS, _BspBase
from repro.core.task_kernels import KernelSpec
from repro.kernels import ops as _kops
from repro.kernels import probes as _probes
from repro.kernels import schedule as _schedule
from repro.kernels.taskbench_step import (
    WEIGHT_ACCUM_DTYPE,
    WEIGHT_DTYPE,
    finalize_weights,
    prepare_step_operands,
)
from repro.launch.mesh import make_row_member_mesh

#: Execution-plan kinds the pattern→plan dispatch resolves to.
PLAN_HALO = "halo"
PLAN_STRIDE = "stride"
PLAN_ALLGATHER = "allgather"
PLAN_KINDS = (PLAN_HALO, PLAN_STRIDE, PLAN_ALLGATHER)

#: Second mesh axis of the 2D (row, member) mesh: stacked ensembles shard
#: K members along it (``member_shards`` option), while every halo /
#: stride / gather transport keeps running over AXIS — in a 2D mesh a
#: named-axis collective only spans its own axis, so row transports
#: never cross the member axis by construction (DESIGN.md §12).
MEMBER_AXIS = "member"


def _ext_dep_operands(
    graph: TaskGraph, block: int, halo: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(W, D) idx/wgt into the halo-extended local block, for one timestep.

    Local row i of a block starting at global row p0 gathers from an
    extended buffer ext = [p0-halo .. p0+B-1+halo] (mod W, via ring
    exchange), so dependency q of global row p maps to extended position
    (p mod B) + halo + o where o is q's signed window offset from p. All
    halo-expressible patterns have period 1, so ONE slice serves every
    timestep t >= 1.
    """
    r = _patterns.halo_radius(graph)
    if r < 0:
        raise ValueError(f"{graph.pattern} is not halo-expressible")
    if graph.period != 1:
        raise ValueError(f"halo pattern {graph.pattern} must have period 1")
    W = graph.width

    def to_ext(p: int, q: int) -> int:
        for o in range(-r, r + 1):
            if (p + o) % W == q:
                return p % block + halo + o
        raise ValueError(f"dep {q} of point {p} outside halo radius {r}")

    ext_lists: List[List[int]] = [
        [to_ext(p, q) for q in graph.dependencies(1, p)] for p in range(W)
    ]
    selfs = [p % block + halo for p in range(W)]
    return prepare_step_operands(ext_lists, W, selfs)


def _rel_dep_operands(graph: TaskGraph) -> Tuple[np.ndarray, np.ndarray]:
    """(W, D) SIGNED-offset operands for the temporal-blocked gather modes.

    Row p's dependency q is stored as its window offset o (q == (p+o) mod
    W), not an absolute buffer position: offsets are a property of the
    global row alone, so the runtime can deep-halo-exchange these tables
    like state and convert to absolute working-buffer rows with a single
    ``+ arange(M)`` — every extended row then gathers its own dependencies
    at any launch depth. Zero-dep rows self-pad at offset 0.
    """
    r = _patterns.halo_radius(graph)
    if r < 0 or graph.period != 1:
        raise ValueError(f"{graph.pattern} is not halo-expressible")
    W = graph.width
    rel_lists: List[List[int]] = []
    for p in range(W):
        offs: List[int] = []
        for q in graph.dependencies(1, p):
            for o in range(-r, r + 1):
                if (p + o) % W == q:
                    offs.append(o)
                    break
            else:
                raise ValueError(f"dep {q} of point {p} outside halo {r}")
        rel_lists.append(offs)
    return prepare_step_operands(rel_lists, W, [0] * W)


def _self_operands(width: int, block: int) -> Tuple[np.ndarray, np.ndarray]:
    """(W, 1) identity operands (t=0: body only, src = raw local block)."""
    selfs = [p % block for p in range(width)]
    return prepare_step_operands([[] for _ in range(width)], width, selfs)


def _window_operands(
    graph: TaskGraph, halo: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(W, 2*halo+1) per-offset combine weights for the window kernel mode.

    Column halo + o carries the (pre-normalized) weight of the dependency
    at window offset o, so the kernel's combine is a static chain of
    shifted-slice FMAs — no gather. Edge clipping (stencil_1d, dom), the
    per-row keep set (random_nearest), duplicate window wraps (nearest
    with W <= 2r), and the zero-dep self-keep rule are all encoded in the
    weights; idx is unused in this mode (returned as zeros). Weights are
    per GLOBAL row and patterns have period 1, so the same row's weights
    are correct at every timestep — the property the temporal-blocked path
    relies on when it exchanges these tables as deep halos.
    """
    r = _patterns.halo_radius(graph)
    if r < 0 or graph.period != 1:
        raise ValueError(f"{graph.pattern} is not window-expressible")
    W = graph.width
    D = 2 * halo + 1
    # idx is unused in window mode (the kernel substitutes a 1-element
    # dummy); a single column keeps the shard_map row-sharding contract
    # without shipping a dead (W, D) block
    idx = np.zeros((W, 1), dtype=np.int32)
    wgt = np.zeros((W, D), dtype=WEIGHT_ACCUM_DTYPE)
    for p in range(W):
        deps = graph.dependencies(1, p)
        if not deps:
            wgt[p, halo] = 1.0  # zero deps: keep own state (self weight 1)
            continue
        share = 1.0 / len(deps)
        for q in deps:
            for o in range(-r, r + 1):
                if (p + o) % W == q:
                    wgt[p, halo + o] += share
                    break
            else:
                raise ValueError(f"dep {q} of point {p} outside halo {r}")
    return idx, finalize_weights(wgt)


def _stride_slot_tables(
    block: int, stride: int
) -> Tuple[np.ndarray, np.ndarray, bool]:
    """(B, 2) idx/wgt tables for one butterfly period slot (the
    gather/onehot ablations of the stride plan; the default pair combine
    needs no tables).

    Power-of-two width (graph-validated) means every point has exactly the
    two dependencies {p, p XOR stride} at weight 1/2 — a power of two, so
    0.5*a + 0.5*b is bit-identical to the fused oracle's (a + b) / 2
    under every combine. In-block strides (stride < block, which implies
    the partner shares the block since blocks are power-of-two sized)
    address the local rows; block strides address a [local | partner]
    working buffer (partner block at rows [B, 2B)). Returns
    (idx, wgt, off_block)."""
    i = np.arange(block, dtype=np.int32)
    off_block = stride >= block
    partner = (block + i) if off_block else (i ^ stride)
    idx = np.stack([i, partner], axis=1).astype(np.int32)
    wgt = np.full((block, 2), 0.5, dtype=WEIGHT_ACCUM_DTYPE)
    return idx, finalize_weights(wgt), off_block


def _global_slot_operands(graph: TaskGraph) -> Tuple[np.ndarray, np.ndarray]:
    """(period, W, D) idx + pre-normalized wgt tables in GLOBAL row ids.

    The all-gather plan's working buffer is the full state in global
    order, so the graph's own dependency arrays ARE the gather tables —
    no rebasing, any pattern. Weights follow the shared precision policy
    (mask / live-count accumulated wide, rounded once); zero-dep rows
    self-gather at weight 1 (combine_dependencies' keep-own-state rule).
    """
    idx, mask = graph.dependency_arrays()
    acc = np.asarray(mask, WEIGHT_ACCUM_DTYPE)
    live = acc.sum(-1, keepdims=True)
    wgt = acc / np.maximum(live, 1.0)
    zero = live[..., 0] == 0  # (period, W)
    if zero.any():
        P, W, _ = idx.shape
        idx = idx.copy()
        selfs = np.broadcast_to(np.arange(W, dtype=np.int32), (P, W))
        idx[..., 0] = np.where(zero, selfs, idx[..., 0])
        wgt[..., 0] = np.where(zero, 1.0, wgt[..., 0])
    return idx, finalize_weights(wgt)


def _spread_base_operands(graph: TaskGraph) -> Tuple[np.ndarray, np.ndarray]:
    """(W, D) t=1 tables for spread; timestep t rotates idx by +(t-1) mod W.

    spread's dependence set {(p + i*stride + (t-1)) mod W} shifts RIGIDLY
    with t, so one base table plus an in-scan additive rotation replaces
    the period-W stack ``_global_slot_operands`` would materialize. The
    live count |{i*stride mod W}| is point- and time-invariant, so the
    weight table never rotates."""
    W = graph.width
    lists = [graph.dependencies(1, p) for p in range(W)]
    D = max(1, max(len(l) for l in lists))
    idx = np.zeros((W, D), dtype=np.int32)
    acc = np.zeros((W, D), dtype=WEIGHT_ACCUM_DTYPE)
    for p, deps in enumerate(lists):
        share = 1.0 / len(deps)
        for j, q in enumerate(deps):
            idx[p, j] = q
            acc[p, j] = share
    return idx, finalize_weights(acc)


def _self_tables(block: int) -> Tuple[jax.Array, jax.Array]:
    """(B, 1) per-device identity tables for the t=0 body-only launch
    (device-invariant, so closures can carry them into shard_map)."""
    return (jnp.arange(block, dtype=jnp.int32)[:, None],
            jnp.ones((block, 1), WEIGHT_DTYPE))


def _xor_swap(x: jax.Array, stride: int) -> jax.Array:
    """Rows permuted by i -> i XOR stride (a power of two dividing the
    row count): reshape to (pairs, 2, stride, ...) and swap the pair axis
    — a pure layout shuffle, no gather. This is what makes the stride
    plan's in-block butterfly combine gather-free."""
    B = x.shape[0]
    g = x.reshape(B // (2 * stride), 2, stride, *x.shape[1:])
    return jnp.flip(g, axis=1).reshape(x.shape)


def _extend_state(s: jax.Array, depth: int, num_devices: int,
                  *, row_axis: int = 0) -> jax.Array:
    """Halo-extend a local block by ``depth`` rows per side (ring exchange;
    multi-hop past the block). Identity at depth 0."""
    if depth == 0:
        return s
    rl, rr = _halo.exchange_halos(s, depth, num_devices, AXIS,
                                  row_axis=row_axis)
    return jnp.concatenate([rl, s, rr], axis=row_axis)


def _rebase_rows(rel: jax.Array, *, row_axis: int = 0) -> jax.Array:
    """Signed window offsets -> absolute rows of THIS working buffer
    (``+ arange(M)``, clipped; the clip only ever binds on edge-garbage
    rows, which are never consumed by valid rows)."""
    m = rel.shape[row_axis]
    shape = [1] * rel.ndim
    shape[row_axis] = m
    rows = jnp.arange(m, dtype=jnp.int32).reshape(shape)
    return jnp.clip(rel + rows, 0, m - 1)


def _extend_tables(idx: jax.Array, wgt: jax.Array, depth: int,
                   num_devices: int, mode: str, *, row_axis: int = 0):
    """Deep-exchange the per-row operand tables ONCE for a blocked run.

    Weights (per global row, depth-invariant) extend exactly like state.
    Gather/onehot offset tables additionally rebase from signed offsets to
    absolute working-buffer rows (``_rebase_rows``). Window mode returns
    idx untouched (it is a dummy the kernel replaces).
    """
    wext = _extend_state(wgt, depth, num_devices, row_axis=row_axis)
    if mode == "window":
        return idx, wext
    rel = _extend_state(idx, depth, num_devices, row_axis=row_axis)
    return _rebase_rows(rel, row_axis=row_axis), wext


class _PhaseTables(NamedTuple):
    """Per-phase operand tables for one pipelined member (leading K axis).

    ``i_int``/``w_int`` cover the interior working buffer (the owned B
    rows); ``i_bnd``/``w_bnd`` cover the fused (K, 6*depth) boundary
    working buffer — rows [left buffer..., right buffer...] — matching
    ``taskbench_step_boundary``'s layout.
    """

    i_int: jax.Array
    w_int: jax.Array
    i_bnd: jax.Array
    w_bnd: jax.Array


def _phase_tables(idx: jax.Array, wgt: jax.Array, depth: int,
                  num_devices: int, mode: str) -> _PhaseTables:
    """Deep-exchange the tables once and slice them per pipeline phase.

    All arrays carry a leading K axis; rows live on axis 1. The extended
    table wext has B + 2*depth rows covering global rows [p0 - depth,
    p0 + B + depth): the interior buffer (owned rows [p0, p0 + B)) is
    wext[depth : depth + B], the left boundary buffer (rows [p0 - depth,
    p0 + 2*depth)) is wext[:3*depth], the right one wext[B - depth:].
    Gather/onehot offsets are rebased per buffer AFTER slicing — each
    phase's idx addresses its own working buffer.
    """
    K, B = wgt.shape[0], wgt.shape[1]

    def phases(ext):
        interior = jax.lax.slice_in_dim(ext, depth, depth + B, axis=1)
        boundary = jnp.concatenate([  # fused rows: [left 3d | right 3d]
            jax.lax.slice_in_dim(ext, 0, 3 * depth, axis=1),
            jax.lax.slice_in_dim(ext, B - depth, B + 2 * depth, axis=1),
        ], axis=1)
        return interior, boundary

    w_int, w_bnd = phases(_extend_state(wgt, depth, num_devices, row_axis=1))
    if mode == "window":  # idx is a dummy the kernel replaces
        i_int = jnp.zeros((K, 1, 1), jnp.int32)
        i_bnd = jnp.zeros((K, 1, 1), jnp.int32)
    else:
        rel_int, rel_bnd = phases(
            _extend_state(idx, depth, num_devices, row_axis=1))
        i_int = _rebase_rows(rel_int, row_axis=1)
        i_bnd = _rebase_rows(rel_bnd, row_axis=1)
    return _PhaseTables(i_int, w_int, i_bnd, w_bnd)


def _pipelined_launch(s, hl, hr, a, ph: _PhaseTables, depth: int,
                      num_devices: int, kwb: dict, impl: str = "xla"):
    """One software-pipelined blocked launch on stacked (K, B, payload)
    state. Steady-state schedule (DESIGN.md §6):

      1. boundary phase — consumes the halo received for THIS launch
         (``hl``/``hr``, issued at the end of the previous launch);
      2. the NEXT launch's deep exchange starts on the boundary outputs
         (they ARE the edge rows the neighbors need);
      3. the interior phase — no data dependence on the halo, the boundary
         launch, or the in-flight collective, so the scheduler may run the
         exchange under it.

    Returns (s_next, HaloHandle for the next launch).
    """
    B = s.shape[1]
    bl = jnp.concatenate(
        [hl, jax.lax.slice_in_dim(s, 0, 2 * depth, axis=1)], axis=1)
    br = jnp.concatenate(
        [jax.lax.slice_in_dim(s, B - 2 * depth, B, axis=1), hr], axis=1)
    bl_out, br_out = _kops.taskbench_boundary(
        bl, br, ph.i_bnd, ph.w_bnd, a, depth=depth, **kwb)
    handle = _halo.exchange_edges_start(
        bl_out, br_out, num_devices, AXIS, row_axis=1, impl=impl)
    mid = _kops.taskbench_interior(
        s, ph.i_int, ph.w_int, a, depth=depth, **kwb)
    return jnp.concatenate([bl_out, mid, br_out], axis=1), handle


def _prologue_exchange(state, depth, num_devices, impl: str = "xla"):
    """Start the FIRST blocked launch's exchange on the t=0 state's edges
    (the pipeline's fill step; the scan body then keeps one exchange in
    flight per launch)."""
    B = state.shape[1]
    return _halo.exchange_edges_start(
        jax.lax.slice_in_dim(state, 0, depth, axis=1),
        jax.lax.slice_in_dim(state, B - depth, B, axis=1),
        num_devices, AXIS, row_axis=1, impl=impl)


def _act_schedule(
    member_steps: Sequence[int], lockstep_steps: int, s: int
) -> np.ndarray:
    """(L, K, S) per-depth activity masks for the blocked launch loop.

    Launch l's inner step d executes lockstep timestep t = 1 + l*S + d;
    member k is active iff t < T_k (its own horizon) — the same predicate
    the per-step backends apply with `jnp.where`, here frozen INTO the
    launch schedule host-side. The final launch of any run carries the
    masked tail ((T-1) mod S trailing zeros for every member).
    """
    L = max(1, -(-(lockstep_steps - 1) // s)) if lockstep_steps > 1 else 0
    t = 1 + (np.arange(L)[:, None, None] * s + np.arange(s)[None, None, :])
    msteps = np.asarray(member_steps, np.int64)[None, :, None]
    return (t < msteps).astype(np.float32)


class _ResolvedPlan(NamedTuple):
    """What one graph will actually run: a plan kind + launch depth.

    ``reason`` names the verdict source when the resolution involved a
    cost-model judgment (plan re-routing, tuner declines) — empty for
    purely structural picks."""

    kind: str
    steps_per_launch: int
    reason: str = ""


@register
class PallasStepRuntime(_BspBase):
    name = "pallas_step"

    # ------------------------------------------------------ plan dispatch

    def _gather_width_cap(self) -> int:
        return int(self.options.get(
            "gather_width_cap", _schedule.DEFAULT_GATHER_WIDTH_CAP))

    def _cost_model(self, payload: Optional[int] = None):
        """The CostModel pricing this runtime's scheduling verdicts.

        The ``cost_model`` option (a CostModel, a to_dict()-shaped dict,
        or a cache-file path) is the EXPLICIT tier of the precedence;
        unset falls through to probes.default_cost_model (env > cached
        probes > analytic). Only ranks/sizes schedules — numerics are
        model-independent."""
        return _probes.coerce_cost_model(
            self.options.get("cost_model"),
            devices=len(self.devices), payload=payload)

    def plan_for(self, graph: TaskGraph) -> Tuple[Optional[str], str]:
        """pattern -> execution plan kind, or (None, reason).

        halo-expressible period-1 patterns take the halo plan (ring
        exchanges, every schedule above); butterfly patterns the stride
        plan (XOR block permutes); anything else — and butterfly when a
        blocked schedule is requested — the all-gather plan, capped at
        ``gather_width_cap`` rows.
        """
        D = len(self.devices)
        if graph.width % D != 0:
            return None, f"width {graph.width} not divisible by {D} devices"
        r = _patterns.halo_radius(graph)
        if r >= 0 and graph.period == 1:
            # no r <= block restriction: _halo.exchange_halos goes
            # multi-hop when a (deep) halo exceeds the local block
            return PLAN_HALO, ""
        if graph.pattern in _patterns.BUTTERFLY_PATTERNS and graph.width > 1:
            # W=1 degenerates to a pure self-dependency (partner = p XOR 1
            # falls outside the width), which breaks the stride plan's
            # exactly-two-deps tables; it falls through to the all-gather
            # plan (W=1 is always under the cap), whose tables come from
            # the graph's own dependency arrays and handle it exactly.
            return PLAN_STRIDE, ""
        cap = self._gather_width_cap()
        if graph.width <= cap:
            return PLAN_ALLGATHER, ""
        return None, (
            f"pattern {graph.pattern} at width {graph.width} fits no "
            f"pallas_step plan (halo: halo-expressible period-1 patterns "
            f"at any width; stride: butterfly fft/tree; allgather: any "
            f"pattern up to gather_width_cap={cap} rows) — fall back to "
            f"the `fused` backend, which runs every pattern at any width "
            f"[verdict source: "
            f"{self._cost_model(graph.payload).describe(graph.width)}]"
        )

    def supports(self, graph: TaskGraph):
        plan, why = self.plan_for(graph)
        return (True, "") if plan is not None else (False, why)

    def _schedule_for_graph(self, graph: TaskGraph) -> _ResolvedPlan:
        """The (plan, steps_per_launch) this runtime will execute.

        The stride plan is per-step by construction (see module
        docstring); an EXPLICIT blocked request on a butterfly graph
        re-routes to the all-gather plan when the width fits under the
        cap and the resolver actually grants a depth > 1 —
        `dispatches_per_run` reports whatever this returns, so launch
        accounting can never drift from the executed schedule."""
        plan, why = self.plan_for(graph)
        if plan is None:
            raise ValueError(
                f"runtime {self.name} cannot run {graph.describe()}: {why}")
        if plan == PLAN_HALO:
            return _ResolvedPlan(plan, self._graph_steps_per_launch(graph))
        opt = self.options.get("steps_per_launch")
        if plan == PLAN_STRIDE:
            # Two routes re-route a butterfly to the blocked all-gather
            # plan. An EXPLICIT depth (the user's ablation choice) always
            # did. "auto" newly can — but only under a MEASURED cost
            # model: the analytic rules cannot rank the plans
            # (gathered_pays_off compares blocked gathers against
            # per-step GATHERS, not against the stride plan it would
            # displace here, whose in-block slots need no collective and
            # whose pair combine is gather-free), while measured
            # launch/stride/gather/row-step walls can
            # (schedule.gathered_beats_strides). With the analytic
            # fallback "auto" keeps the stride plan — bit-identical to
            # the pre-measurement behavior.
            if opt in (None, 1):
                return _ResolvedPlan(plan, 1)
            if _schedule.is_auto(opt):
                if graph.width > self._gather_width_cap():
                    return _ResolvedPlan(plan, 1)
                model = self._cost_model(graph.payload)
                s = self._gathered_steps_per_launch(graph)
                if s <= 1:
                    return _ResolvedPlan(plan, 1)
                strides = _patterns.butterfly_slot_strides(graph)
                B = self._block(graph)
                beats, why = _schedule.gathered_beats_strides(
                    width=graph.width, block=B, steps_per_launch=s,
                    off_block_strides=sum(1 for st in strides if st >= B),
                    period=len(strides), model=model,
                    impl=self._halo_impl())
                if beats:
                    return _ResolvedPlan(PLAN_ALLGATHER, s, why)
                return _ResolvedPlan(plan, 1, why)
            if graph.width <= self._gather_width_cap():
                s = self._gathered_steps_per_launch(graph)
                if s > 1:
                    return _ResolvedPlan(PLAN_ALLGATHER, s,
                                         "explicit blocked request")
            return _ResolvedPlan(plan, 1)
        return _ResolvedPlan(plan, self._gathered_steps_per_launch(graph))

    def _gathered_steps_per_launch(self, graph: TaskGraph) -> int:
        return _schedule.resolve_steps_per_launch_gathered(
            self.options.get("steps_per_launch"),
            width=graph.width, block=self._block(graph),
            max_deps=graph.max_deps, payload=graph.payload,
            total_steps=graph.steps,
            combine=self._plan_combine(PLAN_ALLGATHER),
            # mirror what the launch actually holds: period-1 patterns
            # keep one static table pair, not S per-depth tables
            time_varying=graph.pattern == "spread" or graph.period > 1,
            model=self._cost_model(graph.payload),
        )

    # ------------------------------------------------------------ operands

    def _combine_mode(self) -> str:
        mode = str(self.options.get("combine", "window"))
        if mode not in ("window", "gather", "onehot"):
            # "pair" is in the kernel's COMBINE_MODES but is an INTERNAL
            # lowering the stride plan selects itself — as a runtime
            # option it would crash the halo plan's operand layout, so
            # every unknown/internal mode is rejected up front
            raise ValueError(
                f"unknown combine option {mode!r}: choose window, gather, "
                f"or onehot ('pair' is the stride plan's internal "
                f"lowering, selected automatically)")
        return mode

    def _plan_combine(self, plan: str) -> str:
        """Combine mode under a plan. halo honors the option as-is; the
        stride/allgather working buffers are gathered-row addressed, so
        the window (shifted-slice) combine cannot express them and the
        default ("window"/unset) resolves per plan:

          stride     "pair" — the partner row is materialized by an XOR
                     layout shuffle (in-block) or a block permute
                     (off-block), so the kernel's combine is an
                     elementwise (a + b) * 0.5: gather-free, exact, and
                     Mosaic-friendly (slices and adds only). This is the
                     butterfly analogue of the halo plan's window mode.
          allgather  "onehot" on TPU — the portable MXU lowering, since a
                     Mosaic row gather may not lower (DESIGN.md §7) —
                     and "gather" elsewhere, where fancy indexing lowers
                     fine and the onehot's (W, W) matrix build per step
                     is pure overhead.

        An explicit "gather"/"onehot" option is honored on both plans
        (the ablations); all selections are bit-identical per plan (same
        tables, same weights, exact 0.5 halving)."""
        mode = self._combine_mode()
        if plan == PLAN_HALO or mode in ("gather", "onehot"):
            return mode
        if plan == PLAN_STRIDE:
            return "pair"
        return "onehot" if jax.default_backend() == "tpu" else "gather"

    def _operands(self, graph: TaskGraph, halo: int,
                  block: Optional[int] = None):
        """Host-built (idx, wgt, idx0, wgt0) for one member graph (S=1).

        The t>=1 operands follow the selected combine mode; the t=0 (body
        only) call is always a 1-column self window, which is identical
        across modes (window offset 0 == gather of own row).
        ``block`` overrides the per-device row count (the K-sharded 2D
        mesh shards rows over Dr < D devices, so its blocks are larger
        than ``_block``'s 1D default).
        """
        B = self._block(graph) if block is None else block
        if self._combine_mode() == "window":
            idx, wgt = _window_operands(graph, halo)
        else:
            idx, wgt = _ext_dep_operands(graph, B, halo)
        idx0, wgt0 = _self_operands(graph.width, B)
        return idx, wgt, idx0, wgt0

    def _blocked_operands(self, graph: TaskGraph, halo: int,
                          block: Optional[int] = None):
        """Host-built (idx, wgt, idx0, wgt0) for the blocked path.

        Window mode reuses the per-global-row weight table; gather/onehot
        switch to SIGNED offsets (_rel_dep_operands) so the tables can be
        deep-halo-exchanged and rebased onto the working buffer in-scan.
        """
        B = self._block(graph) if block is None else block
        if self._combine_mode() == "window":
            idx, wgt = _window_operands(graph, halo)
        else:
            idx, wgt = _rel_dep_operands(graph)
        idx0, wgt0 = _self_operands(graph.width, B)
        return idx, wgt, idx0, wgt0

    def _kernel_kw(self, spec: KernelSpec, combine: Optional[str] = None) -> dict:
        kw = dict(
            kind=spec.kind, iterations=spec.iterations, scratch=spec.scratch,
            combine=combine or self._combine_mode(),
        )
        if self.options.get("block_rows"):
            kw["block_rows"] = int(self.options["block_rows"])
        return kw

    # ---------------------------------------------------------- pipelining

    def _pipeline_requested(self) -> bool:
        """``pipeline=False`` is the serial-exchange ablation (mirrors the
        overlap runtime's ``overlap=False``); default on."""
        return bool(self.options.get("pipeline", True))

    def _halo_impl(self) -> str:
        """Transport for the pipelined edge exchange: "xla" (fused
        single-collective default) or "ppermute" (per-direction; isolates
        the pure scheduling effect in ablations)."""
        return str(self.options.get("halo_impl", "xla"))

    def _gather_impl(self, width: int) -> str:
        """Transport for the all-gather plan's ``gather_global``.

        ``gather_impl`` option: an explicit registry name wins; "auto"
        (default) follows a non-default ``halo_impl`` (so ppermute/chaos
        ablations keep injecting into the gather, the pre-2D behavior)
        and otherwise asks the schedule layer to rank chunked vs
        monolithic at this (devices, width) — measured walls when the
        cost model has the devices-dimension probes, the ~sqrt(D)
        rendezvous heuristic past D >= 16 otherwise. Every choice is
        bit-identical; only the wall changes.
        """
        opt = str(self.options.get("gather_impl", "auto"))
        if opt != "auto":
            if opt not in _halo.GATHER_IMPLS:
                raise ValueError(
                    f"unknown gather impl {opt!r}; known "
                    f"{sorted(_halo.GATHER_IMPLS)}")
            return opt
        halo = self._halo_impl()
        if halo != "xla" and halo in _halo.GATHER_IMPLS:
            return halo
        impl, _reason = _schedule.choose_gather_impl(
            width=width, devices=len(self.devices),
            model=self._cost_model())
        return impl

    def _member_shards(self, ensemble: GraphEnsemble) -> int:
        """Resolved Dk for the stacked ensemble paths (``member_shards``
        option; default 1 = the replicated 1D row mesh). "auto" asks the
        schedule layer to price the (Dr, Dk) split. An explicit Dk that
        cannot shard this ensemble's K is rejected loudly here (the mesh
        builder rejects Dk not dividing the device count the same way)."""
        raw = self.options.get("member_shards", 1)
        K = len(ensemble.members)
        D = len(self.devices)
        if _schedule.is_auto(raw):
            g = ensemble.members[0]
            dk, _reason = _schedule.choose_member_shards(
                devices=D, num_members=K, width=g.width,
                steps_per_launch=self._ensemble_steps_per_launch(ensemble),
                radius=max(_patterns.halo_radius(m)
                           for m in ensemble.members),
                model=self._cost_model(g.payload))
            return dk
        dk = int(raw)
        if dk < 1:
            raise ValueError(f"member_shards must be >= 1, got {dk}")
        if dk == 1:
            return 1
        if K % dk:
            raise ValueError(
                f"member_shards={dk} does not divide this ensemble's "
                f"K={K} members — each member-axis shard needs an equal "
                f"K/Dk slice of the stacked (K, B, payload) state. Pass "
                f"member_shards=1 (or a divisor of {K}) to fall back to "
                f"the replicated 1D row mesh.")
        if D % dk:
            # same loud contract as make_row_member_mesh, raised before
            # any shard_map can fail with an opaque XLA error
            make_row_member_mesh(self.devices, dk, row_axis=AXIS,
                                 member_axis=MEMBER_AXIS)
        return dk

    def _stacked_mesh(self, ensemble: GraphEnsemble):
        """(mesh, dk, Dr) for the stacked paths: the 2D (row, member)
        mesh when member_shards > 1, else the 1D row mesh. Row-axis
        collectives span Dr = D / Dk devices either way (AXIS is the
        leading mesh axis in both)."""
        dk = self._member_shards(ensemble)
        D = len(self.devices)
        if dk == 1:
            return self._mesh(), 1, D
        mesh = make_row_member_mesh(self.devices, dk, row_axis=AXIS,
                                    member_axis=MEMBER_AXIS)
        return mesh, dk, D // dk

    def _pipeline_active(self, block: int, s: int, halo: int,
                         payload: Optional[int] = None) -> bool:
        """The pipelined schedule applies when blocking is on AND the owned
        block keeps a nonempty interior once 2*S*r edge rows belong to the
        boundary phase. Tiny blocks (block <= 2*S*r) have nothing to hide
        the exchange under — the regime where pipeline=False wins anyway by
        not paying the second launch — so they fall back to the serial
        schedule. Note S*r < block here, so the pipelined exchange is
        always single-hop. Under ``steps_per_launch="auto"`` the tuner's
        profitability verdict also binds (a fallback depth chosen with no
        covering candidate runs serial), priced by this runtime's cost
        model; an EXPLICIT S is the user's ablation choice and pipelines
        whenever structurally possible."""
        if not (s > 1 and halo > 0 and self._pipeline_requested()
                and block > 2 * s * halo):
            return False
        if _schedule.is_auto(self.options.get("steps_per_launch")):
            return _schedule.pipeline_interior_covers_exchange(
                block, halo, s, self._cost_model(payload))
        return True

    # ------------------------------------------------------- launch depth

    def _steps_per_launch(self, block: int, radius: int, payload: int,
                          total_steps: int) -> int:
        return _schedule.resolve_steps_per_launch(
            self.options.get("steps_per_launch"),
            block=block, radius=radius, payload=payload,
            total_steps=total_steps, combine=self._combine_mode(),
            pipeline=self._pipeline_requested(),
            model=self._cost_model(payload),
        )

    def _graph_steps_per_launch(self, graph: TaskGraph) -> int:
        return self._steps_per_launch(
            self._block(graph), _patterns.halo_radius(graph), graph.payload,
            graph.steps,
        )

    def _ensemble_steps_per_launch(self, ensemble: GraphEnsemble) -> int:
        """Common launch depth for an ensemble: one cadence for all members
        (launch boundaries are shared), so take the most conservative
        member's resolved depth. A member on a stride or all-gather plan
        pins the shared cadence to per-step (its exchanges are per-step /
        per-gather, and the deep-halo machinery does not apply to it)."""
        members = ensemble.members
        if any(self.plan_for(g)[0] != PLAN_HALO for g in members):
            return 1
        if self._is_stacked(ensemble):
            H = max(_patterns.halo_radius(g) for g in members)
            return self._steps_per_launch(
                self._block(members[0]), H, members[0].payload, ensemble.steps
            )
        return min(
            self._steps_per_launch(
                self._block(g), _patterns.halo_radius(g), g.payload,
                ensemble.steps,
            )
            for g in members
        )

    def stacking_verdict(self, ensemble: GraphEnsemble) -> Tuple[bool, str]:
        """``supports()``-style verdict for the stacked fast path: (ok,
        reason). Stacked launches share one (K, B, ...) operand set built
        by the halo-plan machinery, so they require uniform (width,
        payload), one kernel, and every member on the halo plan;
        everything else takes the slow per-step tuple fallback. The reason
        string names exactly which requirement failed so a packer (or a
        trace reader) can see WHY a cohort degraded instead of silently
        paying per-step dispatch."""
        members = ensemble.members
        reasons = []
        if not ensemble.stackable:
            widths = sorted({g.width for g in members})
            payloads = sorted({g.payload for g in members})
            reasons.append(
                f"members do not stack into one (K, W, payload) state: "
                f"widths {widths}, payloads {payloads}")
        kernels = {g.kernel for g in members}
        if len(kernels) != 1:
            reasons.append("mixed kernels: " + ", ".join(sorted(
                f"{k.kind}@it{k.iterations}" for k in kernels)))
        off_plan = []
        for i, g in enumerate(members):
            plan, why = self.plan_for(g)
            if plan != PLAN_HALO:
                off_plan.append(
                    f"member {i} ({g.pattern}) resolves the "
                    f"{plan or 'un-supported'} plan")
        if off_plan:
            reasons.append(
                "stacked operands are built by the halo-plan machinery: "
                + "; ".join(off_plan))
        if reasons:
            return False, "; ".join(reasons)
        return True, ("stacked: uniform (width, payload, kernel) and "
                      "every member on the halo plan")

    def _is_stacked(self, ensemble: GraphEnsemble) -> bool:
        return self.stacking_verdict(ensemble)[0]

    @staticmethod
    def _launches(total_steps: int, s: int) -> int:
        """Kernel launches for one member's run: the t=0 body-only launch
        plus ceil((T-1)/S) blocked combine launches."""
        if total_steps <= 1:
            return 1
        return 1 + -(-(total_steps - 1) // s)

    # ------------------------------------------------------- single graph

    def build(self, graph: TaskGraph) -> Callable[[jax.Array], jax.Array]:
        self._require_support(graph)
        plan = self._schedule_for_graph(graph)
        if plan.kind == PLAN_STRIDE:
            return self._build_plan_stepper(graph, plan.kind)
        if plan.kind == PLAN_ALLGATHER:
            if plan.steps_per_launch > 1:
                return self._build_allgather_blocked(
                    graph, plan.steps_per_launch)
            return self._build_plan_stepper(graph, plan.kind)
        H = _patterns.halo_radius(graph)
        S = plan.steps_per_launch
        if S > 1:
            return self._build_blocked(graph, S)
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        kw = self._kernel_kw(graph.kernel)
        idx, wgt, idx0, wgt0 = self._operands(graph, H)

        def megastep(ext_src, i, w):  # (B|B+2H, P), (B, D'), (B, D')
            return _kops.taskbench_step(ext_src[None], i[None], w[None], **kw)[0]

        def local_run(local, i, w, i0, w0):  # all (B, ...) per device
            state = megastep(local, i0, w0)  # t=0: body only
            if graph.steps == 1:
                return state

            def body(s, _):
                return megastep(_extend_state(s, H, D), i, w), None

            state, _ = jax.lax.scan(
                body, state, None, length=graph.steps - 1, unroll=unroll
            )
            return state

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(AXIS),) * 5, out_specs=P(AXIS),
            )
        )
        sh = NamedSharding(mesh, P(AXIS))
        consts = tuple(
            jax.device_put(jnp.asarray(a), sh) for a in (idx, wgt, idx0, wgt0)
        )
        return lambda init: fn(jax.device_put(init, sh), *consts)

    def _build_blocked(self, graph: TaskGraph, S: int) -> Callable:
        """ceil((T-1)/S) launches: one deep exchange + one S-step kernel
        per launch instead of one exchange + one launch per step. When the
        pipeline applies (DESIGN.md §6) each launch splits into boundary +
        interior phases and the next launch's exchange rides under the
        interior; otherwise the exchange sits serially before the launch.
        """
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        H = _patterns.halo_radius(graph)
        depth = S * H
        mode = self._combine_mode()
        kw0 = self._kernel_kw(graph.kernel)
        kwb = dict(kw0, steps_per_launch=S)
        kwb.pop("block_rows", None)  # blocked path: one program per member
        idx, wgt, idx0, wgt0 = self._blocked_operands(graph, H)
        acts = _act_schedule((graph.steps,), graph.steps, S)[:, 0]  # (L, S)
        T = graph.steps
        pipelined = self._pipeline_active(self._block(graph), S, H,
                                          graph.payload)
        impl = self._halo_impl()

        def local_run(local, i, w, i0, w0, act_seq):
            state = _kops.taskbench_step(
                local[None], i0[None], w0[None], **kw0)[0]  # t=0: body only
            if T == 1:
                return state
            B = local.shape[0]
            if pipelined:
                ph = _phase_tables(i[None], w[None], depth, D, mode)
                h = _prologue_exchange(state[None], depth, D, impl)

                def pbody(carry, a):  # a: (S,) per-depth activity
                    s, hl, hr = carry
                    s2, h2 = _pipelined_launch(
                        s, hl, hr, a[None], ph, depth, D, kwb, impl)
                    return (s2, h2.recv_left, h2.recv_right), None

                (state3, _, _), _ = jax.lax.scan(
                    pbody, (state[None], h.recv_left, h.recv_right),
                    act_seq, unroll=unroll)
                return state3[0]

            # the per-row operand tables are deep-exchanged ONCE: every
            # working row then owns its exact (edge-clipped) weights
            iext, wext = _extend_tables(i, w, depth, D, mode)

            def body(s, a):  # a: (S,) per-depth activity
                ext = _extend_state(s, depth, D)
                nf = _kops.taskbench_step(
                    ext[None], iext[None], wext[None], a[None], **kwb)[0]
                return jax.lax.slice_in_dim(nf, depth, depth + B, axis=0), None

            state, _ = jax.lax.scan(body, state, act_seq, unroll=unroll)
            return state

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(AXIS),) * 5 + (P(),), out_specs=P(AXIS),
            )
        )
        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        consts = tuple(
            jax.device_put(jnp.asarray(a), sh) for a in (idx, wgt, idx0, wgt0)
        ) + (jax.device_put(jnp.asarray(acts), rep),)
        return lambda init: fn(jax.device_put(init, sh), *consts)

    # ------------------------------------------- stride / all-gather plans

    def _stride_step_fns(self, graph: TaskGraph) -> Tuple[Callable, Callable]:
        """(t0, step) closures for one stride-plan (butterfly) member.

        ``step(s, o, t)`` runs timestep t: the period slot's pairing
        distance selects a branch — in-block strides gather locally,
        block strides first XOR-permute the partner block in
        (`_halo.exchange_stride`) — and one megakernel launch combines
        {p, partner} and runs the body. Tables are device-invariant
        (XOR structure is translation-invariant across blocks), so they
        ride as closures; ``o`` is an unused operand slot kept for
        signature parity with the halo members in tuple ensembles."""
        D = len(self.devices)
        B = self._block(graph)
        mode = self._plan_combine(PLAN_STRIDE)
        kw = self._kernel_kw(graph.kernel, combine=mode)
        impl = self._halo_impl()
        period = graph.period
        strides = _patterns.butterfly_slot_strides(graph)
        distinct = sorted(set(strides))
        bmap = jnp.asarray([distinct.index(s) for s in strides], jnp.int32)
        # pair mode's idx/wgt are kernel-side dummies (wgt's row count
        # declares the output width); table modes carry real slot tables
        dummy_i = jnp.zeros((1, 1), jnp.int32)
        dummy_w = jnp.zeros((B, 1), WEIGHT_DTYPE)

        def make_branch(s: int) -> Callable:
            if mode == "pair":
                if s < B:
                    def partner_of(local):
                        return _xor_swap(local, s)
                else:
                    bs = s // B

                    def partner_of(local):
                        p, = _halo.exchange_stride(
                            local, (bs,), D, AXIS, impl=impl)
                        return p

                def branch(local):
                    src = jnp.concatenate(
                        [local, partner_of(local)], axis=0)
                    return _kops.taskbench_step(
                        src[None], dummy_i[None], dummy_w[None], **kw)[0]

                return branch
            idx_np, wgt_np, off_block = _stride_slot_tables(B, s)
            idx, wgt = jnp.asarray(idx_np), jnp.asarray(wgt_np)
            if not off_block:
                def branch(local):
                    return _kops.taskbench_step(
                        local[None], idx[None], wgt[None], **kw)[0]
            else:
                bs = s // B

                def branch(local):
                    partner, = _halo.exchange_stride(
                        local, (bs,), D, AXIS, impl=impl)
                    src = jnp.concatenate([local, partner], axis=0)
                    return _kops.taskbench_step(
                        src[None], idx[None], wgt[None], **kw)[0]
            return branch

        branches = [make_branch(s) for s in distinct]
        i0, w0 = _self_tables(B)

        if mode == "pair":
            # t=0 (body only) through pair itself: [x | x] halves give
            # (a + a) * 0.5 == a bit-exactly, so the stride plan never
            # leaves its gather-free lowering (a gather here would be the
            # one Mosaic-unfriendly op on an otherwise portable path)
            def t0(s, o):
                src = jnp.concatenate([s, s], axis=0)
                return _kops.taskbench_step(
                    src[None], dummy_i[None], dummy_w[None], **kw)[0]
        else:
            def t0(s, o):
                return _kops.taskbench_step(
                    s[None], i0[None], w0[None], **kw)[0]

        if len(branches) == 1:
            def step(s, o, t):
                return branches[0](s)
        else:
            def step(s, o, t):
                slot = jax.lax.rem(t - 1, period)
                return jax.lax.switch(bmap[slot], branches, s)

        return t0, step

    def _global_table_fn(self, graph: TaskGraph) -> Tuple[Callable, bool]:
        """(tables_for, time_varying) — THE global-table policy, shared by
        the per-step and blocked all-gather builders so the two schedules
        cannot diverge.

        time_varying=True: ``tables_for(ts)`` maps a traced (n,) vector
        of timesteps to stacked (n, W, D) idx/wgt tables — spread rotates
        its base table by +(t-1) (the dependence set shifts rigidly;
        weights never rotate), other patterns gather their period stack
        at slots (ts-1) mod period. time_varying=False (period-1
        patterns, e.g. all_to_all): ``tables_for(None)`` returns the one
        static (W, D) pair."""
        W = graph.width
        if graph.pattern == "spread":
            bi, bw = _spread_base_operands(graph)
            base_i, base_w = jnp.asarray(bi), jnp.asarray(bw)

            def tables_for(ts):
                i_t = jnp.mod(base_i[None] + (ts - 1)[:, None, None], W)
                w_t = jnp.broadcast_to(
                    base_w[None], (ts.shape[0],) + base_w.shape)
                return i_t, w_t

            return tables_for, True
        gi, gw = _global_slot_operands(graph)
        tab_i, tab_w = jnp.asarray(gi), jnp.asarray(gw)
        period = gi.shape[0]
        if period == 1:
            def tables_for(ts):
                return tab_i[0], tab_w[0]

            return tables_for, False

        def tables_for(ts):
            slots = jnp.mod(ts - 1, period)
            return (jnp.take(tab_i, slots, axis=0),
                    jnp.take(tab_w, slots, axis=0))

        return tables_for, True

    def _allgather_step_fns(self, graph: TaskGraph) -> Tuple[Callable, Callable]:
        """(t0, step) closures for one all-gather-plan (global) member.

        ``step(s, o, t)``: gather the full global-order state, pick
        timestep t's (idx, wgt) tables (``_global_table_fn``), slice this
        device's output rows out of the global tables, one megakernel
        launch. Tables ride as closures (global tables are
        device-invariant; the per-device slice happens in-scan).

        Uniform all_to_all skips the gather entirely (``psum_mean``
        option, default on): every row's combine is the same global mean,
        so one psum of the local row-sums replaces the O(W) replication —
        within float32 reduction tolerance of the gathered combine, not
        bit-identical (summation order differs)."""
        D = len(self.devices)
        B = self._block(graph)
        W = graph.width
        kw = self._kernel_kw(graph.kernel,
                             combine=self._plan_combine(PLAN_ALLGATHER))
        impl = self._gather_impl(W)
        tables_for, time_varying = self._global_table_fn(graph)
        i0, w0 = _self_tables(B)

        def t0(s, o):
            return _kops.taskbench_step(s[None], i0[None], w0[None], **kw)[0]

        if (graph.pattern == "all_to_all"
                and bool(self.options.get("psum_mean", True))):

            def step(s, o, t):
                mean = _halo.global_mean(s, W, D, AXIS)
                src = jnp.broadcast_to(mean[None, :], (B, mean.shape[0]))
                # self tables on the combined rows: the same body-only
                # launch shape as t0 (combine of src[p] is src[p] itself)
                return _kops.taskbench_step(
                    src[None], i0[None], w0[None], **kw)[0]

            return t0, step

        def step(s, o, t):
            full = _halo.gather_global(s, D, AXIS, impl=impl)
            if time_varying:
                i_ts, w_ts = tables_for(jnp.reshape(t, (1,)))
                i_t, w_t = i_ts[0], w_ts[0]
            else:
                i_t, w_t = tables_for(None)
            r0 = jax.lax.axis_index(AXIS) * B
            i_loc = jax.lax.dynamic_slice_in_dim(i_t, r0, B, axis=0)
            w_loc = jax.lax.dynamic_slice_in_dim(w_t, r0, B, axis=0)
            return _kops.taskbench_step(
                full[None], i_loc[None], w_loc[None], **kw)[0]

        return t0, step

    def _plan_step_fns(self, graph: TaskGraph,
                       plan: str) -> Tuple[Callable, Callable]:
        if plan == PLAN_STRIDE:
            return self._stride_step_fns(graph)
        return self._allgather_step_fns(graph)

    def _build_plan_stepper(self, graph: TaskGraph, plan: str) -> Callable:
        """Single-graph per-step scan for the stride / all-gather plans:
        one megakernel launch (plus at most one collective) per timestep,
        whole loop in one jit — the same dispatch shape as the halo S=1
        path, with the plan's own exchange."""
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        T = graph.steps
        t0, step = self._plan_step_fns(graph, plan)

        def local_run(local):
            state = t0(local, ())
            if T == 1:
                return state

            def body(s, t):
                return step(s, (), t), None

            state, _ = jax.lax.scan(
                body, state, jnp.arange(1, T), unroll=unroll)
            return state

        fn = jax.jit(
            shard_map(local_run, mesh=mesh, check_vma=False,
                      in_specs=P(AXIS), out_specs=P(AXIS)))
        sh = NamedSharding(mesh, P(AXIS))
        return lambda init: fn(jax.device_put(init, sh))

    def _build_allgather_blocked(self, graph: TaskGraph, S: int) -> Callable:
        """Blocked all-gather plan: ONE full-state gather + one S-depth
        launch per ``ceil((T-1)/S)`` launches, with time-varying (S, W, D)
        idx/wgt tables driving the per-depth combine (butterfly slots /
        spread's rotation; period-1 patterns keep static tables). Every
        row of the gathered buffer advances exactly — the buffer is closed
        under any dependence set — so there is no valid-span shrink and
        the device slices its own rows from the final buffer. The act
        machinery (masked tail) is the halo path's, unchanged."""
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        B = self._block(graph)
        T = graph.steps
        kw0 = self._kernel_kw(graph.kernel,
                              combine=self._plan_combine(PLAN_ALLGATHER))
        kwb = dict(kw0, steps_per_launch=S)
        kwb.pop("block_rows", None)
        impl = self._gather_impl(graph.width)
        tables_for, time_varying = self._global_table_fn(graph)
        acts = _act_schedule((T,), T, S)[:, 0]  # (L, S)
        # first timestep of each launch (selects the depth tables in-scan)
        t0s = 1 + np.arange(acts.shape[0], dtype=np.int32) * S
        i0, w0 = _self_tables(B)

        def local_run(local, act_seq, t0_seq):
            state = _kops.taskbench_step(
                local[None], i0[None], w0[None], **kw0)[0]
            if T == 1:
                return state

            def body(s, inp):
                a, tt0 = inp
                full = _halo.gather_global(s, D, AXIS, impl=impl)
                if time_varying:
                    # this launch's S timesteps -> (S, W, D) depth tables
                    i_t, w_t = tables_for(tt0 + jnp.arange(S))
                else:
                    i_t, w_t = tables_for(None)
                nf = _kops.taskbench_step(
                    full[None], i_t[None], w_t[None], a[None], **kwb)[0]
                r0 = jax.lax.axis_index(AXIS) * B
                return jax.lax.dynamic_slice_in_dim(nf, r0, B, axis=0), None

            state, _ = jax.lax.scan(
                body, state, (act_seq, t0_seq), unroll=unroll)
            return state

        fn = jax.jit(
            shard_map(local_run, mesh=mesh, check_vma=False,
                      in_specs=(P(AXIS), P(), P()), out_specs=P(AXIS)))
        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        acts_dev = jax.device_put(jnp.asarray(acts), rep)
        t0_dev = jax.device_put(jnp.asarray(t0s), rep)
        return lambda init: fn(jax.device_put(init, sh), acts_dev, t0_dev)

    # ---------------------------------------------------------- ensembles

    def build_ensemble(self, ensemble: GraphEnsemble) -> Callable:
        self._require_ensemble_support(ensemble)
        S = self._ensemble_steps_per_launch(ensemble)
        if self._is_stacked(ensemble):
            if S > 1:
                return self._build_ensemble_stacked_blocked(ensemble, S)
            return self._build_ensemble_stacked(ensemble)
        self._record_stacking_degradation(ensemble, S, "tuple")
        if S > 1:
            return self._build_ensemble_tuple_blocked(ensemble, S)
        return self._build_ensemble_tuple(ensemble)

    def _record_stacking_degradation(self, ensemble: GraphEnsemble,
                                     S: int, plan_kind: str) -> None:
        """Decision record for a multi-member ensemble that fell off the
        stacked fast path. The fall used to be silent — cadence quietly
        pinned to per-step tuple dispatch — so every builder that takes
        the fallback emits one ``schedule.resolve`` instant naming the
        failed requirement (stacking_verdict's reason)."""
        if len(ensemble.members) <= 1:
            return
        if not getattr(self.tracer, "enabled", False):
            return
        ok, why = self.stacking_verdict(ensemble)
        if ok:
            return
        _schedule.record_resolution(
            self.tracer,
            plan=plan_kind,
            steps_per_launch=S,
            pipeline=False,
            model=self._cost_model(ensemble.members[0].payload),
            reason=f"ensemble off the stacked fast path: {why}",
            runtime=self.name,
            members=len(ensemble.members),
            stacked=False,
        )

    def _build_ensemble_stacked(self, ensemble: GraphEnsemble) -> Callable:
        """All K members' combines + bodies in ONE megakernel launch/step.

        With ``member_shards`` Dk > 1 the shard_map runs over the 2D
        (row, member) mesh: the K axis splits Dk ways (so each device
        holds a (K/Dk, W/Dr, P) slice instead of all K members), rows
        split over the remaining Dr = D/Dk row devices, and every halo
        exchange still names AXIS — spanning only its Dr-device row
        subgroup, never the member axis. Outputs are bit-identical to the
        replicated path (same per-row arithmetic, only ownership moves).
        """
        members = ensemble.members
        K = len(members)
        unroll = int(self.options.get("unroll", 1))
        mesh, dk, Dr = self._stacked_mesh(ensemble)
        H = max(_patterns.halo_radius(g) for g in members)
        kw = self._kernel_kw(members[0].kernel)
        steps = ensemble.steps
        hetero = ensemble.heterogeneous_steps
        member_steps = np.asarray(ensemble.member_steps, np.int32)
        kspec = P(MEMBER_AXIS, AXIS) if dk > 1 else P(None, AXIS)
        mspec = P(MEMBER_AXIS) if dk > 1 else P()

        ops4 = [self._operands(g, H, block=g.width // Dr) for g in members]
        idx, wgt, idx0, wgt0 = _stack_operands(ops4)

        def megastep(ext_src, i, w):  # (K, S, P), (K, B, D'), (K, B, D')
            return _kops.taskbench_step(ext_src, i, w, **kw)

        def local_run(local, i, w, i0, w0, msteps):  # local (K, B, P)
            state = megastep(local, i0, w0)
            if steps == 1:
                return state

            def body(s, t):
                nxt = megastep(_extend_state(s, H, Dr, row_axis=1), i, w)
                if hetero:  # freeze members whose own T is exhausted
                    active = (t < msteps)[:, None, None]
                    nxt = jnp.where(active, nxt, s)
                return nxt, None

            state, _ = jax.lax.scan(
                body, state, jnp.arange(1, steps), unroll=unroll
            )
            return state

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(kspec,) * 5 + (mspec,), out_specs=kspec,
            )
        )
        sh = NamedSharding(mesh, kspec)
        consts = tuple(
            jax.device_put(jnp.asarray(a), sh) for a in (idx, wgt, idx0, wgt0)
        ) + (jax.device_put(jnp.asarray(member_steps),
                            NamedSharding(mesh, mspec)),)

        def run(inits):
            out = fn(jax.device_put(jnp.stack(inits), sh), *consts)
            return tuple(out[k] for k in range(K))

        return run

    def _build_ensemble_stacked_blocked(
        self, ensemble: GraphEnsemble, S: int
    ) -> Callable:
        """All K members share each deep exchange AND each S-step launch."""
        members = ensemble.members
        K = len(members)
        unroll = int(self.options.get("unroll", 1))
        mesh, dk, Dr = self._stacked_mesh(ensemble)
        H = max(_patterns.halo_radius(g) for g in members)
        depth = S * H
        mode = self._combine_mode()
        kw0 = self._kernel_kw(members[0].kernel)
        kwb = dict(kw0, steps_per_launch=S)
        kwb.pop("block_rows", None)
        steps = ensemble.steps
        kspec = P(MEMBER_AXIS, AXIS) if dk > 1 else P(None, AXIS)
        # acts is (L, K, S): the member axis shards its K slices alongside
        # the state, so each device only masks the members it owns
        aspec = P(None, MEMBER_AXIS) if dk > 1 else P()

        ops4 = [self._blocked_operands(g, H, block=g.width // Dr)
                for g in members]
        idx, wgt, idx0, wgt0 = _stack_operands(ops4)
        acts = _act_schedule(ensemble.member_steps, steps, S)  # (L, K, S)
        pipelined = self._pipeline_active(members[0].width // Dr, S, H,
                                          members[0].payload)
        impl = self._halo_impl()

        def local_run(local, i, w, i0, w0, act_seq):  # local (K, B, P)
            state = _kops.taskbench_step(local, i0, w0, **kw0)
            if steps == 1:
                return state
            B = local.shape[1]
            if pipelined:
                # one boundary launch (K row-fused 6*depth-row programs) +
                # one interior launch per deep exchange — every member
                # shares both
                ph = _phase_tables(i, w, depth, Dr, mode)
                h = _prologue_exchange(state, depth, Dr, impl)

                def pbody(carry, a):  # a: (K, S)
                    s, hl, hr = carry
                    s2, h2 = _pipelined_launch(
                        s, hl, hr, a, ph, depth, Dr, kwb, impl)
                    return (s2, h2.recv_left, h2.recv_right), None

                (state, _, _), _ = jax.lax.scan(
                    pbody, (state, h.recv_left, h.recv_right),
                    act_seq, unroll=unroll)
                return state

            iext, wext = _extend_tables(i, w, depth, Dr, mode, row_axis=1)

            def body(s, a):  # a: (K, S) per-member per-depth activity
                ext = _extend_state(s, depth, Dr, row_axis=1)
                nf = _kops.taskbench_step(ext, iext, wext, a, **kwb)
                return jax.lax.slice_in_dim(nf, depth, depth + B, axis=1), None

            state, _ = jax.lax.scan(body, state, act_seq, unroll=unroll)
            return state

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(kspec,) * 5 + (aspec,), out_specs=kspec,
            )
        )
        sh = NamedSharding(mesh, kspec)
        rep = NamedSharding(mesh, aspec)
        consts = tuple(
            jax.device_put(jnp.asarray(a), sh) for a in (idx, wgt, idx0, wgt0)
        ) + (jax.device_put(jnp.asarray(acts), rep),)

        def run(inits):
            out = fn(jax.device_put(jnp.stack(inits), sh), *consts)
            return tuple(out[k] for k in range(K))

        return run

    def _build_ensemble_tuple(self, ensemble: GraphEnsemble) -> Callable:
        """Mixed specs/shapes/plans: one launch per member, one jitted scan.

        Every member contributes a ``(t0, step)`` pair for its own plan:
        halo members keep the sharded-operand tables flowing through
        in_specs; stride and all-gather members carry device-invariant
        closure tables and an empty operand slot, and their step fns take
        the traced timestep (slot selection / rotation)."""
        members = ensemble.members
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        steps = ensemble.steps
        plans = [self.plan_for(g)[0] for g in members]
        ops4: List[tuple] = []
        t0_fns: List[Callable] = []
        step_fns: List[Callable] = []
        for g, plan in zip(members, plans):
            if plan == PLAN_HALO:
                H = _patterns.halo_radius(g)
                kw = self._kernel_kw(g.kernel)
                ops4.append(self._operands(g, H))

                def t0(s, o, kw=kw):
                    return _kops.taskbench_step(
                        s[None], o[2][None], o[3][None], **kw)[0]

                def step(s, o, t, H=H, kw=kw):
                    ext = _extend_state(s, H, D)
                    return _kops.taskbench_step(
                        ext[None], o[0][None], o[1][None], **kw)[0]
            else:
                ops4.append(())
                t0, step = self._plan_step_fns(g, plan)
            t0_fns.append(t0)
            step_fns.append(step)

        def local_run(states, operands):
            states = tuple(
                f(s, o) for f, s, o in zip(t0_fns, states, operands)
            )
            if steps == 1:
                return states

            def body(ss, t):
                nxt = []
                for k, (s, o) in enumerate(zip(ss, operands)):
                    n = step_fns[k](s, o, t)
                    if members[k].steps < steps:
                        n = jnp.where(t < members[k].steps, n, s)
                    nxt.append(n)
                return tuple(nxt), None

            states, _ = jax.lax.scan(
                body, states, jnp.arange(1, steps), unroll=unroll
            )
            return states

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(AXIS), P(AXIS)), out_specs=P(AXIS),
            )
        )
        sh = NamedSharding(mesh, P(AXIS))
        consts = tuple(
            tuple(jax.device_put(jnp.asarray(a), sh) for a in o) for o in ops4
        )
        return lambda inits: fn(
            tuple(jax.device_put(x, sh) for x in inits), consts
        )

    def _build_ensemble_tuple_blocked(
        self, ensemble: GraphEnsemble, S: int
    ) -> Callable:
        """Mixed specs/shapes, blocked: one S-step launch per member per
        scan iteration, launch cadence (and act schedule) shared."""
        members = ensemble.members
        K = len(members)
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        steps = ensemble.steps
        mode = self._combine_mode()
        halos = [_patterns.halo_radius(g) for g in members]
        depths = [S * h for h in halos]
        kws = [self._kernel_kw(g.kernel) for g in members]
        kwbs = [dict(kw, steps_per_launch=S) for kw in kws]
        for kwb in kwbs:
            kwb.pop("block_rows", None)
        ops4 = [self._blocked_operands(g, h) for g, h in zip(members, halos)]
        acts = _act_schedule(ensemble.member_steps, steps, S)  # (L, K, S)
        # per-member pipeline gate: the cadence is shared, but a member with
        # no interior at depth S*h_k keeps the serial exchange inside the
        # same scan body
        piped = [
            self._pipeline_active(self._block(g), S, h, g.payload)
            for g, h in zip(members, halos)
        ]
        impl = self._halo_impl()

        def local_run(states, operands, act_seq):
            states = tuple(
                _kops.taskbench_step(s[None], o[2][None], o[3][None], **kw)[0]
                for s, o, kw in zip(states, operands, kws)
            )
            if steps == 1:
                return states

            exts = []   # serial members: deep-exchanged (iext, wext) tables
            phs = []    # pipelined members: per-phase tables
            halos0 = []  # pipelined members: the fill-step exchange
            for k, (s, o) in enumerate(zip(states, operands)):
                if piped[k]:
                    exts.append(None)
                    phs.append(_phase_tables(
                        o[0][None], o[1][None], depths[k], D, mode))
                    h = _prologue_exchange(s[None], depths[k], D, impl)
                    halos0.append((h.recv_left, h.recv_right))
                else:
                    exts.append(_extend_tables(o[0], o[1], depths[k], D, mode))
                    phs.append(None)
                    halos0.append(())

            def body(carry, a):  # a: (K, S)
                ss, hh = carry
                nxt, nh = [], []
                for k, s in enumerate(ss):
                    dep = depths[k]
                    if piped[k]:
                        hl, hr = hh[k]
                        s2, h2 = _pipelined_launch(
                            s[None], hl, hr, a[k][None], phs[k], dep, D,
                            kwbs[k], impl)
                        nxt.append(s2[0])
                        nh.append((h2.recv_left, h2.recv_right))
                        continue
                    B = s.shape[0]
                    ext = _extend_state(s, dep, D)
                    iext, wext = exts[k]
                    nf = _kops.taskbench_step(
                        ext[None], iext[None], wext[None], a[k][None],
                        **kwbs[k])[0]
                    nxt.append(
                        jax.lax.slice_in_dim(nf, dep, dep + B, axis=0))
                    nh.append(())
                return (tuple(nxt), tuple(nh)), None

            (states, _), _ = jax.lax.scan(
                body, (states, tuple(halos0)), act_seq, unroll=unroll)
            return states

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(AXIS), P(AXIS), P()), out_specs=P(AXIS),
            )
        )
        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        consts = tuple(
            tuple(jax.device_put(jnp.asarray(a), sh) for a in o) for o in ops4
        )
        acts_dev = jax.device_put(jnp.asarray(acts), rep)
        return lambda inits: fn(
            tuple(jax.device_put(x, sh) for x in inits), consts, acts_dev
        )

    # ----------------------------------------------------------- resilience

    def build_ensemble_launches(
        self, ensemble: GraphEnsemble
    ) -> EnsembleLaunchPlan:
        """Expose the ensemble's real launch structure for the resilience
        engine (base.EnsembleLaunchPlan): stacked halo ensembles keep
        their blocked cadence with the SERIAL exchange schedule (launch
        boundaries must be host-visible, and the serial schedule is
        bit-identical to the pipelined one — tests lock that in), mixed
        ensembles run the tuple step fns at per-step cadence. Either way
        each launch is one pure jitted function of (carry, act row), so
        replay-from-snapshot is bit-identical by construction."""
        self._require_ensemble_support(ensemble)
        if self._is_stacked(ensemble):
            return self._launch_plan_stacked(
                ensemble, self._ensemble_steps_per_launch(ensemble))
        self._record_stacking_degradation(ensemble, 1, "stepwise")
        return self._launch_plan_stepwise(ensemble)

    def _launch_plan_stacked(
        self, ensemble: GraphEnsemble, S: int
    ) -> EnsembleLaunchPlan:
        """Host-stepped twin of _build_ensemble_stacked[_blocked]: same
        kernels, same operands, same act predicate — the scan is simply
        unrolled to the host so the engine owns the launch loop."""
        members = ensemble.members
        K = len(members)
        mesh, dk, Dr = self._stacked_mesh(ensemble)
        B = members[0].width // Dr
        H = max(_patterns.halo_radius(g) for g in members)
        depth = S * H
        mode = self._combine_mode()
        kw0 = self._kernel_kw(members[0].kernel)
        steps = ensemble.steps
        acts = _act_schedule(ensemble.member_steps, steps, S)  # (L, K, S)
        kspec = P(MEMBER_AXIS, AXIS) if dk > 1 else P(None, AXIS)
        # the act row (K, S) shards its K slices with the state, so the
        # engine's host-side eviction edits (acts[l:, k, :] = 0) land on
        # exactly the member-shard that owns slot k
        aspec = P(MEMBER_AXIS) if dk > 1 else P()
        # admitted init rows replicate over the member axis (only the
        # owning shard writes them) and row-shard over AXIS
        ispec = P(None, AXIS)

        if S > 1:
            kwb = dict(kw0, steps_per_launch=S)
            kwb.pop("block_rows", None)
            ops4 = [self._blocked_operands(g, H, block=B) for g in members]
        else:
            ops4 = [self._operands(g, H, block=B) for g in members]
        idx, wgt, idx0, wgt0 = _stack_operands(ops4)

        def t0_local(local, i0, w0):  # (K, B, P)
            return _kops.taskbench_step(local, i0, w0, **kw0)

        def launch_local(s, i, w, a):  # a: (K, S), K-sharded with state
            if S > 1:
                iext, wext = _extend_tables(i, w, depth, Dr, mode, row_axis=1)
                ext = _extend_state(s, depth, Dr, row_axis=1)
                nf = _kops.taskbench_step(ext, iext, wext, a, **kwb)
                return jax.lax.slice_in_dim(nf, depth, depth + B, axis=1)
            nxt = _kops.taskbench_step(
                _extend_state(s, H, Dr, row_axis=1), i, w, **kw0)
            # per-member freeze: same predicate the stacked scan applies
            # (act row at S=1 is exactly t < T_k)
            return jnp.where(a[:, 0][:, None, None] > 0, nxt, s)

        def admit_local(s, init, i0, w0, slot):  # init: (1, B, P)
            t0 = _kops.taskbench_step(init, i0[:1], w0[:1], **kw0)
            if dk > 1:
                # global slot -> this member-shard's local K range; only
                # the owning shard commits the update (clamped slice +
                # where keeps everything shape-static under shard_map)
                kl = s.shape[0]
                loc = slot - jax.lax.axis_index(MEMBER_AXIS) * kl
                owned = jnp.logical_and(loc >= 0, loc < kl)
                upd = jax.lax.dynamic_update_slice_in_dim(
                    s, t0, jnp.clip(loc, 0, kl - 1), axis=0)
                return jnp.where(owned, upd, s)
            return jax.lax.dynamic_update_slice_in_dim(s, t0, slot, axis=0)

        sh = NamedSharding(mesh, kspec)
        rep = NamedSharding(mesh, aspec)
        ish = NamedSharding(mesh, ispec)
        t0_fn = jax.jit(shard_map(
            t0_local, mesh=mesh, check_vma=False,
            in_specs=(kspec,) * 3, out_specs=kspec))
        launch = jax.jit(shard_map(
            launch_local, mesh=mesh, check_vma=False,
            in_specs=(kspec,) * 3 + (aspec,), out_specs=kspec))
        admit = jax.jit(shard_map(
            admit_local, mesh=mesh, check_vma=False,
            in_specs=(kspec, ispec) + (kspec,) * 2 + (P(),),
            out_specs=kspec))
        consts = tuple(
            jax.device_put(jnp.asarray(a), sh) for a in (idx, wgt, idx0, wgt0))

        def init_fn(inits):
            return t0_fn(jax.device_put(jnp.stack(inits), sh),
                         consts[2], consts[3])

        def launch_fn(carry, act_row, t0):
            del t0  # stacked halo tables are time-invariant
            return launch(carry, consts[0], consts[1],
                          jax.device_put(act_row, rep))

        def admit_fn(carry, slot, init):
            return admit(carry, jax.device_put(init[None], sh),
                         consts[2], consts[3],
                         jnp.asarray(slot, jnp.int32))

        model = self._cost_model(members[0].payload)
        return EnsembleLaunchPlan(
            steps_per_launch=S,
            member_steps=tuple(ensemble.member_steps),
            acts=acts,
            init_fn=init_fn,
            launch_fn=launch_fn,
            finalize=lambda carry: tuple(carry[k] for k in range(K)),
            admit_fn=admit_fn,
            expected_launch_us=_schedule.expected_launch_wall_us(
                rows=(K // dk) * B, steps_per_launch=S, model=model,
                impl=self._halo_impl()),
            kind="stacked",
            # launch shapes are membership-invariant (evict/admit only
            # edit mask/state VALUES) so this cache must never grow past
            # its first entry — the serving fabric asserts exactly that
            compile_counter=getattr(launch, "_cache_size", None),
        )

    def _launch_plan_stepwise(
        self, ensemble: GraphEnsemble
    ) -> EnsembleLaunchPlan:
        """Per-step cadence for mixed-plan/heterogeneous ensembles: the
        tuple path's (t0, step) fns with the launch loop on the host and
        the freeze predicate driven by the act schedule (so eviction is
        the same mask edit as the stacked plan)."""
        members = ensemble.members
        mesh = self._mesh()
        D = len(self.devices)
        steps = ensemble.steps
        plans = [self.plan_for(g)[0] for g in members]
        acts = _act_schedule(ensemble.member_steps, steps, 1)  # (L, K, 1)
        ops4: List[tuple] = []
        t0_fns: List[Callable] = []
        step_fns: List[Callable] = []
        for g, plan in zip(members, plans):
            if plan == PLAN_HALO:
                H = _patterns.halo_radius(g)
                kw = self._kernel_kw(g.kernel)
                ops4.append(self._operands(g, H))

                def t0(s, o, kw=kw):
                    return _kops.taskbench_step(
                        s[None], o[2][None], o[3][None], **kw)[0]

                def step(s, o, t, H=H, kw=kw):
                    ext = _extend_state(s, H, D)
                    return _kops.taskbench_step(
                        ext[None], o[0][None], o[1][None], **kw)[0]
            else:
                ops4.append(())
                t0, step = self._plan_step_fns(g, plan)
            t0_fns.append(t0)
            step_fns.append(step)

        def t0_all(states, operands):
            return tuple(
                f(s, o) for f, s, o in zip(t0_fns, states, operands))

        def step_all(states, operands, t, act):  # act: (K, 1) replicated
            nxt = []
            for k, (s, o) in enumerate(zip(states, operands)):
                n = step_fns[k](s, o, t)
                nxt.append(jnp.where(act[k, 0] > 0, n, s))
            return tuple(nxt)

        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        t0_jit = jax.jit(shard_map(
            t0_all, mesh=mesh, check_vma=False,
            in_specs=(P(AXIS), P(AXIS)), out_specs=P(AXIS)))
        step_jit = jax.jit(shard_map(
            step_all, mesh=mesh, check_vma=False,
            in_specs=(P(AXIS), P(AXIS), P(), P()), out_specs=P(AXIS)))
        consts = tuple(
            tuple(jax.device_put(jnp.asarray(a), sh) for a in o) for o in ops4)
        admit_jits: dict = {}

        def init_fn(inits):
            return t0_jit(
                tuple(jax.device_put(x, sh) for x in inits), consts)

        def launch_fn(carry, act_row, t0):
            return step_jit(carry, consts, jnp.asarray(t0, jnp.int32),
                            jax.device_put(act_row, rep))

        def admit_fn(carry, slot, init):
            if slot not in admit_jits:
                f = t0_fns[slot]
                admit_jits[slot] = jax.jit(shard_map(
                    lambda s, o, f=f: f(s, o), mesh=mesh, check_vma=False,
                    in_specs=(P(AXIS), P(AXIS)), out_specs=P(AXIS)))
            fresh = admit_jits[slot](jax.device_put(init, sh), consts[slot])
            out = list(carry)
            out[slot] = fresh
            return tuple(out)

        model = self._cost_model(members[0].payload)
        rows = sum(self._block(g) for g in members)
        return EnsembleLaunchPlan(
            steps_per_launch=1,
            member_steps=tuple(ensemble.member_steps),
            acts=acts,
            init_fn=init_fn,
            launch_fn=launch_fn,
            finalize=lambda carry: tuple(carry),
            admit_fn=admit_fn,
            expected_launch_us=_schedule.expected_launch_wall_us(
                rows=rows, steps_per_launch=1, model=model,
                impl=self._halo_impl()),
            kind="stepwise",
            compile_counter=getattr(step_jit, "_cache_size", None),
        )

    # ----------------------------------------------------------- accounting

    def dispatches_per_run(self, graph: TaskGraph) -> int:
        """Actual kernel launches: the t=0 body-only launch plus
        ceil((T-1)/S) blocked combine launches (S=1 degenerates to T).
        The (halo-plan) pipelined schedule splits every blocked launch
        into a boundary launch + an interior launch — TWO kernel launches
        per deep exchange; the accounting stays honest about it (hiding
        the exchange is bought with an extra, smaller, launch). Stride
        plans are per-step BY CONSTRUCTION — a butterfly graph with a
        blocked request only drops below T launches when the all-gather
        plan actually grants a depth (width under the cap, resolver says
        yes), exactly mirroring ``_schedule_for_graph``."""
        plan = self._schedule_for_graph(graph)
        L = self._launches(graph.steps, plan.steps_per_launch)
        if plan.kind == PLAN_HALO and self._pipeline_active(
                self._block(graph), plan.steps_per_launch,
                _patterns.halo_radius(graph), graph.payload):
            return 1 + 2 * (L - 1)
        return L

    def ensemble_dispatches_per_run(self, ensemble: GraphEnsemble) -> int:
        """Stacked ensembles batch all K members into each launch (the
        pipelined split costs 2 launches per blocked iteration — boundary,
        covering both sides of all K members, plus interior); the tuple
        fallback launches each member every scan iteration (frozen members
        included — the kernel runs, the mask discards), so it pays the
        per-member count summed over members."""
        S = self._ensemble_steps_per_launch(ensemble)
        launches = self._launches(ensemble.steps, S)
        members = ensemble.members
        if self._is_stacked(ensemble):
            H = max(_patterns.halo_radius(g) for g in members)
            if self._pipeline_active(self._block(members[0]), S, H,
                                     members[0].payload):
                return 1 + 2 * (launches - 1)
            return launches
        total = 0
        for g in members:
            piped = self._pipeline_active(
                self._block(g), S, _patterns.halo_radius(g), g.payload)
            total += 1 + (2 if piped else 1) * (launches - 1)
        return total

    # ------------------------------------------------------------- tracing
    #
    # The traced executors re-express each schedule as HOST-stepped jits so
    # span boundaries exist (the production builders put the whole loop in
    # one jit, opaque to host timing). Two fidelity rules govern every
    # builder below:
    #
    #   1. numerics are bit-identical to the production path — same
    #      operands, same kernels, same exchange transports, only the loop
    #      moved from lax.scan to Python;
    #   2. the pipelined launch stays ONE program. Splitting boundary /
    #      exchange / interior into separate jits would serialize them on
    #      the per-device dispatch queue and destroy the very overlap being
    #      measured, so the combined launch is timed as a "launch" span and
    #      its phases are priced by SEPARATE in-jit scan-of-R probes
    #      (decompose.py then splits each launch wall by probe costs and
    #      derives the overlap verdict from what the combined wall does
    #      NOT show).

    def _record_schedule(self, graph: TaskGraph, plan: _ResolvedPlan,
                         pipelined: bool) -> None:
        _schedule.record_resolution(
            self.tracer, plan=plan.kind,
            steps_per_launch=plan.steps_per_launch, pipeline=pipelined,
            model=self._cost_model(graph.payload), reason=plan.reason,
            runtime=self.name, pattern=graph.pattern, width=graph.width,
            launches=self._launches(graph.steps, plan.steps_per_launch))

    def _build_traced(self, graph: TaskGraph) -> Callable:
        self._require_support(graph)
        plan = self._schedule_for_graph(graph)
        S = plan.steps_per_launch
        pipelined = (
            plan.kind == PLAN_HALO and S > 1
            and self._pipeline_active(
                self._block(graph), S, _patterns.halo_radius(graph),
                graph.payload))
        self._record_schedule(graph, plan, pipelined)
        if plan.kind == PLAN_STRIDE:
            return self._trace_stride_steps(graph)
        if plan.kind == PLAN_ALLGATHER:
            if S > 1:
                return self._trace_allgather_blocked(graph, S)
            return self._trace_allgather_steps(graph)
        if S > 1 and pipelined:
            return self._trace_blocked_pipelined(graph, S)
        if S > 1:
            return self._trace_blocked_serial(graph, S)
        return self._trace_halo_steps(graph)

    def _trace_halo_steps(self, graph: TaskGraph) -> Callable:
        """Traced S=1 halo plan: per step, one transport span (the ring
        extend) and one megakernel span."""
        mesh = self._mesh()
        D = len(self.devices)
        H = _patterns.halo_radius(graph)
        kw = self._kernel_kw(graph.kernel)
        idx, wgt, idx0, wgt0 = self._operands(graph, H)
        tr = self.tracer
        sh = NamedSharding(mesh, P(AXIS))

        k_fn = jax.jit(shard_map(
            lambda ext, i, w: _kops.taskbench_step(
                ext[None], i[None], w[None], **kw)[0],
            mesh=mesh, check_vma=False,
            in_specs=(P(AXIS),) * 3, out_specs=P(AXIS)))
        ex_fn = jax.jit(shard_map(
            lambda s: _extend_state(s, H, D),
            mesh=mesh, check_vma=False,
            in_specs=P(AXIS), out_specs=P(AXIS))) if H > 0 else None
        consts = tuple(jax.device_put(jnp.asarray(a), sh)
                       for a in (idx, wgt, idx0, wgt0))

        def run(init):
            i, w, i0, w0 = consts
            with tr.span("t0_launch", "dispatch", step=0):
                st = k_fn(jax.device_put(init, sh), i0, w0)
            with tr.span("t0_kernel", "compute.interior", step=0):
                st = jax.block_until_ready(st)
            for t in range(1, graph.steps):
                if ex_fn is not None:
                    with _halo.transport_span(
                            tr, "halo_exchange", impl="ppermute", depth=H,
                            step=t):
                        ext = jax.block_until_ready(ex_fn(st))
                else:
                    ext = st
                with tr.span("megakernel", "compute.interior", step=t,
                             pattern=graph.pattern):
                    st = jax.block_until_ready(k_fn(ext, i, w))
            return st

        return run

    def _trace_blocked_serial(self, graph: TaskGraph, S: int) -> Callable:
        """Traced blocked serial-exchange schedule: per launch, one deep
        transport span then one S-depth kernel span — the exact pair whose
        serialization the pipelined schedule exists to break."""
        mesh = self._mesh()
        D = len(self.devices)
        H = _patterns.halo_radius(graph)
        depth = S * H
        mode = self._combine_mode()
        kw0 = self._kernel_kw(graph.kernel)
        kwb = dict(kw0, steps_per_launch=S)
        kwb.pop("block_rows", None)
        idx, wgt, idx0, wgt0 = self._blocked_operands(graph, H)
        acts = _act_schedule((graph.steps,), graph.steps, S)[:, 0]  # (L, S)
        tr = self.tracer
        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())

        t0_fn = jax.jit(shard_map(
            lambda local, i0, w0: _kops.taskbench_step(
                local[None], i0[None], w0[None], **kw0)[0],
            mesh=mesh, check_vma=False,
            in_specs=(P(AXIS),) * 3, out_specs=P(AXIS)))
        tab_fn = jax.jit(shard_map(
            lambda i, w: _extend_tables(i, w, depth, D, mode),
            mesh=mesh, check_vma=False,
            in_specs=(P(AXIS),) * 2, out_specs=(P(AXIS),) * 2))
        ex_fn = jax.jit(shard_map(
            lambda s: _extend_state(s, depth, D),
            mesh=mesh, check_vma=False,
            in_specs=P(AXIS), out_specs=P(AXIS)))

        def kern(ext, iext, wext, a):
            B = ext.shape[0] - 2 * depth
            nf = _kops.taskbench_step(
                ext[None], iext[None], wext[None], a[None], **kwb)[0]
            return jax.lax.slice_in_dim(nf, depth, depth + B, axis=0)

        k_fn = jax.jit(shard_map(
            kern, mesh=mesh, check_vma=False,
            in_specs=(P(AXIS),) * 3 + (P(),), out_specs=P(AXIS)))
        consts = tuple(jax.device_put(jnp.asarray(a), sh)
                       for a in (idx, wgt, idx0, wgt0))
        act_rows = [jax.device_put(jnp.asarray(a), rep) for a in acts]

        def run(init):
            i, w, i0, w0 = consts
            with tr.span("t0_launch", "dispatch", step=0):
                st = t0_fn(jax.device_put(init, sh), i0, w0)
            with tr.span("t0_kernel", "compute.interior", step=0):
                st = jax.block_until_ready(st)
            if graph.steps == 1:
                return st
            with _halo.transport_span(tr, "table_exchange", impl="ppermute",
                                      depth=depth, setup=True):
                iext, wext = jax.block_until_ready(tab_fn(i, w))
            for l, a in enumerate(act_rows):
                with _halo.transport_span(tr, "deep_exchange",
                                          impl="ppermute", depth=depth,
                                          launch=l):
                    ext = jax.block_until_ready(ex_fn(st))
                with tr.span("blocked_kernel", "compute.interior", launch=l,
                             steps_per_launch=S):
                    st = jax.block_until_ready(k_fn(ext, iext, wext, a))
            return st

        return run

    def _trace_blocked_pipelined(self, graph: TaskGraph, S: int) -> Callable:
        """Traced pipelined schedule: each launch is ONE combined program
        (boundary -> exchange-start -> interior, exactly the production
        `_pipelined_launch` body) recorded as a "launch" span, plus three
        in-jit scan-of-R phase probes whose per-launch costs let
        decompose.py split each combined wall and prove (or refute) the
        overlap. Probe outputs are loop-carried — each rep's results feed
        the next rep's inputs — so neither DCE nor loop-invariant hoisting
        can elide the work being priced; they run AFTER the launch loop so
        their wall can never smear into the attributed extent."""
        mesh = self._mesh()
        D = len(self.devices)
        H = _patterns.halo_radius(graph)
        depth = S * H
        mode = self._combine_mode()
        kw0 = self._kernel_kw(graph.kernel)
        kwb = dict(kw0, steps_per_launch=S)
        kwb.pop("block_rows", None)
        impl = self._halo_impl()
        idx, wgt, idx0, wgt0 = self._blocked_operands(graph, H)
        acts = _act_schedule((graph.steps,), graph.steps, S)[:, 0]  # (L, S)
        tr = self.tracer
        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        R = int(self.options.get("trace_probe_reps", 16))

        t0_fn = jax.jit(shard_map(
            lambda local, i0, w0: _kops.taskbench_step(
                local[None], i0[None], w0[None], **kw0),
            mesh=mesh, check_vma=False,
            in_specs=(P(AXIS),) * 3, out_specs=P(None, AXIS)))

        def setup_local(local, i, w):
            ph = _phase_tables(i[None], w[None], depth, D, mode)
            h = _prologue_exchange(local, depth, D, impl)
            return (*ph, h.recv_left, h.recv_right)

        setup_fn = jax.jit(shard_map(
            setup_local, mesh=mesh, check_vma=False,
            in_specs=(P(None, AXIS), P(AXIS), P(AXIS)),
            out_specs=(P(None, AXIS),) * 6))

        def launch_local(s, hl, hr, a, ii, wi, ib, wb):
            ph = _PhaseTables(ii, wi, ib, wb)
            s2, h2 = _pipelined_launch(s, hl, hr, a, ph, depth, D, kwb, impl)
            return s2, h2.recv_left, h2.recv_right

        launch_fn = jax.jit(shard_map(
            launch_local, mesh=mesh, check_vma=False,
            in_specs=(P(None, AXIS),) * 3 + (P(),) + (P(None, AXIS),) * 4,
            out_specs=(P(None, AXIS),) * 3))

        def ex_probe_local(f, l):
            def body(c, _):
                h = _halo.exchange_edges_start(
                    c[0], c[1], D, AXIS, row_axis=1, impl=impl)
                return (h.recv_left, h.recv_right), None
            out, _ = jax.lax.scan(body, (f, l), None, length=R)
            return out

        ex_probe = jax.jit(shard_map(
            ex_probe_local, mesh=mesh, check_vma=False,
            in_specs=(P(None, AXIS),) * 2, out_specs=(P(None, AXIS),) * 2))

        def bd_probe_local(s, hl, hr, a, ib, wb):
            B = s.shape[1]
            bl = jnp.concatenate(
                [hl, jax.lax.slice_in_dim(s, 0, 2 * depth, axis=1)], axis=1)
            br = jnp.concatenate(
                [jax.lax.slice_in_dim(s, B - 2 * depth, B, axis=1), hr],
                axis=1)

            def body(c, _):
                blo, bro = _kops.taskbench_boundary(
                    c[0], c[1], ib, wb, a, depth=depth, **kwb)
                return (jnp.concatenate([blo, bro, blo], axis=1),
                        jnp.concatenate([bro, blo, bro], axis=1)), None

            out, _ = jax.lax.scan(body, (bl, br), None, length=R)
            return out

        bd_probe = jax.jit(shard_map(
            bd_probe_local, mesh=mesh, check_vma=False,
            in_specs=(P(None, AXIS),) * 3 + (P(),) + (P(None, AXIS),) * 2,
            out_specs=(P(None, AXIS),) * 2))

        def in_probe_local(s, a, ii, wi):
            def body(c, _):
                mid = _kops.taskbench_interior(
                    c, ii, wi, a, depth=depth, **kwb)
                B = c.shape[1]
                return jnp.concatenate([
                    jax.lax.slice_in_dim(c, 0, depth, axis=1), mid,
                    jax.lax.slice_in_dim(c, B - depth, B, axis=1)],
                    axis=1), None
            out, _ = jax.lax.scan(body, s, None, length=R)
            return out

        in_probe = jax.jit(shard_map(
            in_probe_local, mesh=mesh, check_vma=False,
            in_specs=(P(None, AXIS), P()) + (P(None, AXIS),) * 2,
            out_specs=P(None, AXIS)))

        consts = tuple(jax.device_put(jnp.asarray(a), sh)
                       for a in (idx, wgt, idx0, wgt0))
        act_rows = [jax.device_put(jnp.asarray(a)[None], rep) for a in acts]

        def probe(phase, category, thunk):
            t0us = tr.now_us()
            best = _probes._time_best_us(thunk, reps=2)
            tr.add(f"probe.{phase}", category, t0us, tr.now_us(),
                   probe=True, phase=phase, per_launch_us=best / R, reps=R,
                   impl=impl, depth=depth)

        def run(init):
            i, w, i0, w0 = consts
            with tr.span("t0_launch", "dispatch", step=0):
                st = t0_fn(jax.device_put(init, sh), i0, w0)
            with tr.span("t0_kernel", "compute.interior", step=0):
                st = jax.block_until_ready(st)
            if graph.steps == 1:
                return st[0]
            with _halo.transport_span(tr, "prologue_exchange", impl=impl,
                                      depth=depth, setup=True):
                ii, wi, ib, wb, hl, hr = jax.block_until_ready(
                    setup_fn(st, i, w))
            for l, a in enumerate(act_rows):
                with tr.span("pipelined_launch", "launch", launch=l,
                             steps_per_launch=S, impl=impl, depth=depth,
                             kernel_launches=2):
                    st, hl, hr = jax.block_until_ready(
                        launch_fn(st, hl, hr, a, ii, wi, ib, wb))
            steady = act_rows[0]
            probe("exchange", "exchange", lambda: ex_probe(hl, hr))
            probe("boundary", "compute.boundary",
                  lambda: bd_probe(st, hl, hr, steady, ib, wb))
            probe("interior", "compute.interior",
                  lambda: in_probe(st, steady, ii, wi))
            return st[0]

        return run

    def _trace_stride_steps(self, graph: TaskGraph) -> Callable:
        """Traced stride (butterfly) plan: per step, the period slot's
        stride picks host-side between an in-block XOR shuffle (no
        collective — kernel span only) and an off-block XOR permute (one
        stride transport span, then the kernel span)."""
        mesh = self._mesh()
        D = len(self.devices)
        B = self._block(graph)
        mode = self._plan_combine(PLAN_STRIDE)
        kw = self._kernel_kw(graph.kernel, combine=mode)
        impl = self._halo_impl()
        period = graph.period
        strides = _patterns.butterfly_slot_strides(graph)
        tr = self.tracer
        sh = NamedSharding(mesh, P(AXIS))
        dummy_i = jnp.zeros((1, 1), jnp.int32)
        dummy_w = jnp.zeros((B, 1), WEIGHT_DTYPE)
        i0, w0 = _self_tables(B)

        def smap(f, n_in=1):
            return jax.jit(shard_map(
                f, mesh=mesh, check_vma=False,
                in_specs=(P(AXIS),) * n_in if n_in > 1 else P(AXIS),
                out_specs=P(AXIS)))

        fns = {}  # stride -> (exchange jit | None, kernel jit)
        for s in sorted(set(strides)):
            ex = smap(lambda local, bs=s // B: _halo.exchange_stride(
                local, (bs,), D, AXIS, impl=impl)[0]) if s >= B else None
            if mode == "pair":
                if s < B:
                    def kern1(local, s=s):
                        src = jnp.concatenate(
                            [local, _xor_swap(local, s)], axis=0)
                        return _kops.taskbench_step(
                            src[None], dummy_i[None], dummy_w[None], **kw)[0]
                    fns[s] = (None, smap(kern1))
                else:
                    def kern2(local, partner):
                        src = jnp.concatenate([local, partner], axis=0)
                        return _kops.taskbench_step(
                            src[None], dummy_i[None], dummy_w[None], **kw)[0]
                    fns[s] = (ex, smap(kern2, 2))
                continue
            idx_np, wgt_np, off_block = _stride_slot_tables(B, s)
            sidx, swgt = jnp.asarray(idx_np), jnp.asarray(wgt_np)
            if not off_block:
                def kern1(local, sidx=sidx, swgt=swgt):
                    return _kops.taskbench_step(
                        local[None], sidx[None], swgt[None], **kw)[0]
                fns[s] = (None, smap(kern1))
            else:
                def kern2(local, partner, sidx=sidx, swgt=swgt):
                    src = jnp.concatenate([local, partner], axis=0)
                    return _kops.taskbench_step(
                        src[None], sidx[None], swgt[None], **kw)[0]
                fns[s] = (ex, smap(kern2, 2))

        if mode == "pair":
            def t0l(local):
                src = jnp.concatenate([local, local], axis=0)
                return _kops.taskbench_step(
                    src[None], dummy_i[None], dummy_w[None], **kw)[0]
        else:
            def t0l(local):
                return _kops.taskbench_step(
                    local[None], i0[None], w0[None], **kw)[0]
        t0_fn = smap(t0l)

        def run(init):
            with tr.span("t0_launch", "dispatch", step=0):
                st = t0_fn(jax.device_put(init, sh))
            with tr.span("t0_kernel", "compute.interior", step=0):
                st = jax.block_until_ready(st)
            for t in range(1, graph.steps):
                s = strides[(t - 1) % period]
                ex, kern = fns[s]
                if ex is not None:
                    with _halo.transport_span(tr, "stride_exchange",
                                              impl=impl, depth=s // B,
                                              step=t, stride=s):
                        partner = jax.block_until_ready(ex(st))
                    args = (st, partner)
                else:
                    args = (st,)
                with tr.span("stride_kernel", "compute.interior", step=t,
                             stride=s):
                    st = jax.block_until_ready(kern(*args))
            return st

        return run

    def _global_tables_host(self, graph: TaskGraph) -> Callable:
        """Host (numpy) twin of `_global_table_fn`: ``at(t) -> (idx, wgt)``
        for one timestep — same rotation / period-slot arithmetic, computed
        host-side so the traced all-gather builders can feed per-step
        tables without burying the table policy in a jit."""
        W = graph.width
        if graph.pattern == "spread":
            bi, bw = _spread_base_operands(graph)

            def at(t):
                return (bi + (t - 1)) % W, bw

            return at
        gi, gw = _global_slot_operands(graph)
        period = gi.shape[0]

        def at(t):
            return gi[(t - 1) % period], gw[(t - 1) % period]

        return at

    def _trace_allgather_steps(self, graph: TaskGraph) -> Callable:
        """Traced per-step all-gather plan: per step, one gather span (the
        full-state collective) and one megakernel span; this launch's
        (idx, wgt) tables arrive AXIS-sharded so each device reads exactly
        the rows production's in-scan dynamic_slice would."""
        mesh = self._mesh()
        D = len(self.devices)
        B = self._block(graph)
        kw = self._kernel_kw(graph.kernel,
                             combine=self._plan_combine(PLAN_ALLGATHER))
        impl = self._gather_impl(graph.width)
        tr = self.tracer
        sh = NamedSharding(mesh, P(AXIS))
        tab_at = self._global_tables_host(graph)
        i0, w0 = _self_tables(B)

        t0_fn = jax.jit(shard_map(
            lambda local: _kops.taskbench_step(
                local[None], i0[None], w0[None], **kw)[0],
            mesh=mesh, check_vma=False, in_specs=P(AXIS), out_specs=P(AXIS)))

        if (graph.pattern == "all_to_all"
                and bool(self.options.get("psum_mean", True))):
            # production's psum-mean lowering, host-stepped: one reduction
            # span replaces the gather span (same numerics as execute())
            W = graph.width

            def psum_step(local):
                mean = _halo.global_mean(local, W, D, AXIS)
                src = jnp.broadcast_to(mean[None, :], (B, mean.shape[0]))
                return _kops.taskbench_step(
                    src[None], i0[None], w0[None], **kw)[0]

            p_fn = jax.jit(shard_map(
                psum_step, mesh=mesh, check_vma=False,
                in_specs=P(AXIS), out_specs=P(AXIS)))

            def run(init):
                with tr.span("t0_launch", "dispatch", step=0):
                    st = t0_fn(jax.device_put(init, sh))
                with tr.span("t0_kernel", "compute.interior", step=0):
                    st = jax.block_until_ready(st)
                for t in range(1, graph.steps):
                    with _halo.transport_span(
                            tr, "gather_psum_mean", impl="psum",
                            step=t, width=W):
                        st = jax.block_until_ready(p_fn(st))
                return st

            return run

        g_fn = jax.jit(shard_map(
            lambda local: _halo.gather_global(local, D, AXIS, impl=impl),
            mesh=mesh, check_vma=False, in_specs=P(AXIS), out_specs=P()))
        k_fn = jax.jit(shard_map(
            lambda full, i_loc, w_loc: _kops.taskbench_step(
                full[None], i_loc[None], w_loc[None], **kw)[0],
            mesh=mesh, check_vma=False,
            in_specs=(P(), P(AXIS), P(AXIS)), out_specs=P(AXIS)))
        # per-step tables device_put once at build (the host twin of the
        # consts the production scan closes over)
        tabs = []
        for t in range(1, graph.steps):
            i_t, w_t = tab_at(t)
            tabs.append((jax.device_put(jnp.asarray(i_t), sh),
                         jax.device_put(jnp.asarray(w_t), sh)))

        def run(init):
            with tr.span("t0_launch", "dispatch", step=0):
                st = t0_fn(jax.device_put(init, sh))
            with tr.span("t0_kernel", "compute.interior", step=0):
                st = jax.block_until_ready(st)
            for t in range(1, graph.steps):
                with _halo.transport_span(tr, "gather_global", impl=impl,
                                          step=t, width=graph.width):
                    full = jax.block_until_ready(g_fn(st))
                i_t, w_t = tabs[t - 1]
                with tr.span("global_kernel", "compute.interior", step=t):
                    st = jax.block_until_ready(k_fn(full, i_t, w_t))
            return st

        return run

    def _trace_allgather_blocked(self, graph: TaskGraph, S: int) -> Callable:
        """Traced blocked all-gather plan: per launch, one gather span and
        one S-depth kernel span driven by host-precomputed per-launch depth
        tables (the host twin of production's in-scan ``tables_for``)."""
        mesh = self._mesh()
        D = len(self.devices)
        B = self._block(graph)
        T = graph.steps
        kw0 = self._kernel_kw(graph.kernel,
                              combine=self._plan_combine(PLAN_ALLGATHER))
        kwb = dict(kw0, steps_per_launch=S)
        kwb.pop("block_rows", None)
        impl = self._gather_impl(graph.width)
        tr = self.tracer
        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        tab_at = self._global_tables_host(graph)
        time_varying = graph.pattern == "spread" or graph.period > 1
        acts = _act_schedule((T,), T, S)[:, 0]  # (L, S)
        i0, w0 = _self_tables(B)

        t0_fn = jax.jit(shard_map(
            lambda local: _kops.taskbench_step(
                local[None], i0[None], w0[None], **kw0)[0],
            mesh=mesh, check_vma=False, in_specs=P(AXIS), out_specs=P(AXIS)))
        g_fn = jax.jit(shard_map(
            lambda local: _halo.gather_global(local, D, AXIS, impl=impl),
            mesh=mesh, check_vma=False, in_specs=P(AXIS), out_specs=P()))

        def kern(full, i_t, w_t, a):
            nf = _kops.taskbench_step(
                full[None], i_t[None], w_t[None], a[None], **kwb)[0]
            r0 = jax.lax.axis_index(AXIS) * B
            return jax.lax.dynamic_slice_in_dim(nf, r0, B, axis=0)

        k_fn = jax.jit(shard_map(
            kern, mesh=mesh, check_vma=False,
            in_specs=(P(),) * 4, out_specs=P(AXIS)))
        launches = []
        for l, a in enumerate(acts):
            tt0 = 1 + l * S
            if time_varying:
                pairs = [tab_at(t) for t in range(tt0, tt0 + S)]
                i_t = np.stack([p[0] for p in pairs])
                w_t = np.stack([p[1] for p in pairs])
            else:
                i_t, w_t = tab_at(1)
            launches.append((jax.device_put(jnp.asarray(i_t), rep),
                             jax.device_put(jnp.asarray(w_t), rep),
                             jax.device_put(jnp.asarray(a), rep)))

        def run(init):
            with tr.span("t0_launch", "dispatch", step=0):
                st = t0_fn(jax.device_put(init, sh))
            with tr.span("t0_kernel", "compute.interior", step=0):
                st = jax.block_until_ready(st)
            for l, (i_t, w_t, a) in enumerate(launches):
                with _halo.transport_span(tr, "gather_global", impl=impl,
                                          launch=l, width=graph.width):
                    full = jax.block_until_ready(g_fn(st))
                with tr.span("blocked_global_kernel", "compute.interior",
                             launch=l, steps_per_launch=S):
                    st = jax.block_until_ready(k_fn(full, i_t, w_t, a))
            return st

        return run


def _stack_operands(ops4):
    """Stack per-member (idx, wgt, idx0, wgt0) on a leading K axis, padding
    every member's slot dim to the group max (idx 0 / weight 0: a harmless
    self-or-row-0 gather at weight zero)."""

    def stack(j):
        dmax = max(o[j].shape[1] for o in ops4)
        return np.stack([
            np.pad(o[j], ((0, 0), (0, dmax - o[j].shape[1])))
            for o in ops4
        ])

    return stack(0), stack(1), stack(2), stack(3)
