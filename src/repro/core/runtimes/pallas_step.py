"""`pallas_step` runtime — one fused megakernel launch per timestep.

The sixth rung of the backend ladder: like `bsp_scan` the whole timestep
loop lives in one jit (shard_map over devices, lax.scan over steps), but
where every other backend emits one gather + one combine + one body op per
dependency slot per step, this backend lowers the ENTIRE step — gather the
padded dependency slots from the previous-state buffer, masked-mean
combine, grain-size body — into a single `pallas_call`
(repro.kernels.taskbench_step). At fine grain the other backends' floor
measures XLA op-dispatch overhead; this one's floor is the kernel itself,
which is the fused per-task control path Task Bench (SC'20) shows is needed
for sub-microsecond METG.

Dataflow: points are block-distributed like `bsp`; halo-expressible
patterns exchange r edge rows per ring direction (`_halo.exchange_halos`),
and the megakernel gathers from the halo-EXTENDED local block through
host-precomputed (idx, wgt) operands — dependency slots rewritten to
extended-block positions with weights pre-normalized to 1/live-count, and
zero-dep rows self-padded, so the kernel has no edge/wrap/empty branches.

Ensembles: a stackable ensemble with a uniform KernelSpec runs ALL K
members' combines and bodies in the SAME launch (the megakernel's leading K
axis); one ring exchange moves every member's halos at once. Mixed-spec or
ragged-shape ensembles fall back to one launch per member inside the same
jitted scan. Heterogeneous ``steps`` freeze by masking: a member past its
own T carries its state through `jnp.where` untouched.

Options: combine="gather"|"onehot" (in-kernel gather vs MXU one-hot matmul
— see taskbench_step.py), block_rows, unroll.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import patterns as _patterns
from repro.core.graph import GraphEnsemble, TaskGraph
from repro.core.runtimes import _halo
from repro.core.runtimes.base import register
from repro.core.runtimes.bsp import AXIS, _BspBase
from repro.core.task_kernels import KernelSpec
from repro.kernels import ops as _kops
from repro.kernels.taskbench_step import prepare_step_operands


def _ext_dep_operands(
    graph: TaskGraph, block: int, halo: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(W, D) idx/wgt into the halo-extended local block, for one timestep.

    Local row i of a block starting at global row p0 gathers from an
    extended buffer ext = [p0-halo .. p0+B-1+halo] (mod W, via ring
    exchange), so dependency q of global row p maps to extended position
    (p mod B) + halo + o where o is q's signed window offset from p. All
    halo-expressible patterns have period 1, so ONE slice serves every
    timestep t >= 1.
    """
    r = _patterns.halo_radius(graph)
    if r < 0:
        raise ValueError(f"{graph.pattern} is not halo-expressible")
    if graph.period != 1:
        raise ValueError(f"halo pattern {graph.pattern} must have period 1")
    W = graph.width

    def to_ext(p: int, q: int) -> int:
        for o in range(-r, r + 1):
            if (p + o) % W == q:
                return p % block + halo + o
        raise ValueError(f"dep {q} of point {p} outside halo radius {r}")

    ext_lists: List[List[int]] = [
        [to_ext(p, q) for q in graph.dependencies(1, p)] for p in range(W)
    ]
    selfs = [p % block + halo for p in range(W)]
    return prepare_step_operands(ext_lists, W, selfs)


def _self_operands(width: int, block: int) -> Tuple[np.ndarray, np.ndarray]:
    """(W, 1) identity operands (t=0: body only, src = raw local block)."""
    selfs = [p % block for p in range(width)]
    return prepare_step_operands([[] for _ in range(width)], width, selfs)


def _window_operands(
    graph: TaskGraph, halo: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(W, 2*halo+1) per-offset combine weights for the window kernel mode.

    Column halo + o carries the (pre-normalized) weight of the dependency
    at window offset o, so the kernel's combine is a static chain of
    shifted-slice FMAs — no gather. Edge clipping (stencil_1d, dom), the
    per-row keep set (random_nearest), duplicate window wraps (nearest
    with W <= 2r), and the zero-dep self-keep rule are all encoded in the
    weights; idx is unused in this mode (returned as zeros).
    """
    r = _patterns.halo_radius(graph)
    if r < 0 or graph.period != 1:
        raise ValueError(f"{graph.pattern} is not window-expressible")
    W = graph.width
    D = 2 * halo + 1
    # idx is unused in window mode (the kernel substitutes a 1-element
    # dummy); a single column keeps the shard_map row-sharding contract
    # without shipping a dead (W, D) block
    idx = np.zeros((W, 1), dtype=np.int32)
    wgt = np.zeros((W, D), dtype=np.float64)
    for p in range(W):
        deps = graph.dependencies(1, p)
        if not deps:
            wgt[p, halo] = 1.0  # zero deps: keep own state (self weight 1)
            continue
        share = 1.0 / len(deps)
        for q in deps:
            for o in range(-r, r + 1):
                if (p + o) % W == q:
                    wgt[p, halo + o] += share
                    break
            else:
                raise ValueError(f"dep {q} of point {p} outside halo {r}")
    return idx, wgt.astype(np.float32)


@register
class PallasStepRuntime(_BspBase):
    name = "pallas_step"

    def supports(self, graph: TaskGraph):
        D = len(self.devices)
        if graph.width % D != 0:
            return False, f"width {graph.width} not divisible by {D} devices"
        r = _patterns.halo_radius(graph)
        if r < 0:
            return False, (
                f"pattern {graph.pattern} is not halo-expressible; "
                f"pallas_step fuses halo-pattern steps only"
            )
        B = graph.width // D
        if r > B:
            return False, f"halo radius {r} exceeds block {B} (multi-hop needed)"
        return True, ""

    # ------------------------------------------------------------ operands

    def _combine_mode(self) -> str:
        return str(self.options.get("combine", "window"))

    def _operands(self, graph: TaskGraph, halo: int):
        """Host-built (idx, wgt, idx0, wgt0) for one member graph.

        The t>=1 operands follow the selected combine mode; the t=0 (body
        only) call is always a 1-column self window, which is identical
        across modes (window offset 0 == gather of own row).
        """
        B = self._block(graph)
        if self._combine_mode() == "window":
            idx, wgt = _window_operands(graph, halo)
        else:
            idx, wgt = _ext_dep_operands(graph, B, halo)
        idx0, wgt0 = _self_operands(graph.width, B)
        return idx, wgt, idx0, wgt0

    def _kernel_kw(self, spec: KernelSpec) -> dict:
        kw = dict(
            kind=spec.kind, iterations=spec.iterations, scratch=spec.scratch,
            combine=self._combine_mode(),
        )
        if self.options.get("block_rows"):
            kw["block_rows"] = int(self.options["block_rows"])
        return kw

    # ------------------------------------------------------- single graph

    def build(self, graph: TaskGraph) -> Callable[[jax.Array], jax.Array]:
        self._require_support(graph)
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        H = _patterns.halo_radius(graph)
        kw = self._kernel_kw(graph.kernel)
        idx, wgt, idx0, wgt0 = self._operands(graph, H)

        def megastep(ext_src, i, w):  # (B|B+2H, P), (B, D'), (B, D')
            return _kops.taskbench_step(ext_src[None], i[None], w[None], **kw)[0]

        def local_run(local, i, w, i0, w0):  # all (B, ...) per device
            state = megastep(local, i0, w0)  # t=0: body only
            if graph.steps == 1:
                return state

            def body(s, _):
                if H > 0:
                    rl, rr = _halo.exchange_halos(s, H, D, AXIS)
                    ext = jnp.concatenate([rl, s, rr], axis=0)
                else:
                    ext = s
                return megastep(ext, i, w), None

            state, _ = jax.lax.scan(
                body, state, None, length=graph.steps - 1, unroll=unroll
            )
            return state

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(AXIS),) * 5, out_specs=P(AXIS),
            )
        )
        sh = NamedSharding(mesh, P(AXIS))
        consts = tuple(
            jax.device_put(jnp.asarray(a), sh) for a in (idx, wgt, idx0, wgt0)
        )
        return lambda init: fn(jax.device_put(init, sh), *consts)

    # ---------------------------------------------------------- ensembles

    def build_ensemble(self, ensemble: GraphEnsemble) -> Callable:
        self._require_ensemble_support(ensemble)
        members = ensemble.members
        specs = [g.kernel for g in members]
        if ensemble.stackable and len(set(specs)) == 1:
            return self._build_ensemble_stacked(ensemble)
        return self._build_ensemble_tuple(ensemble)

    def _build_ensemble_stacked(self, ensemble: GraphEnsemble) -> Callable:
        """All K members' combines + bodies in ONE megakernel launch/step."""
        members = ensemble.members
        K = len(members)
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        H = max(_patterns.halo_radius(g) for g in members)
        kw = self._kernel_kw(members[0].kernel)
        steps = ensemble.steps
        hetero = ensemble.heterogeneous_steps
        member_steps = np.asarray(ensemble.member_steps, np.int32)

        ops4 = [self._operands(g, H) for g in members]

        def stack(j):  # pad every member's slot dim to the group max, stack
            dmax = max(o[j].shape[1] for o in ops4)
            return np.stack([
                np.pad(o[j], ((0, 0), (0, dmax - o[j].shape[1])))
                for o in ops4
            ])

        idx, wgt = stack(0), stack(1)
        idx0, wgt0 = stack(2), stack(3)

        def megastep(ext_src, i, w):  # (K, S, P), (K, B, D'), (K, B, D')
            return _kops.taskbench_step(ext_src, i, w, **kw)

        def local_run(local, i, w, i0, w0, msteps):  # local (K, B, P)
            state = megastep(local, i0, w0)
            if steps == 1:
                return state

            def body(s, t):
                if H > 0:
                    rl, rr = _halo.exchange_halos(s, H, D, AXIS, row_axis=1)
                    ext = jnp.concatenate([rl, s, rr], axis=1)
                else:
                    ext = s
                nxt = megastep(ext, i, w)
                if hetero:  # freeze members whose own T is exhausted
                    active = (t < msteps)[:, None, None]
                    nxt = jnp.where(active, nxt, s)
                return nxt, None

            state, _ = jax.lax.scan(
                body, state, jnp.arange(1, steps), unroll=unroll
            )
            return state

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(None, AXIS),) * 5 + (P(),), out_specs=P(None, AXIS),
            )
        )
        sh = NamedSharding(mesh, P(None, AXIS))
        consts = tuple(
            jax.device_put(jnp.asarray(a), sh) for a in (idx, wgt, idx0, wgt0)
        ) + (jnp.asarray(member_steps),)

        def run(inits):
            out = fn(jax.device_put(jnp.stack(inits), sh), *consts)
            return tuple(out[k] for k in range(K))

        return run

    def _build_ensemble_tuple(self, ensemble: GraphEnsemble) -> Callable:
        """Mixed specs/shapes: one launch per member, still one jitted scan."""
        members = ensemble.members
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        steps = ensemble.steps
        halos = [_patterns.halo_radius(g) for g in members]
        kws = [self._kernel_kw(g.kernel) for g in members]
        ops4 = [self._operands(g, h) for g, h in zip(members, halos)]

        def member_step(k):
            H = halos[k]
            kw = kws[k]

            def step(s, i, w):
                if H > 0:
                    rl, rr = _halo.exchange_halos(s, H, D, AXIS)
                    ext = jnp.concatenate([rl, s, rr], axis=0)
                else:
                    ext = s
                return _kops.taskbench_step(ext[None], i[None], w[None], **kw)[0]

            return step

        step_fns = [member_step(k) for k in range(len(members))]

        def local_run(states, operands):
            states = tuple(
                _kops.taskbench_step(s[None], o[2][None], o[3][None], **kw)[0]
                for s, o, kw in zip(states, operands, kws)
            )
            if steps == 1:
                return states

            def body(ss, t):
                nxt = []
                for k, (s, o) in enumerate(zip(ss, operands)):
                    n = step_fns[k](s, o[0], o[1])
                    if members[k].steps < steps:
                        n = jnp.where(t < members[k].steps, n, s)
                    nxt.append(n)
                return tuple(nxt), None

            states, _ = jax.lax.scan(
                body, states, jnp.arange(1, steps), unroll=unroll
            )
            return states

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(AXIS), P(AXIS)), out_specs=P(AXIS),
            )
        )
        sh = NamedSharding(mesh, P(AXIS))
        consts = tuple(
            tuple(jax.device_put(jnp.asarray(a), sh) for a in o) for o in ops4
        )
        return lambda inits: fn(
            tuple(jax.device_put(x, sh) for x in inits), consts
        )

    def dispatches_per_run(self, graph: TaskGraph) -> int:
        return 1

    def ensemble_dispatches_per_run(self, ensemble: GraphEnsemble) -> int:
        return 1
