"""`pallas_step` runtime — fused megakernel launches, temporally blockable.

The sixth rung of the backend ladder: like `bsp_scan` the whole timestep
loop lives in one jit (shard_map over devices, lax.scan over launches), but
where every other backend emits one gather + one combine + one body op per
dependency slot per step, this backend lowers the ENTIRE step — gather the
padded dependency slots from the previous-state buffer, masked-mean
combine, grain-size body — into a single `pallas_call`
(repro.kernels.taskbench_step). At fine grain the other backends' floor
measures XLA op-dispatch overhead; this one's floor is the kernel itself,
which is the fused per-task control path Task Bench (SC'20) shows is needed
for sub-microsecond METG.

Temporal blocking (``steps_per_launch=S``): after PR 2 the remaining
per-step cost was one kernel launch plus one ring halo exchange PER STEP.
Since every halo-expressible pattern advances at most ``r`` rows of
influence per step, exchanging a deep halo of ``S*r`` rows once lets each
device advance S full timesteps locally before communicating again — the
classic deep-halo stencil optimization applied to the whole Task Bench
step. The loop becomes ``ceil((T-1)/S)`` launches; each launch's kernel
iterates combine + body S times on a working buffer whose valid region
shrinks by ``r`` rows per inner step (kernels/taskbench_step.py has the
kernel-side contract). Per-row combine weights ride along: they are
indexed by fixed global row id, so ONE deep exchange of the weight (and,
for gather/onehot, relative-offset) tables before the scan gives every
working row its exact edge-clipped weights at every depth. Heterogeneous
``steps`` freeze at launch granularity through a per-depth activity mask
baked host-side into the scan inputs — the final partial launch of any run
is the same mask (the "masked tail"). ``steps_per_launch`` accepts an int,
``"auto"`` (VMEM-budget tuner, kernels/schedule.py), and defaults to 1
(the PR-2 per-step behavior).

Dataflow: points are block-distributed like `bsp`; halo-expressible
patterns exchange ``S*r`` edge rows per ring direction
(`_halo.exchange_halos`, multi-hop when the depth exceeds a block), and the
megakernel gathers from the halo-EXTENDED local block through
host-precomputed (idx, wgt) operands — weights pre-normalized to
1/live-count and zero-dep rows self-padded, so the kernel has no
edge/wrap/empty branches.

Ensembles: a stackable ensemble with a uniform KernelSpec runs ALL K
members' combines and bodies in the SAME launch (the megakernel's leading K
axis); one deep ring exchange moves every member's halos for S steps at
once. Mixed-spec or ragged-shape ensembles fall back to one launch per
member inside the same jitted scan.

Double-buffered deep-halo pipeline (``pipeline=True``, the default): with
blocking alone every deep exchange still sits serially between launches, so
at fine grain the wall/step floor measures ring latency. The pipelined
schedule splits each blocked launch into a boundary phase (the 2*S*r edge
rows whose S-step light cone touches the incoming halo) and an interior
phase (everything else), and issues the NEXT launch's exchange on the
boundary outputs — which are exactly the rows the neighbors need — before
running the interior, so in steady state the exchange of launch l+1 is in
flight under the interior compute of launch l (`_halo.exchange_edges_start`
/ the HaloHandle carried in the scan are the double-buffered halo slots).
``pipeline=False`` is the serial-exchange ablation, mirroring the overlap
runtime's ``overlap=False``; blocks with no interior (B <= 2*S*r, where
splitting buys nothing and costs a second launch) fall back to it
automatically. The scan's final iteration issues one dead exchange (uniform
bodies); its cost is 1/L of the exchanges and it keeps the loop rolled.

Options: combine="window"|"gather"|"onehot" (see taskbench_step.py),
steps_per_launch=int|"auto", pipeline=True|False, block_rows, unroll.
"""
from __future__ import annotations

from typing import Callable, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import patterns as _patterns
from repro.core.graph import GraphEnsemble, TaskGraph
from repro.core.runtimes import _halo
from repro.core.runtimes.base import register
from repro.core.runtimes.bsp import AXIS, _BspBase
from repro.core.task_kernels import KernelSpec
from repro.kernels import ops as _kops
from repro.kernels import schedule as _schedule
from repro.kernels.taskbench_step import (
    WEIGHT_ACCUM_DTYPE,
    finalize_weights,
    prepare_step_operands,
)


def _ext_dep_operands(
    graph: TaskGraph, block: int, halo: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(W, D) idx/wgt into the halo-extended local block, for one timestep.

    Local row i of a block starting at global row p0 gathers from an
    extended buffer ext = [p0-halo .. p0+B-1+halo] (mod W, via ring
    exchange), so dependency q of global row p maps to extended position
    (p mod B) + halo + o where o is q's signed window offset from p. All
    halo-expressible patterns have period 1, so ONE slice serves every
    timestep t >= 1.
    """
    r = _patterns.halo_radius(graph)
    if r < 0:
        raise ValueError(f"{graph.pattern} is not halo-expressible")
    if graph.period != 1:
        raise ValueError(f"halo pattern {graph.pattern} must have period 1")
    W = graph.width

    def to_ext(p: int, q: int) -> int:
        for o in range(-r, r + 1):
            if (p + o) % W == q:
                return p % block + halo + o
        raise ValueError(f"dep {q} of point {p} outside halo radius {r}")

    ext_lists: List[List[int]] = [
        [to_ext(p, q) for q in graph.dependencies(1, p)] for p in range(W)
    ]
    selfs = [p % block + halo for p in range(W)]
    return prepare_step_operands(ext_lists, W, selfs)


def _rel_dep_operands(graph: TaskGraph) -> Tuple[np.ndarray, np.ndarray]:
    """(W, D) SIGNED-offset operands for the temporal-blocked gather modes.

    Row p's dependency q is stored as its window offset o (q == (p+o) mod
    W), not an absolute buffer position: offsets are a property of the
    global row alone, so the runtime can deep-halo-exchange these tables
    like state and convert to absolute working-buffer rows with a single
    ``+ arange(M)`` — every extended row then gathers its own dependencies
    at any launch depth. Zero-dep rows self-pad at offset 0.
    """
    r = _patterns.halo_radius(graph)
    if r < 0 or graph.period != 1:
        raise ValueError(f"{graph.pattern} is not halo-expressible")
    W = graph.width
    rel_lists: List[List[int]] = []
    for p in range(W):
        offs: List[int] = []
        for q in graph.dependencies(1, p):
            for o in range(-r, r + 1):
                if (p + o) % W == q:
                    offs.append(o)
                    break
            else:
                raise ValueError(f"dep {q} of point {p} outside halo {r}")
        rel_lists.append(offs)
    return prepare_step_operands(rel_lists, W, [0] * W)


def _self_operands(width: int, block: int) -> Tuple[np.ndarray, np.ndarray]:
    """(W, 1) identity operands (t=0: body only, src = raw local block)."""
    selfs = [p % block for p in range(width)]
    return prepare_step_operands([[] for _ in range(width)], width, selfs)


def _window_operands(
    graph: TaskGraph, halo: int
) -> Tuple[np.ndarray, np.ndarray]:
    """(W, 2*halo+1) per-offset combine weights for the window kernel mode.

    Column halo + o carries the (pre-normalized) weight of the dependency
    at window offset o, so the kernel's combine is a static chain of
    shifted-slice FMAs — no gather. Edge clipping (stencil_1d, dom), the
    per-row keep set (random_nearest), duplicate window wraps (nearest
    with W <= 2r), and the zero-dep self-keep rule are all encoded in the
    weights; idx is unused in this mode (returned as zeros). Weights are
    per GLOBAL row and patterns have period 1, so the same row's weights
    are correct at every timestep — the property the temporal-blocked path
    relies on when it exchanges these tables as deep halos.
    """
    r = _patterns.halo_radius(graph)
    if r < 0 or graph.period != 1:
        raise ValueError(f"{graph.pattern} is not window-expressible")
    W = graph.width
    D = 2 * halo + 1
    # idx is unused in window mode (the kernel substitutes a 1-element
    # dummy); a single column keeps the shard_map row-sharding contract
    # without shipping a dead (W, D) block
    idx = np.zeros((W, 1), dtype=np.int32)
    wgt = np.zeros((W, D), dtype=WEIGHT_ACCUM_DTYPE)
    for p in range(W):
        deps = graph.dependencies(1, p)
        if not deps:
            wgt[p, halo] = 1.0  # zero deps: keep own state (self weight 1)
            continue
        share = 1.0 / len(deps)
        for q in deps:
            for o in range(-r, r + 1):
                if (p + o) % W == q:
                    wgt[p, halo + o] += share
                    break
            else:
                raise ValueError(f"dep {q} of point {p} outside halo {r}")
    return idx, finalize_weights(wgt)


def _extend_state(s: jax.Array, depth: int, num_devices: int,
                  *, row_axis: int = 0) -> jax.Array:
    """Halo-extend a local block by ``depth`` rows per side (ring exchange;
    multi-hop past the block). Identity at depth 0."""
    if depth == 0:
        return s
    rl, rr = _halo.exchange_halos(s, depth, num_devices, AXIS,
                                  row_axis=row_axis)
    return jnp.concatenate([rl, s, rr], axis=row_axis)


def _rebase_rows(rel: jax.Array, *, row_axis: int = 0) -> jax.Array:
    """Signed window offsets -> absolute rows of THIS working buffer
    (``+ arange(M)``, clipped; the clip only ever binds on edge-garbage
    rows, which are never consumed by valid rows)."""
    m = rel.shape[row_axis]
    shape = [1] * rel.ndim
    shape[row_axis] = m
    rows = jnp.arange(m, dtype=jnp.int32).reshape(shape)
    return jnp.clip(rel + rows, 0, m - 1)


def _extend_tables(idx: jax.Array, wgt: jax.Array, depth: int,
                   num_devices: int, mode: str, *, row_axis: int = 0):
    """Deep-exchange the per-row operand tables ONCE for a blocked run.

    Weights (per global row, depth-invariant) extend exactly like state.
    Gather/onehot offset tables additionally rebase from signed offsets to
    absolute working-buffer rows (``_rebase_rows``). Window mode returns
    idx untouched (it is a dummy the kernel replaces).
    """
    wext = _extend_state(wgt, depth, num_devices, row_axis=row_axis)
    if mode == "window":
        return idx, wext
    rel = _extend_state(idx, depth, num_devices, row_axis=row_axis)
    return _rebase_rows(rel, row_axis=row_axis), wext


class _PhaseTables(NamedTuple):
    """Per-phase operand tables for one pipelined member (leading K axis).

    ``i_int``/``w_int`` cover the interior working buffer (the owned B
    rows); ``i_bnd``/``w_bnd`` cover the fused (K, 6*depth) boundary
    working buffer — rows [left buffer..., right buffer...] — matching
    ``taskbench_step_boundary``'s layout.
    """

    i_int: jax.Array
    w_int: jax.Array
    i_bnd: jax.Array
    w_bnd: jax.Array


def _phase_tables(idx: jax.Array, wgt: jax.Array, depth: int,
                  num_devices: int, mode: str) -> _PhaseTables:
    """Deep-exchange the tables once and slice them per pipeline phase.

    All arrays carry a leading K axis; rows live on axis 1. The extended
    table wext has B + 2*depth rows covering global rows [p0 - depth,
    p0 + B + depth): the interior buffer (owned rows [p0, p0 + B)) is
    wext[depth : depth + B], the left boundary buffer (rows [p0 - depth,
    p0 + 2*depth)) is wext[:3*depth], the right one wext[B - depth:].
    Gather/onehot offsets are rebased per buffer AFTER slicing — each
    phase's idx addresses its own working buffer.
    """
    K, B = wgt.shape[0], wgt.shape[1]

    def phases(ext):
        interior = jax.lax.slice_in_dim(ext, depth, depth + B, axis=1)
        boundary = jnp.concatenate([  # fused rows: [left 3d | right 3d]
            jax.lax.slice_in_dim(ext, 0, 3 * depth, axis=1),
            jax.lax.slice_in_dim(ext, B - depth, B + 2 * depth, axis=1),
        ], axis=1)
        return interior, boundary

    w_int, w_bnd = phases(_extend_state(wgt, depth, num_devices, row_axis=1))
    if mode == "window":  # idx is a dummy the kernel replaces
        i_int = jnp.zeros((K, 1, 1), jnp.int32)
        i_bnd = jnp.zeros((K, 1, 1), jnp.int32)
    else:
        rel_int, rel_bnd = phases(
            _extend_state(idx, depth, num_devices, row_axis=1))
        i_int = _rebase_rows(rel_int, row_axis=1)
        i_bnd = _rebase_rows(rel_bnd, row_axis=1)
    return _PhaseTables(i_int, w_int, i_bnd, w_bnd)


def _pipelined_launch(s, hl, hr, a, ph: _PhaseTables, depth: int,
                      num_devices: int, kwb: dict, impl: str = "xla"):
    """One software-pipelined blocked launch on stacked (K, B, payload)
    state. Steady-state schedule (DESIGN.md §6):

      1. boundary phase — consumes the halo received for THIS launch
         (``hl``/``hr``, issued at the end of the previous launch);
      2. the NEXT launch's deep exchange starts on the boundary outputs
         (they ARE the edge rows the neighbors need);
      3. the interior phase — no data dependence on the halo, the boundary
         launch, or the in-flight collective, so the scheduler may run the
         exchange under it.

    Returns (s_next, HaloHandle for the next launch).
    """
    B = s.shape[1]
    bl = jnp.concatenate(
        [hl, jax.lax.slice_in_dim(s, 0, 2 * depth, axis=1)], axis=1)
    br = jnp.concatenate(
        [jax.lax.slice_in_dim(s, B - 2 * depth, B, axis=1), hr], axis=1)
    bl_out, br_out = _kops.taskbench_boundary(
        bl, br, ph.i_bnd, ph.w_bnd, a, depth=depth, **kwb)
    handle = _halo.exchange_edges_start(
        bl_out, br_out, num_devices, AXIS, row_axis=1, impl=impl)
    mid = _kops.taskbench_interior(
        s, ph.i_int, ph.w_int, a, depth=depth, **kwb)
    return jnp.concatenate([bl_out, mid, br_out], axis=1), handle


def _prologue_exchange(state, depth, num_devices, impl: str = "xla"):
    """Start the FIRST blocked launch's exchange on the t=0 state's edges
    (the pipeline's fill step; the scan body then keeps one exchange in
    flight per launch)."""
    B = state.shape[1]
    return _halo.exchange_edges_start(
        jax.lax.slice_in_dim(state, 0, depth, axis=1),
        jax.lax.slice_in_dim(state, B - depth, B, axis=1),
        num_devices, AXIS, row_axis=1, impl=impl)


def _act_schedule(
    member_steps: Sequence[int], lockstep_steps: int, s: int
) -> np.ndarray:
    """(L, K, S) per-depth activity masks for the blocked launch loop.

    Launch l's inner step d executes lockstep timestep t = 1 + l*S + d;
    member k is active iff t < T_k (its own horizon) — the same predicate
    the per-step backends apply with `jnp.where`, here frozen INTO the
    launch schedule host-side. The final launch of any run carries the
    masked tail ((T-1) mod S trailing zeros for every member).
    """
    L = max(1, -(-(lockstep_steps - 1) // s)) if lockstep_steps > 1 else 0
    t = 1 + (np.arange(L)[:, None, None] * s + np.arange(s)[None, None, :])
    msteps = np.asarray(member_steps, np.int64)[None, :, None]
    return (t < msteps).astype(np.float32)


@register
class PallasStepRuntime(_BspBase):
    name = "pallas_step"

    def supports(self, graph: TaskGraph):
        D = len(self.devices)
        if graph.width % D != 0:
            return False, f"width {graph.width} not divisible by {D} devices"
        r = _patterns.halo_radius(graph)
        if r < 0:
            return False, (
                f"pattern {graph.pattern} is not halo-expressible; "
                f"pallas_step fuses halo-pattern steps only"
            )
        # no r <= block restriction: _halo.exchange_halos goes multi-hop
        # when a (deep) halo exceeds the local block
        return True, ""

    # ------------------------------------------------------------ operands

    def _combine_mode(self) -> str:
        return str(self.options.get("combine", "window"))

    def _operands(self, graph: TaskGraph, halo: int):
        """Host-built (idx, wgt, idx0, wgt0) for one member graph (S=1).

        The t>=1 operands follow the selected combine mode; the t=0 (body
        only) call is always a 1-column self window, which is identical
        across modes (window offset 0 == gather of own row).
        """
        B = self._block(graph)
        if self._combine_mode() == "window":
            idx, wgt = _window_operands(graph, halo)
        else:
            idx, wgt = _ext_dep_operands(graph, B, halo)
        idx0, wgt0 = _self_operands(graph.width, B)
        return idx, wgt, idx0, wgt0

    def _blocked_operands(self, graph: TaskGraph, halo: int):
        """Host-built (idx, wgt, idx0, wgt0) for the blocked path.

        Window mode reuses the per-global-row weight table; gather/onehot
        switch to SIGNED offsets (_rel_dep_operands) so the tables can be
        deep-halo-exchanged and rebased onto the working buffer in-scan.
        """
        B = self._block(graph)
        if self._combine_mode() == "window":
            idx, wgt = _window_operands(graph, halo)
        else:
            idx, wgt = _rel_dep_operands(graph)
        idx0, wgt0 = _self_operands(graph.width, B)
        return idx, wgt, idx0, wgt0

    def _kernel_kw(self, spec: KernelSpec) -> dict:
        kw = dict(
            kind=spec.kind, iterations=spec.iterations, scratch=spec.scratch,
            combine=self._combine_mode(),
        )
        if self.options.get("block_rows"):
            kw["block_rows"] = int(self.options["block_rows"])
        return kw

    # ---------------------------------------------------------- pipelining

    def _pipeline_requested(self) -> bool:
        """``pipeline=False`` is the serial-exchange ablation (mirrors the
        overlap runtime's ``overlap=False``); default on."""
        return bool(self.options.get("pipeline", True))

    def _halo_impl(self) -> str:
        """Transport for the pipelined edge exchange: "xla" (fused
        single-collective default) or "ppermute" (per-direction; isolates
        the pure scheduling effect in ablations)."""
        return str(self.options.get("halo_impl", "xla"))

    def _pipeline_active(self, block: int, s: int, halo: int) -> bool:
        """The pipelined schedule applies when blocking is on AND the owned
        block keeps a nonempty interior once 2*S*r edge rows belong to the
        boundary phase. Tiny blocks (block <= 2*S*r) have nothing to hide
        the exchange under — the regime where pipeline=False wins anyway by
        not paying the second launch — so they fall back to the serial
        schedule. Note S*r < block here, so the pipelined exchange is
        always single-hop. Under ``steps_per_launch="auto"`` the tuner's
        profitability verdict also binds (a fallback depth chosen with no
        covering candidate runs serial); an EXPLICIT S is the user's
        ablation choice and pipelines whenever structurally possible."""
        if not (s > 1 and halo > 0 and self._pipeline_requested()
                and block > 2 * s * halo):
            return False
        if _schedule.is_auto(self.options.get("steps_per_launch")):
            return _schedule.pipeline_interior_covers_exchange(block, halo, s)
        return True

    # ------------------------------------------------------- launch depth

    def _steps_per_launch(self, block: int, radius: int, payload: int,
                          total_steps: int) -> int:
        return _schedule.resolve_steps_per_launch(
            self.options.get("steps_per_launch"),
            block=block, radius=radius, payload=payload,
            total_steps=total_steps, combine=self._combine_mode(),
            pipeline=self._pipeline_requested(),
        )

    def _graph_steps_per_launch(self, graph: TaskGraph) -> int:
        return self._steps_per_launch(
            self._block(graph), _patterns.halo_radius(graph), graph.payload,
            graph.steps,
        )

    def _ensemble_steps_per_launch(self, ensemble: GraphEnsemble) -> int:
        """Common launch depth for an ensemble: one cadence for all members
        (launch boundaries are shared), so take the most conservative
        member's resolved depth."""
        members = ensemble.members
        if self._is_stacked(ensemble):
            H = max(_patterns.halo_radius(g) for g in members)
            return self._steps_per_launch(
                self._block(members[0]), H, members[0].payload, ensemble.steps
            )
        return min(
            self._steps_per_launch(
                self._block(g), _patterns.halo_radius(g), g.payload,
                ensemble.steps,
            )
            for g in members
        )

    @staticmethod
    def _is_stacked(ensemble: GraphEnsemble) -> bool:
        return ensemble.stackable and len({g.kernel for g in ensemble.members}) == 1

    @staticmethod
    def _launches(total_steps: int, s: int) -> int:
        """Kernel launches for one member's run: the t=0 body-only launch
        plus ceil((T-1)/S) blocked combine launches."""
        if total_steps <= 1:
            return 1
        return 1 + -(-(total_steps - 1) // s)

    # ------------------------------------------------------- single graph

    def build(self, graph: TaskGraph) -> Callable[[jax.Array], jax.Array]:
        self._require_support(graph)
        H = _patterns.halo_radius(graph)
        S = self._graph_steps_per_launch(graph)
        if S > 1:
            return self._build_blocked(graph, S)
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        kw = self._kernel_kw(graph.kernel)
        idx, wgt, idx0, wgt0 = self._operands(graph, H)

        def megastep(ext_src, i, w):  # (B|B+2H, P), (B, D'), (B, D')
            return _kops.taskbench_step(ext_src[None], i[None], w[None], **kw)[0]

        def local_run(local, i, w, i0, w0):  # all (B, ...) per device
            state = megastep(local, i0, w0)  # t=0: body only
            if graph.steps == 1:
                return state

            def body(s, _):
                return megastep(_extend_state(s, H, D), i, w), None

            state, _ = jax.lax.scan(
                body, state, None, length=graph.steps - 1, unroll=unroll
            )
            return state

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(AXIS),) * 5, out_specs=P(AXIS),
            )
        )
        sh = NamedSharding(mesh, P(AXIS))
        consts = tuple(
            jax.device_put(jnp.asarray(a), sh) for a in (idx, wgt, idx0, wgt0)
        )
        return lambda init: fn(jax.device_put(init, sh), *consts)

    def _build_blocked(self, graph: TaskGraph, S: int) -> Callable:
        """ceil((T-1)/S) launches: one deep exchange + one S-step kernel
        per launch instead of one exchange + one launch per step. When the
        pipeline applies (DESIGN.md §6) each launch splits into boundary +
        interior phases and the next launch's exchange rides under the
        interior; otherwise the exchange sits serially before the launch.
        """
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        H = _patterns.halo_radius(graph)
        depth = S * H
        mode = self._combine_mode()
        kw0 = self._kernel_kw(graph.kernel)
        kwb = dict(kw0, steps_per_launch=S)
        kwb.pop("block_rows", None)  # blocked path: one program per member
        idx, wgt, idx0, wgt0 = self._blocked_operands(graph, H)
        acts = _act_schedule((graph.steps,), graph.steps, S)[:, 0]  # (L, S)
        T = graph.steps
        pipelined = self._pipeline_active(self._block(graph), S, H)
        impl = self._halo_impl()

        def local_run(local, i, w, i0, w0, act_seq):
            state = _kops.taskbench_step(
                local[None], i0[None], w0[None], **kw0)[0]  # t=0: body only
            if T == 1:
                return state
            B = local.shape[0]
            if pipelined:
                ph = _phase_tables(i[None], w[None], depth, D, mode)
                h = _prologue_exchange(state[None], depth, D, impl)

                def pbody(carry, a):  # a: (S,) per-depth activity
                    s, hl, hr = carry
                    s2, h2 = _pipelined_launch(
                        s, hl, hr, a[None], ph, depth, D, kwb, impl)
                    return (s2, h2.recv_left, h2.recv_right), None

                (state3, _, _), _ = jax.lax.scan(
                    pbody, (state[None], h.recv_left, h.recv_right),
                    act_seq, unroll=unroll)
                return state3[0]

            # the per-row operand tables are deep-exchanged ONCE: every
            # working row then owns its exact (edge-clipped) weights
            iext, wext = _extend_tables(i, w, depth, D, mode)

            def body(s, a):  # a: (S,) per-depth activity
                ext = _extend_state(s, depth, D)
                nf = _kops.taskbench_step(
                    ext[None], iext[None], wext[None], a[None], **kwb)[0]
                return jax.lax.slice_in_dim(nf, depth, depth + B, axis=0), None

            state, _ = jax.lax.scan(body, state, act_seq, unroll=unroll)
            return state

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(AXIS),) * 5 + (P(),), out_specs=P(AXIS),
            )
        )
        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        consts = tuple(
            jax.device_put(jnp.asarray(a), sh) for a in (idx, wgt, idx0, wgt0)
        ) + (jax.device_put(jnp.asarray(acts), rep),)
        return lambda init: fn(jax.device_put(init, sh), *consts)

    # ---------------------------------------------------------- ensembles

    def build_ensemble(self, ensemble: GraphEnsemble) -> Callable:
        self._require_ensemble_support(ensemble)
        S = self._ensemble_steps_per_launch(ensemble)
        if self._is_stacked(ensemble):
            if S > 1:
                return self._build_ensemble_stacked_blocked(ensemble, S)
            return self._build_ensemble_stacked(ensemble)
        if S > 1:
            return self._build_ensemble_tuple_blocked(ensemble, S)
        return self._build_ensemble_tuple(ensemble)

    def _build_ensemble_stacked(self, ensemble: GraphEnsemble) -> Callable:
        """All K members' combines + bodies in ONE megakernel launch/step."""
        members = ensemble.members
        K = len(members)
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        H = max(_patterns.halo_radius(g) for g in members)
        kw = self._kernel_kw(members[0].kernel)
        steps = ensemble.steps
        hetero = ensemble.heterogeneous_steps
        member_steps = np.asarray(ensemble.member_steps, np.int32)

        ops4 = [self._operands(g, H) for g in members]
        idx, wgt, idx0, wgt0 = _stack_operands(ops4)

        def megastep(ext_src, i, w):  # (K, S, P), (K, B, D'), (K, B, D')
            return _kops.taskbench_step(ext_src, i, w, **kw)

        def local_run(local, i, w, i0, w0, msteps):  # local (K, B, P)
            state = megastep(local, i0, w0)
            if steps == 1:
                return state

            def body(s, t):
                nxt = megastep(_extend_state(s, H, D, row_axis=1), i, w)
                if hetero:  # freeze members whose own T is exhausted
                    active = (t < msteps)[:, None, None]
                    nxt = jnp.where(active, nxt, s)
                return nxt, None

            state, _ = jax.lax.scan(
                body, state, jnp.arange(1, steps), unroll=unroll
            )
            return state

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(None, AXIS),) * 5 + (P(),), out_specs=P(None, AXIS),
            )
        )
        sh = NamedSharding(mesh, P(None, AXIS))
        consts = tuple(
            jax.device_put(jnp.asarray(a), sh) for a in (idx, wgt, idx0, wgt0)
        ) + (jnp.asarray(member_steps),)

        def run(inits):
            out = fn(jax.device_put(jnp.stack(inits), sh), *consts)
            return tuple(out[k] for k in range(K))

        return run

    def _build_ensemble_stacked_blocked(
        self, ensemble: GraphEnsemble, S: int
    ) -> Callable:
        """All K members share each deep exchange AND each S-step launch."""
        members = ensemble.members
        K = len(members)
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        H = max(_patterns.halo_radius(g) for g in members)
        depth = S * H
        mode = self._combine_mode()
        kw0 = self._kernel_kw(members[0].kernel)
        kwb = dict(kw0, steps_per_launch=S)
        kwb.pop("block_rows", None)
        steps = ensemble.steps

        ops4 = [self._blocked_operands(g, H) for g in members]
        idx, wgt, idx0, wgt0 = _stack_operands(ops4)
        acts = _act_schedule(ensemble.member_steps, steps, S)  # (L, K, S)
        pipelined = self._pipeline_active(self._block(members[0]), S, H)
        impl = self._halo_impl()

        def local_run(local, i, w, i0, w0, act_seq):  # local (K, B, P)
            state = _kops.taskbench_step(local, i0, w0, **kw0)
            if steps == 1:
                return state
            B = local.shape[1]
            if pipelined:
                # one boundary launch (K row-fused 6*depth-row programs) +
                # one interior launch per deep exchange — every member
                # shares both
                ph = _phase_tables(i, w, depth, D, mode)
                h = _prologue_exchange(state, depth, D, impl)

                def pbody(carry, a):  # a: (K, S)
                    s, hl, hr = carry
                    s2, h2 = _pipelined_launch(
                        s, hl, hr, a, ph, depth, D, kwb, impl)
                    return (s2, h2.recv_left, h2.recv_right), None

                (state, _, _), _ = jax.lax.scan(
                    pbody, (state, h.recv_left, h.recv_right),
                    act_seq, unroll=unroll)
                return state

            iext, wext = _extend_tables(i, w, depth, D, mode, row_axis=1)

            def body(s, a):  # a: (K, S) per-member per-depth activity
                ext = _extend_state(s, depth, D, row_axis=1)
                nf = _kops.taskbench_step(ext, iext, wext, a, **kwb)
                return jax.lax.slice_in_dim(nf, depth, depth + B, axis=1), None

            state, _ = jax.lax.scan(body, state, act_seq, unroll=unroll)
            return state

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(None, AXIS),) * 5 + (P(),), out_specs=P(None, AXIS),
            )
        )
        sh = NamedSharding(mesh, P(None, AXIS))
        rep = NamedSharding(mesh, P())
        consts = tuple(
            jax.device_put(jnp.asarray(a), sh) for a in (idx, wgt, idx0, wgt0)
        ) + (jax.device_put(jnp.asarray(acts), rep),)

        def run(inits):
            out = fn(jax.device_put(jnp.stack(inits), sh), *consts)
            return tuple(out[k] for k in range(K))

        return run

    def _build_ensemble_tuple(self, ensemble: GraphEnsemble) -> Callable:
        """Mixed specs/shapes: one launch per member, still one jitted scan."""
        members = ensemble.members
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        steps = ensemble.steps
        halos = [_patterns.halo_radius(g) for g in members]
        kws = [self._kernel_kw(g.kernel) for g in members]
        ops4 = [self._operands(g, h) for g, h in zip(members, halos)]

        def member_step(k):
            H = halos[k]
            kw = kws[k]

            def step(s, i, w):
                ext = _extend_state(s, H, D)
                return _kops.taskbench_step(ext[None], i[None], w[None], **kw)[0]

            return step

        step_fns = [member_step(k) for k in range(len(members))]

        def local_run(states, operands):
            states = tuple(
                _kops.taskbench_step(s[None], o[2][None], o[3][None], **kw)[0]
                for s, o, kw in zip(states, operands, kws)
            )
            if steps == 1:
                return states

            def body(ss, t):
                nxt = []
                for k, (s, o) in enumerate(zip(ss, operands)):
                    n = step_fns[k](s, o[0], o[1])
                    if members[k].steps < steps:
                        n = jnp.where(t < members[k].steps, n, s)
                    nxt.append(n)
                return tuple(nxt), None

            states, _ = jax.lax.scan(
                body, states, jnp.arange(1, steps), unroll=unroll
            )
            return states

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(AXIS), P(AXIS)), out_specs=P(AXIS),
            )
        )
        sh = NamedSharding(mesh, P(AXIS))
        consts = tuple(
            tuple(jax.device_put(jnp.asarray(a), sh) for a in o) for o in ops4
        )
        return lambda inits: fn(
            tuple(jax.device_put(x, sh) for x in inits), consts
        )

    def _build_ensemble_tuple_blocked(
        self, ensemble: GraphEnsemble, S: int
    ) -> Callable:
        """Mixed specs/shapes, blocked: one S-step launch per member per
        scan iteration, launch cadence (and act schedule) shared."""
        members = ensemble.members
        K = len(members)
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        D = len(self.devices)
        steps = ensemble.steps
        mode = self._combine_mode()
        halos = [_patterns.halo_radius(g) for g in members]
        depths = [S * h for h in halos]
        kws = [self._kernel_kw(g.kernel) for g in members]
        kwbs = [dict(kw, steps_per_launch=S) for kw in kws]
        for kwb in kwbs:
            kwb.pop("block_rows", None)
        ops4 = [self._blocked_operands(g, h) for g, h in zip(members, halos)]
        acts = _act_schedule(ensemble.member_steps, steps, S)  # (L, K, S)
        # per-member pipeline gate: the cadence is shared, but a member with
        # no interior at depth S*h_k keeps the serial exchange inside the
        # same scan body
        piped = [
            self._pipeline_active(self._block(g), S, h)
            for g, h in zip(members, halos)
        ]
        impl = self._halo_impl()

        def local_run(states, operands, act_seq):
            states = tuple(
                _kops.taskbench_step(s[None], o[2][None], o[3][None], **kw)[0]
                for s, o, kw in zip(states, operands, kws)
            )
            if steps == 1:
                return states

            exts = []   # serial members: deep-exchanged (iext, wext) tables
            phs = []    # pipelined members: per-phase tables
            halos0 = []  # pipelined members: the fill-step exchange
            for k, (s, o) in enumerate(zip(states, operands)):
                if piped[k]:
                    exts.append(None)
                    phs.append(_phase_tables(
                        o[0][None], o[1][None], depths[k], D, mode))
                    h = _prologue_exchange(s[None], depths[k], D, impl)
                    halos0.append((h.recv_left, h.recv_right))
                else:
                    exts.append(_extend_tables(o[0], o[1], depths[k], D, mode))
                    phs.append(None)
                    halos0.append(())

            def body(carry, a):  # a: (K, S)
                ss, hh = carry
                nxt, nh = [], []
                for k, s in enumerate(ss):
                    dep = depths[k]
                    if piped[k]:
                        hl, hr = hh[k]
                        s2, h2 = _pipelined_launch(
                            s[None], hl, hr, a[k][None], phs[k], dep, D,
                            kwbs[k], impl)
                        nxt.append(s2[0])
                        nh.append((h2.recv_left, h2.recv_right))
                        continue
                    B = s.shape[0]
                    ext = _extend_state(s, dep, D)
                    iext, wext = exts[k]
                    nf = _kops.taskbench_step(
                        ext[None], iext[None], wext[None], a[k][None],
                        **kwbs[k])[0]
                    nxt.append(
                        jax.lax.slice_in_dim(nf, dep, dep + B, axis=0))
                    nh.append(())
                return (tuple(nxt), tuple(nh)), None

            (states, _), _ = jax.lax.scan(
                body, (states, tuple(halos0)), act_seq, unroll=unroll)
            return states

        fn = jax.jit(
            shard_map(
                local_run, mesh=mesh, check_vma=False,
                in_specs=(P(AXIS), P(AXIS), P()), out_specs=P(AXIS),
            )
        )
        sh = NamedSharding(mesh, P(AXIS))
        rep = NamedSharding(mesh, P())
        consts = tuple(
            tuple(jax.device_put(jnp.asarray(a), sh) for a in o) for o in ops4
        )
        acts_dev = jax.device_put(jnp.asarray(acts), rep)
        return lambda inits: fn(
            tuple(jax.device_put(x, sh) for x in inits), consts, acts_dev
        )

    # ----------------------------------------------------------- accounting

    def dispatches_per_run(self, graph: TaskGraph) -> int:
        """Actual kernel launches: the t=0 body-only launch plus
        ceil((T-1)/S) blocked combine launches (S=1 degenerates to T).
        The pipelined schedule splits every blocked launch into a boundary
        launch + an interior launch — TWO kernel launches per deep
        exchange; the accounting stays honest about it (hiding the
        exchange is bought with an extra, smaller, launch)."""
        S = self._graph_steps_per_launch(graph)
        L = self._launches(graph.steps, S)
        if self._pipeline_active(
                self._block(graph), S, _patterns.halo_radius(graph)):
            return 1 + 2 * (L - 1)
        return L

    def ensemble_dispatches_per_run(self, ensemble: GraphEnsemble) -> int:
        """Stacked ensembles batch all K members into each launch (the
        pipelined split costs 2 launches per blocked iteration — boundary,
        covering both sides of all K members, plus interior); the tuple
        fallback launches each member every scan iteration (frozen members
        included — the kernel runs, the mask discards), so it pays the
        per-member count summed over members."""
        S = self._ensemble_steps_per_launch(ensemble)
        launches = self._launches(ensemble.steps, S)
        members = ensemble.members
        if self._is_stacked(ensemble):
            H = max(_patterns.halo_radius(g) for g in members)
            if self._pipeline_active(self._block(members[0]), S, H):
                return 1 + 2 * (launches - 1)
            return launches
        total = 0
        for g in members:
            piped = self._pipeline_active(
                self._block(g), S, _patterns.halo_radius(g))
            total += 1 + (2 if piped else 1) * (launches - 1)
        return total


def _stack_operands(ops4):
    """Stack per-member (idx, wgt, idx0, wgt0) on a leading K axis, padding
    every member's slot dim to the group max (idx 0 / weight 0: a harmless
    self-or-row-0 gather at weight zero)."""

    def stack(j):
        dmax = max(o[j].shape[1] for o in ops4)
        return np.stack([
            np.pad(o[j], ((0, 0), (0, dmax - o[j].shape[1])))
            for o in ops4
        ])

    return stack(0), stack(1), stack(2), stack(3)
