"""Shared halo-exchange dataflow used by the distributed runtimes.

Points are block-distributed: device d owns rows [d*B, (d+1)*B) of the global
(W, payload) state. Halo-expressible patterns (stencil/dom/nearest/...) reach
at most ``r = halo_radius`` points across, so one ring exchange of r edge rows
per direction supplies all remote inputs.

``make_halo_combine`` builds a combine closure that EXACTLY matches
``task_kernels.combine_dependencies`` (mean over live deps) so fused and
distributed backends stay bit-compatible — the masks below must mirror
patterns.dependencies for every edge case (global edges, dom's asymmetry,
random_nearest's keep set).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patterns as _patterns
from repro.core.graph import TaskGraph


def offset_keep(graph: TaskGraph) -> np.ndarray:
    """Which window offsets [-r..r] the pattern actually consumes."""
    r = _patterns.halo_radius(graph)
    offsets = np.arange(-r, r + 1)
    if graph.pattern == "no_comm":
        return offsets == 0
    if graph.pattern == "dom":
        return offsets <= 0
    # stencil_1d(_periodic), nearest, random_nearest: whole window
    return np.ones_like(offsets, dtype=bool)


def random_keep_table(graph: TaskGraph) -> Optional[np.ndarray]:
    """(W, 2r+1) keep mask for random_nearest; None for other patterns."""
    if graph.pattern != "random_nearest":
        return None
    r = graph.radius
    W = graph.width
    keep = np.zeros((W, 2 * r + 1), dtype=np.float32)
    for p in range(W):
        deps = set(_patterns.dependencies(graph, 1, p))
        for j, o in enumerate(range(-r, r + 1)):
            if (p + o) % W in deps:
                keep[p, j] = 1.0
    return keep


def make_halo_combine(graph: TaskGraph) -> Callable:
    """Build combine(ctx, n, p0) -> (n, payload).

    Args (of the returned closure):
      ctx: (n + 2r, payload) rows giving each output row its full window:
           output row i consumes ctx rows [i, i + 2r].
      n:   static number of output rows.
      p0:  traced global point id of output row 0 (for edge masking).
    """
    r = _patterns.halo_radius(graph)
    if r < 0:
        raise ValueError(f"{graph.pattern} is not halo-expressible")
    keep_np = offset_keep(graph)
    nonperiodic = graph.pattern in ("stencil_1d", "dom")
    rand_np = random_keep_table(graph)
    W = graph.width
    rand = jnp.asarray(rand_np) if rand_np is not None else None

    def combine(ctx: jax.Array, n: int, p0: jax.Array) -> jax.Array:
        if r == 0:  # no_comm: self only
            return ctx
        windows = jnp.stack(
            [
                jax.lax.dynamic_slice_in_dim(ctx, j, n, axis=0)
                for j in range(2 * r + 1)
            ],
            axis=1,
        )  # (n, 2r+1, payload)
        p = p0 + jnp.arange(n)  # (n,) global ids
        offs = jnp.arange(-r, r + 1)  # (2r+1,)
        mask = jnp.broadcast_to(
            jnp.asarray(keep_np, jnp.float32)[None, :], (n, 2 * r + 1)
        )
        if nonperiodic:
            q = p[:, None] + offs[None, :]
            mask = mask * ((q >= 0) & (q < W)).astype(jnp.float32)
        if rand is not None:
            mask = mask * jax.lax.dynamic_slice_in_dim(rand, p0, n, axis=0)
        denom = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
        return (windows * mask[..., None]).sum(axis=1) / denom

    return combine


def ring_perms(num_devices: int, axis: str = "shard"):
    """Forward (d -> d+1) and backward (d -> d-1) ring permutations."""
    fwd = [(d, (d + 1) % num_devices) for d in range(num_devices)]
    bwd = [(d, (d - 1) % num_devices) for d in range(num_devices)]
    return fwd, bwd


def exchange_halos(local: jax.Array, r: int, num_devices: int,
                   axis: str = "shard", *, row_axis: int = 0):
    """Ring-exchange r edge rows each way (multi-hop when r exceeds a block).

    Returns (recv_left, recv_right): the r rows that sit immediately
    left/right of this device's block in global order (wrapped at the ends;
    wrap values are masked off by the combine for non-periodic patterns).
    ``row_axis`` is the point-row dimension — 0 for a (B, payload) block, 1
    for an ensemble's stacked (K, B, payload) block, where one exchange
    moves every member's halos at once.

    ``r <= B`` is one ppermute of r sliced edge rows per direction (the
    per-step fast path). Deep halos (``r > B``, e.g. the temporal-blocked
    megakernel's S*radius rows) compose ``ceil(r / B)`` whole-block ring
    shifts per direction: hop h delivers the block h devices away, the
    blocks concatenate in global row order, and the innermost r rows are
    returned. Depths past a full ring wrap (hop count may exceed the device
    count) simply revisit blocks, which is exactly the periodic/mod-W
    semantics the halo combines expect.
    """
    fwd, bwd = ring_perms(num_devices, axis)
    n = local.shape[row_axis]
    if r <= n:
        last = jax.lax.slice_in_dim(local, n - r, n, axis=row_axis)
        first = jax.lax.slice_in_dim(local, 0, r, axis=row_axis)
        recv_left = jax.lax.ppermute(last, axis, fwd)  # from d-1: its last r
        recv_right = jax.lax.ppermute(first, axis, bwd)  # from d+1: its first r
        return recv_left, recv_right

    hops = -(-r // n)  # ceil: whole-block shifts per direction
    left_blocks = []   # hop h holds block d-h: collect nearest-first
    right_blocks = []  # hop h holds block d+h
    cur_l = cur_r = local
    for _ in range(hops):
        cur_l = jax.lax.ppermute(cur_l, axis, fwd)
        cur_r = jax.lax.ppermute(cur_r, axis, bwd)
        left_blocks.append(cur_l)
        right_blocks.append(cur_r)
    # global row order: [d-hops .. d-1] on the left, [d+1 .. d+hops] right
    left_full = jnp.concatenate(list(reversed(left_blocks)), axis=row_axis)
    right_full = jnp.concatenate(right_blocks, axis=row_axis)
    total = hops * n
    recv_left = jax.lax.slice_in_dim(
        left_full, total - r, total, axis=row_axis)
    recv_right = jax.lax.slice_in_dim(right_full, 0, r, axis=row_axis)
    return recv_left, recv_right
