"""Shared halo-exchange dataflow used by the distributed runtimes.

Points are block-distributed: device d owns rows [d*B, (d+1)*B) of the global
(W, payload) state. Halo-expressible patterns (stencil/dom/nearest/...) reach
at most ``r = halo_radius`` points across, so one ring exchange of r edge rows
per direction supplies all remote inputs.

``make_halo_combine`` builds a combine closure that EXACTLY matches
``task_kernels.combine_dependencies`` (mean over live deps) so fused and
distributed backends stay bit-compatible — the masks below must mirror
patterns.dependencies for every edge case (global edges, dom's asymmetry,
random_nearest's keep set).

Async interface (the pipelined `pallas_step` path): ``exchange_halos_start``
/ ``exchange_edges_start`` issue the ring transfer and return a
``HaloHandle``; ``exchange_halos_join`` yields the received rows. The
default (and only off-TPU) implementation issues ``ppermute`` ops whose
results nothing touches until the join point — the asynchrony is the SSA
dataflow itself: XLA's latency-hiding scheduler splits the collective into
start/done thunks and runs any independent compute between issue and join
under the transfer. On TPU, a Mosaic ``make_async_remote_copy`` ring kernel
(double-buffered VMEM halo slots, send/recv semaphores per direction) can
slot in behind the same start/join interface; it is not implemented here
because this container cannot lower or validate it — the interface is the
contract, `HALO_ASYNC_IMPLS` the registry a TPU build extends.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patterns as _patterns
from repro.core.graph import TaskGraph


def offset_keep(graph: TaskGraph) -> np.ndarray:
    """Which window offsets [-r..r] the pattern actually consumes."""
    r = _patterns.halo_radius(graph)
    offsets = np.arange(-r, r + 1)
    if graph.pattern == "no_comm":
        return offsets == 0
    if graph.pattern == "dom":
        return offsets <= 0
    # stencil_1d(_periodic), nearest, random_nearest: whole window
    return np.ones_like(offsets, dtype=bool)


def random_keep_table(graph: TaskGraph) -> Optional[np.ndarray]:
    """(W, 2r+1) keep mask for random_nearest; None for other patterns."""
    if graph.pattern != "random_nearest":
        return None
    r = graph.radius
    W = graph.width
    keep = np.zeros((W, 2 * r + 1), dtype=np.float32)
    for p in range(W):
        deps = set(_patterns.dependencies(graph, 1, p))
        for j, o in enumerate(range(-r, r + 1)):
            if (p + o) % W in deps:
                keep[p, j] = 1.0
    return keep


def make_halo_combine(graph: TaskGraph) -> Callable:
    """Build combine(ctx, n, p0) -> (n, payload).

    Args (of the returned closure):
      ctx: (n + 2r, payload) rows giving each output row its full window:
           output row i consumes ctx rows [i, i + 2r].
      n:   static number of output rows.
      p0:  traced global point id of output row 0 (for edge masking).
    """
    r = _patterns.halo_radius(graph)
    if r < 0:
        raise ValueError(f"{graph.pattern} is not halo-expressible")
    keep_np = offset_keep(graph)
    nonperiodic = graph.pattern in ("stencil_1d", "dom")
    rand_np = random_keep_table(graph)
    W = graph.width
    rand = jnp.asarray(rand_np) if rand_np is not None else None

    def combine(ctx: jax.Array, n: int, p0: jax.Array) -> jax.Array:
        if r == 0:  # no_comm: self only
            return ctx
        windows = jnp.stack(
            [
                jax.lax.dynamic_slice_in_dim(ctx, j, n, axis=0)
                for j in range(2 * r + 1)
            ],
            axis=1,
        )  # (n, 2r+1, payload)
        p = p0 + jnp.arange(n)  # (n,) global ids
        offs = jnp.arange(-r, r + 1)  # (2r+1,)
        mask = jnp.broadcast_to(
            jnp.asarray(keep_np, jnp.float32)[None, :], (n, 2 * r + 1)
        )
        if nonperiodic:
            q = p[:, None] + offs[None, :]
            mask = mask * ((q >= 0) & (q < W)).astype(jnp.float32)
        if rand is not None:
            mask = mask * jax.lax.dynamic_slice_in_dim(rand, p0, n, axis=0)
        denom = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)
        return (windows * mask[..., None]).sum(axis=1) / denom

    return combine


def ring_perms(num_devices: int, axis: str = "shard"):
    """Forward (d -> d+1) and backward (d -> d-1) ring permutations."""
    fwd = [(d, (d + 1) % num_devices) for d in range(num_devices)]
    bwd = [(d, (d - 1) % num_devices) for d in range(num_devices)]
    return fwd, bwd


def transport_span(tracer, kind: str, *, impl: str, depth: int = 0, **attrs):
    """The one span every traced transport dispatch goes through.

    Centralizing the category choice and the ``impl``/``depth`` tagging here
    keeps the attribution uniform across all three transport families (ring
    halo, stride/XOR partner, global gather) no matter which runtime issues
    them — decompose.py can then split "exchange" from "gather" wall without
    knowing which backend produced the trace. ``kind`` is the span name
    (e.g. "deep_exchange", "stride_exchange", "gather_global"); gather-family
    kinds land in the ``gather`` category, everything else in ``exchange``.
    """
    category = "gather" if "gather" in kind else "exchange"
    return tracer.span(kind, category, impl=impl, depth=depth, **attrs)


@dataclasses.dataclass(frozen=True)
class HaloHandle:
    """An in-flight ring exchange: the double-buffered halo slots.

    ``recv_left``/``recv_right`` are the transfer's landing buffers. Under
    the XLA implementation they are ordinary traced arrays that no op may
    consume before ``exchange_halos_join`` — keeping the window between
    start and join free of data dependences is what lets the scheduler run
    the collective under unrelated compute. A Mosaic implementation would
    carry (buffer, semaphore) pairs here instead; only the join may touch
    the buffers in either case.
    """

    recv_left: jax.Array
    recv_right: jax.Array

    def join(self) -> Tuple[jax.Array, jax.Array]:
        return self.recv_left, self.recv_right


def _gather_edges_start(first: jax.Array, last: jax.Array, num_devices: int,
                        axis: str = "shard", *, row_axis: int = 0) -> HaloHandle:
    """Fused default: ONE collective moves both directions.

    Having both edge buffers in hand at issue time — the property the
    double-buffered interface guarantees — lets the two ring directions
    share a single all-gather of the packed [first | last] edges instead of
    paying one collective rendezvous per direction (two back-to-back
    ppermutes cost ~3x one collective on this container's forced-host
    devices). Each device then slices its left neighbor's ``last`` and
    right neighbor's ``first`` out of the gathered ring locally; the moved
    rows are exact copies either way, so transports are bit-identical.
    """
    r = first.shape[row_axis]
    packed = jnp.concatenate([first, last], axis=row_axis)  # (2r, ...)
    ring = jax.lax.all_gather(
        packed, axis, axis=row_axis, tiled=True)  # (D * 2r, ...)
    d = jax.lax.axis_index(axis)
    left = jnp.mod(d - 1, num_devices) * 2 * r + r   # d-1's `last` rows
    right = jnp.mod(d + 1, num_devices) * 2 * r      # d+1's `first` rows
    return HaloHandle(
        recv_left=jax.lax.dynamic_slice_in_dim(ring, left, r, axis=row_axis),
        recv_right=jax.lax.dynamic_slice_in_dim(ring, right, r, axis=row_axis),
    )


def _ppermute_edges_start(first: jax.Array, last: jax.Array, num_devices: int,
                          axis: str = "shard", *, row_axis: int = 0) -> HaloHandle:
    """ppermute variant: one collective per direction, results untouched
    until the join — the transport ``exchange_halos`` uses, kept for
    parity testing and as the donated-buffer fallback where an all-gather
    does not lower."""
    del row_axis  # ppermute moves whole buffers; the slicing already happened
    fwd, bwd = ring_perms(num_devices, axis)
    return HaloHandle(
        recv_left=jax.lax.ppermute(last, axis, fwd),   # from d-1: its last r
        recv_right=jax.lax.ppermute(first, axis, bwd),  # from d+1: its first r
    )


#: name -> edge-transfer starter. "xla" (the fused single-collective
#: transport) is the portable default, "ppermute" the per-direction
#: variant; a TPU build registers "mosaic" (make_async_remote_copy ring
#: kernel) under the same signature and everything above this module is
#: unchanged.
HALO_ASYNC_IMPLS = {
    "xla": _gather_edges_start,
    "ppermute": _ppermute_edges_start,
}


def exchange_edges_start(first: jax.Array, last: jax.Array, num_devices: int,
                         axis: str = "shard", *, row_axis: int = 0,
                         impl: str = "xla") -> HaloHandle:
    """Start a ring exchange of PRE-SLICED edge rows (``r <= block``).

    ``first``/``last`` are this device's leading/trailing r rows (along
    ``row_axis``) — e.g. the boundary-phase outputs of a pipelined launch,
    which are exactly the rows the next launch's neighbors need, so the
    transfer can be issued the moment they exist, before any interior
    compute. Join with ``exchange_halos_join``.
    """
    try:
        start = HALO_ASYNC_IMPLS[impl]
    except KeyError:
        raise ValueError(
            f"unknown halo async impl {impl!r}; known {sorted(HALO_ASYNC_IMPLS)}"
        ) from None
    return start(first, last, num_devices, axis, row_axis=row_axis)


def exchange_halos_start(local: jax.Array, r: int, num_devices: int,
                         axis: str = "shard", *, row_axis: int = 0,
                         impl: str = "xla") -> HaloHandle:
    """Start a ring exchange of r edge rows each way; join for the results.

    The async counterpart of ``exchange_halos`` (same depth semantics,
    including the multi-hop deep path): slices the edge rows and issues the
    transfers, returning a ``HaloHandle`` whose buffers must not be
    consumed before ``exchange_halos_join``. Multi-hop depths (``r >
    block``) issue the whole chain of block shifts up front; the chain is
    still one dependence-free island the scheduler may sink under
    independent compute.
    """
    n = local.shape[row_axis]
    if r <= n:
        last = jax.lax.slice_in_dim(local, n - r, n, axis=row_axis)
        first = jax.lax.slice_in_dim(local, 0, r, axis=row_axis)
        return exchange_edges_start(first, last, num_devices, axis,
                                    row_axis=row_axis, impl=impl)

    fwd, bwd = ring_perms(num_devices, axis)
    hops = -(-r // n)  # ceil: whole-block shifts per direction
    left_blocks = []   # hop h holds block d-h: collect nearest-first
    right_blocks = []  # hop h holds block d+h
    cur_l = cur_r = local
    for _ in range(hops):
        cur_l = jax.lax.ppermute(cur_l, axis, fwd)
        cur_r = jax.lax.ppermute(cur_r, axis, bwd)
        left_blocks.append(cur_l)
        right_blocks.append(cur_r)
    # global row order: [d-hops .. d-1] on the left, [d+1 .. d+hops] right
    left_full = jnp.concatenate(list(reversed(left_blocks)), axis=row_axis)
    right_full = jnp.concatenate(right_blocks, axis=row_axis)
    total = hops * n
    recv_left = jax.lax.slice_in_dim(
        left_full, total - r, total, axis=row_axis)
    recv_right = jax.lax.slice_in_dim(right_full, 0, r, axis=row_axis)
    return HaloHandle(recv_left=recv_left, recv_right=recv_right)


def exchange_halos_join(handle: HaloHandle) -> Tuple[jax.Array, jax.Array]:
    """Complete an exchange: (recv_left, recv_right), now safe to consume."""
    return handle.join()


# --------------------------------------------------------------- strides
#
# Butterfly patterns (fft/tree) pair point p with p XOR 2^k — at block
# strides, device d's partner rows live wholesale on device d XOR bs
# (bs = stride // block). Unlike the ring halo there is no left/right:
# the XOR permutation is an involution, so ONE permute both sends and
# receives a full partner block per requested stride.


@dataclasses.dataclass(frozen=True)
class StrideHandle:
    """In-flight XOR block exchange: one landing buffer per stride.

    ``partners[j]`` is the full local-shaped block of device
    ``d XOR block_strides[j]``. The same start/join discipline as
    ``HaloHandle`` applies: nothing may consume a buffer before the join,
    which is what lets XLA's latency-hiding scheduler sink the
    collective(s) under independent compute. A Mosaic transport would
    carry (buffer, semaphore) pairs per stride behind the same interface.
    """

    partners: Tuple[jax.Array, ...]

    def join(self) -> Tuple[jax.Array, ...]:
        return self.partners


def _gather_stride_start(local: jax.Array, block_strides, num_devices: int,
                         axis: str = "shard", *,
                         row_axis: int = 0) -> StrideHandle:
    """Fused default: ONE all-gather serves every requested stride.

    Each device slices the blocks it needs — d XOR bs for each bs — out
    of the gathered ring locally. One collective rendezvous regardless of
    how many strides the caller wants (the same trade the fused halo
    transport makes: on forced-host devices rendezvous cost dominates
    moved bytes).
    """
    n = local.shape[row_axis]
    ring = jax.lax.all_gather(local, axis, axis=row_axis, tiled=True)
    d = jax.lax.axis_index(axis)
    return StrideHandle(partners=tuple(
        jax.lax.dynamic_slice_in_dim(
            ring, jnp.bitwise_xor(d, jnp.int32(bs)) * n, n, axis=row_axis)
        for bs in block_strides
    ))


def _ppermute_stride_start(local: jax.Array, block_strides, num_devices: int,
                           axis: str = "shard", *,
                           row_axis: int = 0) -> StrideHandle:
    """ppermute variant: one XOR collective per stride (moves only the
    partner blocks; kept for parity testing and as the minimal-traffic
    transport where an all-gather does not lower)."""
    del row_axis  # whole blocks move; no slicing needed
    partners = []
    for bs in block_strides:
        perm = [(d, d ^ int(bs)) for d in range(num_devices)]
        partners.append(jax.lax.ppermute(local, axis, perm))
    return StrideHandle(partners=tuple(partners))


#: name -> stride-transfer starter, mirroring HALO_ASYNC_IMPLS: "xla" is
#: the fused single-collective default, "ppermute" the per-stride variant;
#: a TPU build registers "mosaic" (make_async_remote_copy with one
#: send/recv semaphore pair per stride) under the same signature.
STRIDE_ASYNC_IMPLS = {
    "xla": _gather_stride_start,
    "ppermute": _ppermute_stride_start,
}

def _gather_xla(local: jax.Array, num_devices: int, axis: str,
                *, row_axis: int = 0) -> jax.Array:
    """One tiled all-gather: the monolithic baseline transport."""
    return jax.lax.all_gather(local, axis, axis=row_axis, tiled=True)


def _gather_ppermute(local: jax.Array, num_devices: int, axis: str,
                     *, row_axis: int = 0) -> jax.Array:
    """Assemble the ring from D-1 whole-block backward shifts and rotate
    into global order — the minimal-collective-primitive spelling, kept
    for transport parity tests (exact row copies, so outputs are
    bit-identical to "xla")."""
    _, bwd = ring_perms(num_devices, axis)
    blocks = [local]  # device-local order: [d, d+1, ..., d+D-1]
    cur = local
    for _ in range(num_devices - 1):
        cur = jax.lax.ppermute(cur, axis, bwd)
        blocks.append(cur)
    stacked = jnp.concatenate(blocks, axis=row_axis)
    n = local.shape[row_axis]
    d = jax.lax.axis_index(axis)
    # rotate [d..d+D-1] into [0..D-1]: global row 0 sits n*d rows from the
    # END of the device-local order exactly when d > 0; a doubled buffer
    # sliced at (D - d) * n mod (D * n) does it without traced-shift roll
    doubled = jnp.concatenate([stacked, stacked], axis=row_axis)
    start = jnp.mod((num_devices - d) * n, num_devices * n)
    return jax.lax.dynamic_slice_in_dim(
        doubled, start, num_devices * n, axis=row_axis)


def gather_chunk_group(num_devices: int) -> int:
    """Segment size for the chunked gather: the divisor of D nearest
    sqrt(D), so both stages rendezvous ~sqrt(D) participants instead of
    one D-wide barrier. 1 or D degenerates to the monolithic gather."""
    best, best_err = 1, float("inf")
    for g in range(1, num_devices + 1):
        if num_devices % g:
            continue
        err = abs(g - num_devices ** 0.5)
        if err < best_err or (err == best_err and g > best):
            best, best_err = g, err
    return best


def _gather_chunked(local: jax.Array, num_devices: int, axis: str,
                    *, row_axis: int = 0,
                    group: Optional[int] = None) -> jax.Array:
    """Hierarchical (neighbor-limited) gather: a ring of segment
    all-gathers instead of one D-wide rendezvous.

    Stage 1 all-gathers within contiguous ring segments of G devices;
    stage 2 all-gathers the assembled segment blocks across
    one-representative-per-segment stride groups. Each collective
    synchronizes a bounded participant count, which is what makes the
    global patterns pay O(W/D * log D)-ish coordination instead of a flat
    D-wide barrier per launch. Both stages move exact row copies in global
    order, so the result is bit-identical to the monolithic transport —
    for EVERY G | D, which is why G is a pure cost choice.

    ``group=None`` delegates G to the scheduling policy
    (``schedule.choose_gather_chunk_group``: explicit > env > measured
    grouping probes > the sqrt(D) analytic rule); an explicit ``group``
    must divide D. G <= 1 or G >= D degenerates to the monolithic gather.
    """
    if group is None:
        # lazy policy import (mirrors the runtime's schedule use): this
        # module must stay importable without the probes/cache machinery
        from repro.kernels import schedule as _schedule

        group, _ = _schedule.choose_gather_chunk_group(
            devices=num_devices,
            width=local.shape[row_axis] * num_devices)
    g = int(group)
    if g >= 1 and num_devices % g:
        raise ValueError(
            f"chunked gather group {g} does not divide D={num_devices}")
    if g <= 1 or g >= num_devices:
        return _gather_xla(local, num_devices, axis, row_axis=row_axis)
    ngroups = num_devices // g
    segments = [[b * g + i for i in range(g)] for b in range(ngroups)]
    seg = jax.lax.all_gather(local, axis, axis=row_axis, tiled=True,
                             axis_index_groups=segments)
    across = [[i + b * g for b in range(ngroups)] for i in range(g)]
    return jax.lax.all_gather(seg, axis, axis=row_axis, tiled=True,
                              axis_index_groups=across)


#: name -> global-gather transport, mirroring the halo/stride registries:
#: "xla" is the monolithic tiled all-gather, "ppermute" the D-1-shift ring
#: spelling, "chunked" the hierarchical two-stage segment gather that
#: bounds every rendezvous at ~sqrt(D) participants (the D >= 16 default
#: when a measured cost model ranks it cheaper).
GATHER_IMPLS = {
    "xla": _gather_xla,
    "ppermute": _gather_ppermute,
    "chunked": _gather_chunked,
}

#: kind -> the mutable transport registry behind it. This is the public
#: seam for transport extensions: a TPU build registers "mosaic" starters,
#: and the fault-injection layer (repro.resilience.faults) registers
#: "chaos+<base>" wrappers that delegate to the base impl but consult the
#: armed FaultPlan first — production impls and callers are untouched.
TRANSPORT_REGISTRIES = {
    "halo": HALO_ASYNC_IMPLS,
    "stride": STRIDE_ASYNC_IMPLS,
    "gather": GATHER_IMPLS,
}


def register_transport_impl(kind: str, name: str, start,
                            *, replace: bool = False) -> None:
    """Register a named transport starter in the ``kind`` registry.

    ``start`` must follow the registry's starter signature (see
    ``HALO_ASYNC_IMPLS`` / ``STRIDE_ASYNC_IMPLS``). Silent shadowing of a
    production transport is refused unless ``replace=True`` — a chaos
    wrapper accidentally registered as "xla" would corrupt every runtime
    in the process.
    """
    try:
        registry = TRANSPORT_REGISTRIES[kind]
    except KeyError:
        raise ValueError(
            f"unknown transport registry {kind!r}; "
            f"known {sorted(TRANSPORT_REGISTRIES)}") from None
    if name in registry and not replace:
        raise ValueError(
            f"transport impl {name!r} already registered for {kind!r}; "
            f"pass replace=True to shadow it deliberately")
    registry[name] = start


def exchange_stride_start(local: jax.Array, block_strides, num_devices: int,
                          axis: str = "shard", *, row_axis: int = 0,
                          impl: str = "xla") -> StrideHandle:
    """Start an XOR block exchange for each stride in ``block_strides``.

    ``num_devices`` must be a power of two (d XOR bs is only a
    permutation of the ring when it is; on other counts some partners
    fall off the mesh and the transports would diverge — ppermute crashes
    while the gather transport's clamped slice silently delivers wrong
    rows, so the contract is enforced loudly here). Every stride must be
    in [1, num_devices) (in-block pairing distances never reach this
    function — the caller shuffles locally). Join with
    ``exchange_stride_join``.
    """
    if num_devices & (num_devices - 1):
        raise ValueError(
            f"XOR stride exchange needs a power-of-two device count, "
            f"got {num_devices} (partner d XOR bs would leave the mesh)")
    for bs in block_strides:
        if not 0 < int(bs) < num_devices:
            raise ValueError(
                f"block stride {bs} outside [1, {num_devices}) — in-block "
                f"strides are local shuffles, not exchanges")
    try:
        start = STRIDE_ASYNC_IMPLS[impl]
    except KeyError:
        raise ValueError(
            f"unknown stride async impl {impl!r}; "
            f"known {sorted(STRIDE_ASYNC_IMPLS)}"
        ) from None
    return start(local, tuple(int(b) for b in block_strides), num_devices,
                 axis, row_axis=row_axis)


def exchange_stride_join(handle: StrideHandle) -> Tuple[jax.Array, ...]:
    """Complete a stride exchange: the partner blocks, safe to consume."""
    return handle.join()


def exchange_stride(local: jax.Array, block_strides, num_devices: int,
                    axis: str = "shard", *, row_axis: int = 0,
                    impl: str = "xla") -> Tuple[jax.Array, ...]:
    """Synchronous spelling: start and join back-to-back."""
    return exchange_stride_join(
        exchange_stride_start(local, block_strides, num_devices, axis,
                              row_axis=row_axis, impl=impl))


def gather_global(local: jax.Array, num_devices: int, axis: str = "shard",
                  *, row_axis: int = 0, impl: str = "xla",
                  chunk_group: Optional[int] = None) -> jax.Array:
    """The full global-order state on every device (the all-gather plan).

    ``impl`` names a GATHER_IMPLS transport: "xla" (one monolithic tiled
    all-gather), "ppermute" (D-1 ring shifts, parity-test spelling), or
    "chunked" (hierarchical segment gather bounding every rendezvous at
    ~sqrt(D) participants). All transports move exact row copies, so
    outputs are bit-identical across impls. ``chunk_group`` forces the
    chunked transport's rendezvous group G (must divide D); it only
    reaches the plain "chunked" impl — registry wrappers such as
    "chaos+chunked" keep the policy-resolved default.
    """
    if num_devices == 1:
        return local
    try:
        start = GATHER_IMPLS[impl]
    except KeyError:
        raise ValueError(
            f"unknown gather impl {impl!r}; known "
            f"{sorted(GATHER_IMPLS)}") from None
    if chunk_group is not None and impl == "chunked":
        return start(local, num_devices, axis, row_axis=row_axis,
                     group=chunk_group)
    return start(local, num_devices, axis, row_axis=row_axis)


def global_mean(local: jax.Array, width: int, num_devices: int,
                axis: str = "shard", *, row_axis: int = 0) -> jax.Array:
    """Mean over the GLOBAL row axis via one psum — the uniform
    all_to_all combine lowering.

    When every point depends on every point with weight 1/W, the gathered
    W-row buffer collapses to one vector: sum the local rows, psum the
    partial across the row axis, divide by W. This replaces an O(W)
    replication per launch with an O(payload) reduction. NOT bit-identical
    to the gather+masked-mean kernel (different summation order), but
    within float32 reduction tolerance — callers gate it behind an option.
    """
    partial = jnp.sum(local, axis=row_axis)
    if num_devices > 1:
        partial = jax.lax.psum(partial, axis)
    return partial / jnp.asarray(width, local.dtype)


def exchange_halos(local: jax.Array, r: int, num_devices: int,
                   axis: str = "shard", *, row_axis: int = 0):
    """Ring-exchange r edge rows each way (multi-hop when r exceeds a block).

    Returns (recv_left, recv_right): the r rows that sit immediately
    left/right of this device's block in global order (wrapped at the ends;
    wrap values are masked off by the combine for non-periodic patterns).
    ``row_axis`` is the point-row dimension — 0 for a (B, payload) block, 1
    for an ensemble's stacked (K, B, payload) block, where one exchange
    moves every member's halos at once.

    ``r <= B`` is one ppermute of r sliced edge rows per direction (the
    per-step fast path). Deep halos (``r > B``, e.g. the temporal-blocked
    megakernel's S*radius rows) compose ``ceil(r / B)`` whole-block ring
    shifts per direction: hop h delivers the block h devices away, the
    blocks concatenate in global row order, and the innermost r rows are
    returned. Depths past a full ring wrap (hop count may exceed the device
    count) simply revisit blocks, which is exactly the periodic/mod-W
    semantics the halo combines expect.

    This is the synchronous spelling — start and join back-to-back, pinned
    to the established per-direction ppermute transport so every backend
    that predates the pipeline (bsp/bsp_scan/overlap, and pallas_step's
    serial schedule) keeps its measured behavior. The pipelined paths call
    start/join themselves to put compute between, and default to the fused
    single-collective transport instead.
    """
    return exchange_halos_join(
        exchange_halos_start(local, r, num_devices, axis, row_axis=row_axis,
                             impl="ppermute")
    )
