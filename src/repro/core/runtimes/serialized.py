"""`serialized` runtime — one host dispatch per task.

Every task (t, p) is a separate jit call driven by a Python loop. This is the
maximal-overhead rung: it charges the full host->device dispatch latency to
every task, which is JAX's analogue of an AMT runtime's per-task spawn +
schedule cost (the quantity the paper isolates with fine-grain sweeps; cf.
HPX-local's threading-subsystem overhead, paper §3.3/§6.1).

At large grain the dispatch cost amortizes and this backend reaches the same
peak FLOP/s as `fused` (paper Fig 1a); at small grain its efficiency collapses
first, giving it the largest METG — exactly the Charm++/HPX-vs-MPI shape of
paper Table 2.

The task body jit is compiled ONCE per (deps, payload) shape and reused by all
T*W tasks, so what we time is dispatch, not compilation.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import TaskGraph
from repro.core.runtimes.base import Runtime, register
from repro.core.task_kernels import apply_kernel


@register
class SerializedRuntime(Runtime):
    name = "serialized"

    MAX_TASKS = 200_000  # refuse graphs whose python loop would take forever

    def supports(self, graph: TaskGraph):
        if graph.num_tasks > self.MAX_TASKS:
            return False, f"too many tasks for per-task dispatch ({graph.num_tasks})"
        if graph.pattern == "all_to_all" and graph.width > 1024:
            return False, "all_to_all fan-in too wide for per-task gather"
        return True, ""

    def build(self, graph: TaskGraph) -> Callable[[jax.Array], jax.Array]:
        spec = graph.kernel
        use_pallas = bool(self.options.get("use_pallas", False))

        @partial(jax.jit, static_argnums=())
        def task_no_deps(x):  # (payload,)
            return apply_kernel(x, spec, use_pallas=use_pallas)

        @jax.jit
        def task_with_deps(deps, mask):  # (D, payload), (D,)
            w = mask[:, None]
            combined = (deps * w).sum(0) / jnp.maximum(mask.sum(), 1.0)
            return apply_kernel(combined, spec, use_pallas=use_pallas)

        # Host-side dependency lists, precomputed (the "graph build" phase —
        # Task Bench likewise excludes graph construction from timing).
        dep_ids: List[List[tuple]] = []
        for t in range(graph.steps):
            dep_ids.append([graph.dependencies(t, p) for p in range(graph.width)])
        D = max(1, graph.max_deps)
        pad_masks = {}
        for t in range(graph.steps):
            for deps in dep_ids[t]:
                n = len(deps)
                if n and n not in pad_masks:
                    pad_masks[n] = jnp.asarray(
                        np.concatenate([np.ones(n), np.zeros(D - n)]).astype(np.float32)
                    )

        def run(init):
            state = [init[p] for p in range(graph.width)]
            state = [task_no_deps(x) for x in state]  # t = 0
            zero = jnp.zeros_like(state[0])
            for t in range(1, graph.steps):
                nxt = []
                for p in range(graph.width):
                    deps = dep_ids[t][p]
                    if not deps:
                        nxt.append(task_no_deps(state[p]))
                        continue
                    stack = jnp.stack(
                        [state[d] for d in deps] + [zero] * (D - len(deps))
                    )
                    nxt.append(task_with_deps(stack, pad_masks[len(deps)]))
                state = nxt
            return jnp.stack(state)

        return run

    def dispatches_per_run(self, graph: TaskGraph) -> int:
        return graph.num_tasks
