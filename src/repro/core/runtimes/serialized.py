"""`serialized` runtime — one host dispatch per task.

Every task (t, p) is a separate jit call driven by a Python loop. This is the
maximal-overhead rung: it charges the full host->device dispatch latency to
every task, which is JAX's analogue of an AMT runtime's per-task spawn +
schedule cost (the quantity the paper isolates with fine-grain sweeps; cf.
HPX-local's threading-subsystem overhead, paper §3.3/§6.1).

At large grain the dispatch cost amortizes and this backend reaches the same
peak FLOP/s as `fused` (paper Fig 1a); at small grain its efficiency collapses
first, giving it the largest METG — exactly the Charm++/HPX-vs-MPI shape of
paper Table 2.

The task body jit is compiled ONCE per (deps, payload) shape and reused by all
T*W tasks, so what we time is dispatch, not compilation.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import GraphEnsemble, TaskGraph
from repro.core.runtimes.base import Runtime, register
from repro.core.task_kernels import apply_kernel


class _TaskDispatcher:
    """Per-graph dispatch machinery: jitted task bodies + host dep lists.

    The task body jit is compiled ONCE per (deps, payload) shape and reused
    by all T*W tasks, so what we time is dispatch, not compilation. One
    dispatcher per ensemble member keeps distinct kernels/payloads from
    sharing (and thus hiding) each other's compile cache.
    """

    def __init__(self, graph: TaskGraph, use_pallas: bool):
        spec = graph.kernel

        @partial(jax.jit, static_argnums=())
        def task_no_deps(x):  # (payload,)
            return apply_kernel(x, spec, use_pallas=use_pallas)

        @jax.jit
        def task_with_deps(deps, mask):  # (D, payload), (D,)
            w = mask[:, None]
            combined = (deps * w).sum(0) / jnp.maximum(mask.sum(), 1.0)
            return apply_kernel(combined, spec, use_pallas=use_pallas)

        self.graph = graph
        self.task_no_deps = task_no_deps
        self.task_with_deps = task_with_deps

        # Host-side dependency lists, precomputed (the "graph build" phase —
        # Task Bench likewise excludes graph construction from timing).
        dep_ids: List[List[tuple]] = []
        for t in range(graph.steps):
            dep_ids.append([graph.dependencies(t, p) for p in range(graph.width)])
        D = max(1, graph.max_deps)
        pad_masks = {}
        for t in range(graph.steps):
            for deps in dep_ids[t]:
                n = len(deps)
                if n and n not in pad_masks:
                    pad_masks[n] = jnp.asarray(
                        np.concatenate([np.ones(n), np.zeros(D - n)]).astype(np.float32)
                    )
        self.dep_ids = dep_ids
        self.pad = D
        self.pad_masks = pad_masks

    def initial(self, init: jax.Array) -> List[jax.Array]:
        return [self.task_no_deps(init[p]) for p in range(self.graph.width)]

    def advance(self, state: List[jax.Array], t: int) -> List[jax.Array]:
        """Dispatch every point of timestep t (one host dispatch per task)."""
        zero = jnp.zeros_like(state[0])
        nxt = []
        for p in range(self.graph.width):
            deps = self.dep_ids[t][p]
            if not deps:
                nxt.append(self.task_no_deps(state[p]))
                continue
            stack = jnp.stack(
                [state[d] for d in deps] + [zero] * (self.pad - len(deps))
            )
            nxt.append(self.task_with_deps(stack, self.pad_masks[len(deps)]))
        return nxt


@register
class SerializedRuntime(Runtime):
    name = "serialized"

    MAX_TASKS = 200_000  # refuse graphs whose python loop would take forever

    def supports(self, graph: TaskGraph):
        if graph.num_tasks > self.MAX_TASKS:
            return False, f"too many tasks for per-task dispatch ({graph.num_tasks})"
        if graph.pattern == "all_to_all" and graph.width > 1024:
            return False, "all_to_all fan-in too wide for per-task gather"
        return True, ""

    def supports_ensemble(self, ensemble: GraphEnsemble):
        ok, why = super().supports_ensemble(ensemble)
        if not ok:
            return ok, why
        if ensemble.num_tasks > self.MAX_TASKS:
            return False, (
                f"too many total tasks for per-task dispatch ({ensemble.num_tasks})"
            )
        return True, ""

    def build(self, graph: TaskGraph) -> Callable[[jax.Array], jax.Array]:
        use_pallas = bool(self.options.get("use_pallas", False))
        disp = _TaskDispatcher(graph, use_pallas)

        def run(init):
            state = disp.initial(init)
            for t in range(1, graph.steps):
                state = disp.advance(state, t)
            return jnp.stack(state)

        return run

    def build_ensemble(self, ensemble: GraphEnsemble) -> Callable:
        """Round-robin per timestep: member 0's tasks are dispatched, then
        member 1's, ... — the minimal-scheduling-freedom rung. Every task is
        still its own host dispatch and no program spans two tasks, so the
        compiler can never overlap members; only jax's async dispatch queue
        may pipeline adjacent task launches."""
        use_pallas = bool(self.options.get("use_pallas", False))
        dispatchers = [_TaskDispatcher(g, use_pallas) for g in ensemble.members]

        def run(inits):
            states = [d.initial(x) for d, x in zip(dispatchers, inits)]
            for t in range(1, ensemble.steps):
                # members past their own T are frozen: zero task dispatches
                states = [
                    d.advance(s, t) if t < d.graph.steps else s
                    for d, s in zip(dispatchers, states)
                ]
            return tuple(jnp.stack(s) for s in states)

        return run

    def dispatches_per_run(self, graph: TaskGraph) -> int:
        return graph.num_tasks

    def _build_traced(self, graph: TaskGraph) -> Callable:
        """Per-timestep spans (per-TASK spans would record W*T entries of
        pure recorder noise; the step span's ``tasks`` attr keeps the
        per-task dispatch count). The ``dispatch`` span covers the host
        loop issuing W task programs — the quantity this backend exists to
        maximize — and the ``compute.interior`` span the trailing drain of
        whatever the async queue still holds."""
        use_pallas = bool(self.options.get("use_pallas", False))
        disp = _TaskDispatcher(graph, use_pallas)
        tr = self.tracer
        W = graph.width

        def run(init):
            with tr.span("t0_dispatch", "dispatch", step=0, tasks=W):
                state = disp.initial(init)
            with tr.span("t0_compute", "compute.interior", step=0):
                state = jax.block_until_ready(state)
            for t in range(1, graph.steps):
                with tr.span("task_dispatch", "dispatch", step=t, tasks=W):
                    state = disp.advance(state, t)
                with tr.span("task_drain", "compute.interior", step=t):
                    state = jax.block_until_ready(state)
            return jnp.stack(state)

        return run
