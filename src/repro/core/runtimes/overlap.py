"""`overlap` runtime — overdecomposed, communication-hiding (Charm++/HPX analogue).

The AMT value proposition the paper studies (§6.2): give each core N > 1 tasks
so the runtime can execute ready tasks while messages for the others are in
flight. The TPU-native rendition:

  * each device owns B = width/devices points (B = the overdecomposition
    factor when width = N x devices);
  * per timestep, the halo ppermute for the boundary points is issued FIRST,
    then the B - 2r interior points (whose inputs are all local) are computed
    with no data dependence on the collective, then the boundary points
    consume the received halos.

XLA's latency-hiding scheduler can therefore place collective-permute-start
before the interior compute and -done after it — the DMA rides under the MXU
work exactly like a chare's entry method executing under an in-flight message.
The whole timestep loop lives in one lax.scan (AMTs have no per-step host
barrier), so dispatch overhead is ~zero and what remains is communication +
schedule quality: the quantity the paper's Fig 2 isolates.

Options (the Fig-3-style "build options" of this backend):
  overlap=False      compute boundary first (no latency hiding) — the
                     "simplified scheduling path" ablation.
  halo_via="allgather"  transport ablation: fetch the whole ring instead of
                     r-row halos (NIC-vs-SHMEM analogue; see DESIGN.md §2).
  unroll=k           scan unroll factor.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import patterns as _patterns
from repro.core.graph import GraphEnsemble, TaskGraph
from repro.core.runtimes import _halo
from repro.core.runtimes.base import register
from repro.core.runtimes.bsp import AXIS, _BspBase
from repro.core.task_kernels import apply_kernel


@register
class OverlapRuntime(_BspBase):
    name = "overlap"

    def supports(self, graph: TaskGraph):
        ok, why = super().supports(graph)
        if not ok:
            return ok, why
        pat = graph.pattern
        if pat not in _patterns.HALO_PATTERNS and pat != "random_nearest":
            return False, f"overlap models halo patterns; {pat} is not one"
        r = _patterns.halo_radius(graph)
        B = self._block(graph)
        if r > 0 and B < 2 * r:
            return False, (
                f"block {B} < 2*radius {r}: no interior to overlap "
                f"(increase overdecomposition)"
            )
        return True, ""

    def _make_overlap_step(self, graph: TaskGraph) -> Callable:
        """step(local) for one timestep of one graph, halo-first ordering."""
        use_pallas = bool(self.options.get("use_pallas", False))
        do_overlap = bool(self.options.get("overlap", True))
        halo_via = str(self.options.get("halo_via", "ppermute"))

        D = len(self.devices)
        B = self._block(graph)
        r = _patterns.halo_radius(graph)
        spec = graph.kernel
        combine = _halo.make_halo_combine(graph)

        def fetch_halos(local):
            if halo_via == "allgather":
                full = jax.lax.all_gather(local, AXIS, axis=0, tiled=True)  # (W,P)
                d = jax.lax.axis_index(AXIS)
                left = jax.lax.dynamic_slice_in_dim(
                    jnp.roll(full, r, axis=0), d * B, r, axis=0
                )
                right = jax.lax.dynamic_slice_in_dim(
                    jnp.roll(full, -B, axis=0), d * B, r, axis=0
                )
                return left, right
            return _halo.exchange_halos(local, r, D, AXIS)

        def step(local):  # (B, payload)
            d = jax.lax.axis_index(AXIS)
            p0 = d * B
            if r == 0:
                return apply_kernel(combine(local, B, p0), spec,
                                    use_pallas=use_pallas)

            recv_l, recv_r = fetch_halos(local)

            def interior():
                # rows r .. B-r-1; their full window lives in `local`
                x = combine(local, B - 2 * r, p0 + r)
                return apply_kernel(x, spec, use_pallas=use_pallas)

            def boundary(rl, rr):
                ctx_top = jnp.concatenate([rl, local[: 2 * r]], axis=0)
                ctx_bot = jnp.concatenate([local[B - 2 * r:], rr], axis=0)
                top = apply_kernel(combine(ctx_top, r, p0), spec,
                                   use_pallas=use_pallas)
                bot = apply_kernel(combine(ctx_bot, r, p0 + B - r), spec,
                                   use_pallas=use_pallas)
                return top, bot

            if do_overlap:
                # interior first: no data dependence on the collective, so the
                # scheduler may overlap the ppermute with this compute.
                mid = interior()
                top, bot = boundary(recv_l, recv_r)
            else:
                top, bot = boundary(recv_l, recv_r)
                mid = interior()
            return jnp.concatenate([top, mid, bot], axis=0)

        return step

    def build(self, graph: TaskGraph) -> Callable[[jax.Array], jax.Array]:
        use_pallas = bool(self.options.get("use_pallas", False))
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        spec = graph.kernel
        step = self._make_overlap_step(graph)

        def local_run(local):
            local = apply_kernel(local, spec, use_pallas=use_pallas)
            if graph.steps == 1:
                return local

            def body(state, _):
                return step(state), None

            local, _ = jax.lax.scan(
                body, local, None, length=graph.steps - 1, unroll=unroll
            )
            return local

        fn = jax.jit(self._shard_map(mesh, local_run))
        sharding = NamedSharding(mesh, P(AXIS))
        return lambda init: fn(jax.device_put(init, sharding))

    def build_ensemble(self, ensemble: GraphEnsemble) -> Callable:
        """The paper's §6.2 workload: K overdecomposed graphs in ONE jitted
        timestep loop. Every member's halo ppermute is issued inside the same
        traced step with no data dependence on the other members' interior
        compute, so XLA's latency-hiding scheduler can run graph A's interior
        under graph B's in-flight exchange — the chare-style "execute a ready
        task while messages are in flight" freedom Charm++/HPX exploit."""
        use_pallas = bool(self.options.get("use_pallas", False))
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        members = ensemble.members
        specs = [g.kernel for g in members]
        steps = ensemble.steps
        member_steps = [self._make_overlap_step(g) for g in members]

        def local_run(locals_):  # tuple of (B_k, payload_k) per device
            locals_ = tuple(
                apply_kernel(x, sp, use_pallas=use_pallas)
                for x, sp in zip(locals_, specs)
            )
            if ensemble.steps == 1:
                return locals_

            def body(states, t):
                nxt = []
                for g, st, s in zip(members, member_steps, states):
                    n = st(s)
                    if g.steps < steps:  # masked freeze past this member's T
                        n = jnp.where(t < g.steps, n, s)
                    nxt.append(n)
                return tuple(nxt), None

            locals_, _ = jax.lax.scan(
                body, locals_, jnp.arange(1, steps), unroll=unroll
            )
            return locals_

        fn = jax.jit(self._shard_map_tuple(mesh, local_run, len(members)))
        sharding = NamedSharding(mesh, P(AXIS))
        return lambda inits: fn(tuple(jax.device_put(x, sharding) for x in inits))

    def dispatches_per_run(self, graph: TaskGraph) -> int:
        return 1

    def ensemble_dispatches_per_run(self, ensemble: GraphEnsemble) -> int:
        return 1
