"""`bsp` runtime — bulk-synchronous shard_map (the MPI analogue).

Points are block-distributed over the device mesh. Every timestep is one
synchronous superstep: exchange (collective), then compute — exactly MPI's
send/recv + compute structure in the paper's Task Bench MPI backend.

Two dispatch models:
  bsp        one host dispatch per timestep (Python loop), charging per-step
             launch overhead like an MPI rank's per-iteration progress loop.
  bsp_scan   the whole timestep loop inside one jit (lax.scan + lax.switch
             over the pattern period) — the "perfectly amortized" MPI bound.

Collective selection per pattern class (see patterns.py):
  halo       ring ppermute of r edge rows each way
  butterfly  XOR block collective_permute (stride >= block) or local shuffle
  global     all_to_all -> psum-mean; spread -> all_gather + arithmetic gather
"""
from __future__ import annotations

from typing import Callable, List

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.compat import pcast_varying, shard_map
from repro.core import patterns as _patterns
from repro.core.graph import GraphEnsemble, TaskGraph
from repro.core.runtimes import _halo
from repro.core.runtimes.base import Runtime, register
from repro.core.task_kernels import apply_kernel

AXIS = "shard"


class _BspBase(Runtime):
    """Shared machinery for bsp / bsp_scan / overlap."""

    def _mesh(self) -> Mesh:
        return Mesh(np.array(self.devices), (AXIS,))

    def _block(self, graph: TaskGraph) -> int:
        return graph.width // len(self.devices)

    def supports(self, graph: TaskGraph):
        D = len(self.devices)
        if graph.width % D != 0:
            return False, f"width {graph.width} not divisible by {D} devices"
        B = graph.width // D
        pat = graph.pattern
        if pat in _patterns.HALO_PATTERNS or pat == "random_nearest":
            r = _patterns.halo_radius(graph)
            if r > B:
                return False, f"halo radius {r} exceeds block {B} (multi-hop needed)"
            return True, ""
        if pat in _patterns.BUTTERFLY_PATTERNS:
            if D & (D - 1):
                return False, "butterfly patterns need power-of-two device count"
            return True, ""
        if pat in ("all_to_all", "spread", "trivial"):
            return True, ""
        return False, f"pattern {pat} unsupported by {self.name}"

    # ---------------------------------------------------------- step bodies

    def _make_halo_step(self, graph: TaskGraph, use_pallas: bool) -> Callable:
        r = _patterns.halo_radius(graph)
        B = self._block(graph)
        D = len(self.devices)
        combine = _halo.make_halo_combine(graph)
        spec = graph.kernel

        def step(local):  # (B, payload)
            d = jax.lax.axis_index(AXIS)
            p0 = d * B
            if r == 0:
                x = combine(local, B, p0)
            else:
                recv_l, recv_r = _halo.exchange_halos(local, r, D, AXIS)
                ext = jnp.concatenate([recv_l, local, recv_r], axis=0)
                x = combine(ext, B, p0)
            return apply_kernel(x, spec, use_pallas=use_pallas)

        return step

    def _make_butterfly_steps(self, graph: TaskGraph, use_pallas: bool) -> List[Callable]:
        """One step body per period slot k (pairing distance 2^k_eff)."""
        W, D = graph.width, len(self.devices)
        B = W // D
        spec = graph.kernel

        def make(stride: int) -> Callable:
            def step(local):
                if stride < B:  # partner within block: local row shuffle
                    j = jnp.arange(B)
                    partner = local[j ^ stride]
                else:  # partner block: XOR collective permute
                    bs = stride // B
                    perm = [(d, d ^ bs) for d in range(D)]
                    partner = jax.lax.ppermute(local, AXIS, perm)
                x = (local + partner) * 0.5
                return apply_kernel(x, spec, use_pallas=use_pallas)

            return step

        return [make(s) for s in _patterns.butterfly_slot_strides(graph)]

    def _make_global_step(self, graph: TaskGraph, use_pallas: bool) -> Callable:
        W, D = graph.width, len(self.devices)
        B = W // D
        spec = graph.kernel
        if graph.pattern == "all_to_all":

            def step(local, t):
                mean = jax.lax.psum(local.sum(axis=0), AXIS) / W
                x = jnp.broadcast_to(mean[None, :], local.shape)
                # psum output is shard-invariant; re-mark as varying so scan
                # carries keep a consistent VMA type under shard_map.
                x = pcast_varying(x, AXIS)
                return apply_kernel(x, spec, use_pallas=use_pallas)

            return step

        if graph.pattern == "spread":
            stride = max(1, W // graph.fanout)

            def step(local, t):
                full = jax.lax.all_gather(local, AXIS, axis=0, tiled=True)  # (W, P)
                d = jax.lax.axis_index(AXIS)
                p = d * B + jnp.arange(B)
                ids = (p[:, None] + jnp.arange(graph.fanout)[None, :] * stride
                       + (t - 1)) % W  # (B, fanout)
                x = full[ids].mean(axis=1)
                return apply_kernel(x, spec, use_pallas=use_pallas)

            return step

        if graph.pattern == "trivial":

            def step(local, t):
                return apply_kernel(local, spec, use_pallas=use_pallas)

            return step

        raise ValueError(graph.pattern)

    def _make_member_step(self, graph: TaskGraph, use_pallas: bool) -> Callable:
        """Uniform step(local, t) for one graph, period branching included.

        This is the building block both the fused-loop ensembles (bsp_scan /
        overlap carry a tuple of these in one scan) and the single-graph
        scan body share.
        """
        pat = graph.pattern
        if pat in _patterns.HALO_PATTERNS or pat == "random_nearest":
            body = self._make_halo_step(graph, use_pallas)
            return lambda local, t: body(local)
        if pat in _patterns.BUTTERFLY_PATTERNS:
            bodies = self._make_butterfly_steps(graph, use_pallas)
            if len(bodies) == 1:
                return lambda local, t: bodies[0](local)
            period = graph.period

            def step(local, t):
                slot = jax.lax.rem(t - 1, period)
                return jax.lax.switch(
                    slot, [lambda s, b=b: b(s) for b in bodies], local
                )

            return step
        return self._make_global_step(graph, use_pallas)

    def _check_vma(self) -> bool:
        # pallas_call has no replication rule, so bodies that launch Pallas
        # kernels (use_pallas=True) must disable VMA/replication checking;
        # pure-jnp bodies keep the trace-time safety net.
        return not bool(self.options.get("use_pallas", False))

    def _shard_map(self, mesh: Mesh, fn: Callable, n_in: int = 1) -> Callable:
        return shard_map(
            fn,
            mesh=mesh,
            check_vma=self._check_vma(),
            in_specs=tuple([P(AXIS)] * n_in) if n_in > 1 else P(AXIS),
            out_specs=P(AXIS),
        )

    def _shard_map_tuple(self, mesh: Mesh, fn: Callable, k: int) -> Callable:
        """shard_map over a function taking/returning a K-tuple of states."""
        return shard_map(
            fn,
            mesh=mesh,
            check_vma=self._check_vma(),
            in_specs=(tuple([P(AXIS)] * k),),
            out_specs=tuple([P(AXIS)] * k),
        )


@register
class BspRuntime(_BspBase):
    name = "bsp"

    def _build_stepper(self, graph: TaskGraph):
        """(kernel_only, pick, sharding): the per-dispatch pieces of one graph."""
        use_pallas = bool(self.options.get("use_pallas", False))
        donate = bool(self.options.get("donate", True))
        mesh = self._mesh()
        spec = graph.kernel
        pat = graph.pattern

        kernel_only = self._shard_map(
            mesh, lambda local: apply_kernel(local, spec, use_pallas=use_pallas)
        )
        kernel_only = jax.jit(kernel_only, donate_argnums=(0,) if donate else ())

        if pat in _patterns.HALO_PATTERNS or pat == "random_nearest":
            body = self._make_halo_step(graph, use_pallas)
            steps = [jax.jit(self._shard_map(mesh, body),
                             donate_argnums=(0,) if donate else ())]
            pick = lambda t: steps[0]
        elif pat in _patterns.BUTTERFLY_PATTERNS:
            bodies = self._make_butterfly_steps(graph, use_pallas)
            steps = [jax.jit(self._shard_map(mesh, b),
                             donate_argnums=(0,) if donate else ())
                     for b in bodies]
            period = graph.period
            pick = lambda t: steps[(t - 1) % period]
        else:  # global patterns take (local, t): t rides in replicated
            body = self._make_global_step(graph, use_pallas)
            stepped = jax.jit(
                shard_map(
                    body, mesh=mesh, check_vma=self._check_vma(),
                    in_specs=(P(AXIS), P()), out_specs=P(AXIS)
                ),
                donate_argnums=(0,) if donate else (),
            )

            def pick(t):
                return lambda s: stepped(s, jnp.int32(t))

        return kernel_only, pick, NamedSharding(mesh, P(AXIS))

    def build(self, graph: TaskGraph) -> Callable[[jax.Array], jax.Array]:
        kernel_only, pick, sharding = self._build_stepper(graph)

        def run(init):
            state = kernel_only(jax.device_put(init, sharding))
            for t in range(1, graph.steps):
                state = pick(t)(state)
            return state

        return run

    def build_ensemble(self, ensemble: GraphEnsemble) -> Callable:
        """Round-robin host dispatch: per timestep, one dispatch per member,
        in member order. Models an MPI-style runtime: each member superstep
        is its own program, so no compiler may interleave one member's
        compute with another's exchange, and every superstep pays its own
        dispatch. (jax's async device queue may still pipeline adjacent
        dispatches; the denied freedom is compiler-level scheduling, which
        is what separates this rung from bsp_scan/overlap.)"""
        parts = [self._build_stepper(g) for g in ensemble.members]

        def run(inits):
            states = [
                ko(jax.device_put(x, sh))
                for (ko, _, sh), x in zip(parts, inits)
            ]
            for t in range(1, ensemble.steps):
                # members past their own T are frozen: no dispatch at all
                # (the host analogue of the fused backends' masked freeze)
                states = [
                    pick(t)(s) if t < g.steps else s
                    for (_, pick, _), s, g in zip(parts, states, ensemble.members)
                ]
            return tuple(states)

        return run

    def dispatches_per_run(self, graph: TaskGraph) -> int:
        return graph.steps

    def _build_traced(self, graph: TaskGraph) -> Callable:
        """Per-superstep spans: ``dispatch`` is the host call issuing the
        step program, ``compute.interior`` the wait for it to finish (the
        traced run blocks per step to obtain real intervals; the timed
        path keeps its async queue). The halo/stride collective runs
        INSIDE each superstep's program — MPI's exchange+compute rung is
        one dispatch by construction — so its wall lands in the compute
        span; per-transport attribution belongs to pallas_step's traced
        paths."""
        kernel_only, pick, sharding = self._build_stepper(graph)
        tr = self.tracer

        def run(init):
            with tr.span("t0_dispatch", "dispatch", step=0):
                state = kernel_only(jax.device_put(init, sharding))
            with tr.span("t0_compute", "compute.interior", step=0):
                state = jax.block_until_ready(state)
            for t in range(1, graph.steps):
                f = pick(t)
                with tr.span("superstep_dispatch", "dispatch", step=t):
                    state = f(state)
                with tr.span("superstep", "compute.interior", step=t,
                             pattern=graph.pattern):
                    state = jax.block_until_ready(state)
            return state

        return run


@register
class BspScanRuntime(_BspBase):
    """BSP with the timestep loop fused into the jit (amortized dispatch)."""

    name = "bsp_scan"

    def build(self, graph: TaskGraph) -> Callable[[jax.Array], jax.Array]:
        use_pallas = bool(self.options.get("use_pallas", False))
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        spec = graph.kernel
        step = self._make_member_step(graph, use_pallas)

        def local_run(local):  # (B, payload) per device
            local = apply_kernel(local, spec, use_pallas=use_pallas)
            if graph.steps == 1:
                return local

            def scan_body(state, t):
                return step(state, t), None

            local, _ = jax.lax.scan(
                scan_body, local, jnp.arange(1, graph.steps), unroll=unroll
            )
            return local

        fn = jax.jit(self._shard_map(mesh, local_run))
        sharding = NamedSharding(mesh, P(AXIS))
        return lambda init: fn(jax.device_put(init, sharding))

    def build_ensemble(self, ensemble: GraphEnsemble) -> Callable:
        """All members advance inside ONE jitted scan (tuple carry): a
        single host dispatch runs the whole ensemble, and XLA may interleave
        member supersteps — the amortized-dispatch MPI bound with full
        cross-member freedom."""
        use_pallas = bool(self.options.get("use_pallas", False))
        unroll = int(self.options.get("unroll", 1))
        mesh = self._mesh()
        members = ensemble.members
        specs = [g.kernel for g in members]
        steps = ensemble.steps
        member_steps = [self._make_member_step(g, use_pallas) for g in members]

        def local_run(locals_):  # tuple of (B_k, payload_k) per device
            locals_ = tuple(
                apply_kernel(x, sp, use_pallas=use_pallas)
                for x, sp in zip(locals_, specs)
            )
            if ensemble.steps == 1:
                return locals_

            def scan_body(states, t):
                nxt = []
                for g, st, s in zip(members, member_steps, states):
                    n = st(s, t)
                    if g.steps < steps:  # masked freeze past this member's T
                        n = jnp.where(t < g.steps, n, s)
                    nxt.append(n)
                return tuple(nxt), None

            locals_, _ = jax.lax.scan(
                scan_body, locals_, jnp.arange(1, ensemble.steps), unroll=unroll
            )
            return locals_

        fn = jax.jit(self._shard_map_tuple(mesh, local_run, len(members)))
        sharding = NamedSharding(mesh, P(AXIS))
        return lambda inits: fn(tuple(jax.device_put(x, sharding) for x in inits))

    def dispatches_per_run(self, graph: TaskGraph) -> int:
        return 1

    def ensemble_dispatches_per_run(self, ensemble: GraphEnsemble) -> int:
        return 1
