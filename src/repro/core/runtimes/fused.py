"""`fused` runtime — whole-graph single jit (the OpenMP/static analogue).

The entire T-step graph lowers into one XLA program: a lax.scan over
timesteps whose body gathers dependencies and applies the task kernel,
vectorized over all W points. There is exactly ONE host dispatch per graph
execution, so this backend's METG floor is set purely by XLA's fused compute
throughput — the "zero runtime overhead" rung of the ladder, like the paper's
best shared-memory configuration at coarse grain.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.graph import GraphEnsemble, TaskGraph
from repro.core.runtimes.base import Runtime, register
from repro.core.task_kernels import (
    apply_kernel,
    combine_all_to_all,
    combine_dependencies,
)

#: refuse dependency-array materializations beyond this many cells
_MAX_DEP_CELLS = 64 << 20


@register
class FusedRuntime(Runtime):
    name = "fused"

    def supports(self, graph: TaskGraph):
        if graph.pattern == "all_to_all":
            return True, ""
        # (period, W, max_deps) index arrays; refuse absurd materializations.
        cells = graph.period * graph.width * graph.max_deps
        if cells > _MAX_DEP_CELLS:
            return False, f"dependency array too large ({cells} cells)"
        return True, ""

    @staticmethod
    def _make_combine(graph: TaskGraph) -> Callable:
        """combine(state, t) -> per-point kernel inputs for timestep t."""
        if graph.pattern == "all_to_all":
            return lambda state, t: combine_all_to_all(state)
        idx_np, mask_np = graph.dependency_arrays()
        idx = jnp.asarray(idx_np)
        mask = jnp.asarray(mask_np)
        period = graph.period

        def combine(state, t):
            s = jax.lax.rem(t - 1, period)
            i = jax.lax.dynamic_index_in_dim(idx, s, 0, keepdims=False)
            m = jax.lax.dynamic_index_in_dim(mask, s, 0, keepdims=False)
            return combine_dependencies(state, i, m)

        return combine

    def build(self, graph: TaskGraph) -> Callable[[jax.Array], jax.Array]:
        spec = graph.kernel
        use_pallas = bool(self.options.get("use_pallas", False))
        unroll = int(self.options.get("unroll", 1))
        combine = self._make_combine(graph)

        def step(state, t):
            x = combine(state, t)
            return apply_kernel(x, spec, use_pallas=use_pallas), None

        @jax.jit
        def run(init):
            state = apply_kernel(init, spec, use_pallas=use_pallas)  # t=0 tasks
            if graph.steps == 1:
                return state
            state, _ = jax.lax.scan(
                step, state, jnp.arange(1, graph.steps), unroll=unroll
            )
            return state

        return run

    # ------------------------------------------------------------- ensembles

    def build_ensemble(self, ensemble: GraphEnsemble) -> Callable:
        """All K member graphs inside ONE jitted timestep loop.

        Stackable ensembles (uniform width/payload) share a (K, W, payload)
        state tensor and the padded (K, Pmax, W, Dmax) dependency arrays, so
        each timestep is one vmapped gather/combine over all members — XLA
        sees a single dataflow and interleaves members at will. Heterogeneous
        ensembles fall back to a tuple-of-states scan carry with per-member
        combine closures; still one program, same scheduling freedom.

        Members with different ``steps`` are frozen by masking: the lockstep
        loop runs max(T_k) iterations and a member past its own T carries its
        final state through ``jnp.where`` unchanged (no further tasks).
        """
        use_pallas = bool(self.options.get("use_pallas", False))
        unroll = int(self.options.get("unroll", 1))
        members = ensemble.members
        specs = [g.kernel for g in members]
        steps = ensemble.steps

        stacked = (
            ensemble.stackable
            and len(members)
            * max(g.period for g in members)
            * members[0].width
            * max(g.max_deps for g in members)
            <= _MAX_DEP_CELLS
        )

        hetero = ensemble.heterogeneous_steps
        msteps = jnp.asarray(ensemble.member_steps, jnp.int32)

        if stacked:
            idx_np, mask_np, periods_np = ensemble.dependency_arrays()
            idx = jnp.asarray(idx_np)
            mask = jnp.asarray(mask_np)
            periods = jnp.asarray(periods_np)
            take = jax.vmap(
                lambda a, s: jax.lax.dynamic_index_in_dim(a, s, 0, keepdims=False)
            )

            def apply_all(x):  # (K, W, payload)
                if len(set(specs)) == 1:
                    return apply_kernel(x, specs[0], use_pallas=use_pallas)
                return jnp.stack(
                    [
                        apply_kernel(x[k], sp, use_pallas=use_pallas)
                        for k, sp in enumerate(specs)
                    ]
                )

            def step(state, t):
                s = jax.lax.rem(t - 1, periods)  # (K,) per-member slot
                x = jax.vmap(combine_dependencies)(state, take(idx, s), take(mask, s))
                nxt = apply_all(x)
                if hetero:  # freeze members whose own T is exhausted
                    nxt = jnp.where((t < msteps)[:, None, None], nxt, state)
                return nxt, None

            @jax.jit
            def run(inits):
                state = apply_all(jnp.stack(inits))
                if steps > 1:
                    state, _ = jax.lax.scan(
                        step, state, jnp.arange(1, steps), unroll=unroll
                    )
                return tuple(state[k] for k in range(len(members)))

            return run

        combines = [self._make_combine(g) for g in members]

        def step(states, t):
            nxt = []
            for g, s, c, sp in zip(members, states, combines, specs):
                n = apply_kernel(c(s, t), sp, use_pallas=use_pallas)
                if g.steps < steps:  # freeze once this member's T is done
                    n = jnp.where(t < g.steps, n, s)
                nxt.append(n)
            return tuple(nxt), None

        @jax.jit
        def run(inits):
            states = tuple(
                apply_kernel(x, sp, use_pallas=use_pallas)
                for x, sp in zip(inits, specs)
            )
            if steps > 1:
                states, _ = jax.lax.scan(
                    step, states, jnp.arange(1, steps), unroll=unroll
                )
            return states

        return run

    def dispatches_per_run(self, graph: TaskGraph) -> int:
        return 1

    def ensemble_dispatches_per_run(self, ensemble: GraphEnsemble) -> int:
        return 1
