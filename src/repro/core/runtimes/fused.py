"""`fused` runtime — whole-graph single jit (the OpenMP/static analogue).

The entire T-step graph lowers into one XLA program: a lax.scan over
timesteps whose body gathers dependencies and applies the task kernel,
vectorized over all W points. There is exactly ONE host dispatch per graph
execution, so this backend's METG floor is set purely by XLA's fused compute
throughput — the "zero runtime overhead" rung of the ladder, like the paper's
best shared-memory configuration at coarse grain.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.graph import TaskGraph
from repro.core.runtimes.base import Runtime, register
from repro.core.task_kernels import (
    apply_kernel,
    combine_all_to_all,
    combine_dependencies,
)


@register
class FusedRuntime(Runtime):
    name = "fused"

    def supports(self, graph: TaskGraph):
        if graph.pattern == "all_to_all":
            return True, ""
        # (period, W, max_deps) index arrays; refuse absurd materializations.
        cells = graph.period * graph.width * graph.max_deps
        if cells > 64 << 20:
            return False, f"dependency array too large ({cells} cells)"
        return True, ""

    def build(self, graph: TaskGraph) -> Callable[[jax.Array], jax.Array]:
        spec = graph.kernel
        use_pallas = bool(self.options.get("use_pallas", False))
        unroll = int(self.options.get("unroll", 1))

        if graph.pattern == "all_to_all":
            combine = lambda state, t: combine_all_to_all(state)
        else:
            idx_np, mask_np = graph.dependency_arrays()
            idx = jnp.asarray(idx_np)
            mask = jnp.asarray(mask_np)
            period = graph.period

            def combine(state, t):
                s = jax.lax.rem(t - 1, period)
                i = jax.lax.dynamic_index_in_dim(idx, s, 0, keepdims=False)
                m = jax.lax.dynamic_index_in_dim(mask, s, 0, keepdims=False)
                return combine_dependencies(state, i, m)

        def step(state, t):
            x = combine(state, t)
            return apply_kernel(x, spec, use_pallas=use_pallas), None

        @jax.jit
        def run(init):
            state = apply_kernel(init, spec, use_pallas=use_pallas)  # t=0 tasks
            if graph.steps == 1:
                return state
            state, _ = jax.lax.scan(
                step, state, jnp.arange(1, graph.steps), unroll=unroll
            )
            return state

        return run

    def dispatches_per_run(self, graph: TaskGraph) -> int:
        return 1
