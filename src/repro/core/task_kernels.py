"""Grain-size-parameterized task bodies.

The paper's compute kernel executes ``iterations`` fused-multiply-adds per
element ("the time for each vertex to execute such a kernel with a grain size
of one is 2.5 ns" — paper §6.1). We reproduce that exactly: the task body is an
iterated elementwise FMA over the point's payload vector, so

    FLOPs(task) = 2 * payload * iterations        (compute_bound)

``memory_bound`` sweeps a scratch buffer instead (bytes-dominated), and
``empty`` is a no-op body used to measure pure runtime overhead.

The *reference* implementation here is pure jnp (this module). The TPU
hot-spot implementation is ``repro.kernels.taskbench_compute`` (Pallas,
VMEM-tiled); runtimes select it with ``use_pallas=True`` and tests assert
allclose between the two across shapes/dtypes.

Numerical design: the FMA uses a contraction map x <- a*x + b with |a| < 1 so
arbitrarily many iterations stay bounded (no inf/nan at any grain size) while
remaining un-DCE-able (result depends on every iteration and on the combined
dependency inputs).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

# Contraction constants: x converges towards B/(1-A) = 0.1/0.5 without ever
# being constant-foldable (A, B are runtime scalars broadcast in).
FMA_A = 0.5
FMA_B = 0.1

KINDS = ("compute_bound", "memory_bound", "empty")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Task body spec. ``iterations`` is the grain-size knob."""

    kind: str = "compute_bound"
    iterations: int = 16
    scratch: int = 2048  # floats; memory_bound working set per point

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown kernel kind {self.kind!r}; known {KINDS}")
        if self.iterations < 0:
            raise ValueError("iterations must be >= 0")

    def flops(self, payload: int) -> int:
        if self.kind == "compute_bound":
            return 2 * payload * self.iterations
        if self.kind == "memory_bound":
            return self.scratch * self.iterations  # 1 add per touched element
        return 0

    def bytes(self, payload: int) -> int:
        if self.kind == "compute_bound":
            return 4 * payload * 2  # read + write once; iterations live in reg
        if self.kind == "memory_bound":
            return 4 * self.scratch * 2 * self.iterations
        return 0

    def grain_duration_estimate(self, payload: int, flops_per_s: float) -> float:
        """Seconds per task at a given sustained FLOP rate (napkin math)."""
        return self.flops(payload) / max(flops_per_s, 1.0)


# --------------------------------------------------------------------------
# Reference (pure-jnp) task bodies. All operate on x: (..., payload) f32.
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnums=(1,))
def _compute_bound_jit(x: jax.Array, iterations: int) -> jax.Array:
    return compute_bound_body(x, iterations)


def compute_bound_body(x: jax.Array, iterations: int) -> jax.Array:
    """Iterated FMA: x <- A*x + B, ``iterations`` times (trace-time loop-free)."""
    a = jnp.asarray(FMA_A, x.dtype)
    b = jnp.asarray(FMA_B, x.dtype)

    def body(_, v):
        return a * v + b

    return jax.lax.fori_loop(0, iterations, body, x)


def memory_bound_body(x: jax.Array, iterations: int, scratch: int) -> jax.Array:
    """Bytes-dominated body: stream a scratch buffer ``iterations`` times.

    Each point expands its payload into a (scratch,) working set, sweeps it
    (read-modify-write) per iteration, then reduces back to payload size.
    """
    lead = x.shape[:-1]
    payload = x.shape[-1]
    reps = -(-scratch // payload)  # ceil
    buf = jnp.tile(x, lead and (1,) * len(lead) + (reps,) or (reps,))[..., :scratch]

    def body(i, b):
        # rotate + add: forces a full read and write of the buffer
        return jnp.roll(b, 1, axis=-1) + jnp.asarray(1e-6, b.dtype)

    buf = jax.lax.fori_loop(0, iterations, body, buf)
    # reduce back to payload: mean over the scratch window per payload slot
    pad = reps * payload - scratch
    buf = jnp.concatenate([buf, jnp.zeros(lead + (pad,), buf.dtype)], axis=-1)
    return buf.reshape(lead + (reps, payload)).mean(axis=-2)


def apply_kernel(
    x: jax.Array, spec: KernelSpec, *, use_pallas: bool = False
) -> jax.Array:
    """Apply the task body to a batch of point states x: (..., payload)."""
    if spec.kind == "empty" or spec.iterations == 0:
        return x
    if spec.kind == "compute_bound":
        if use_pallas:
            from repro.kernels import ops as _kops

            return _kops.taskbench_compute(x, spec.iterations)
        return compute_bound_body(x, spec.iterations)
    if spec.kind == "memory_bound":
        return memory_bound_body(x, spec.iterations, spec.scratch)
    raise ValueError(spec.kind)


def combine_dependencies(
    outputs: jax.Array, idx: jax.Array, mask: jax.Array
) -> jax.Array:
    """Gather + reduce dependency outputs into per-point kernel inputs.

    Args:
      outputs: (W, payload) previous-step point outputs.
      idx:     (W, D) int32 dependency indices (padded).
      mask:    (W, D) f32 1/0 liveness.

    Returns:
      (W, payload): mean over live deps of their outputs; points with zero
      deps (trivial pattern / masked rows) keep their own previous output.
    """
    gathered = outputs[idx]  # (W, D, payload)
    w = mask[..., None]
    denom = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)[..., None]
    combined = (gathered * w).sum(axis=1) / denom[:, 0]
    has_deps = (mask.sum(-1) > 0)[:, None]
    return jnp.where(has_deps, combined, outputs)


def combine_all_to_all(outputs: jax.Array) -> jax.Array:
    """Specialized combine for the all_to_all pattern: mean over all points.

    Avoids materializing the (W, W) index array for wide graphs.
    """
    mean = outputs.mean(axis=0, keepdims=True)
    return jnp.broadcast_to(mean, outputs.shape)


def initial_state(width: int, payload: int, seed: int = 0) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    return jax.random.uniform(key, (width, payload), jnp.float32, 0.1, 1.0)
