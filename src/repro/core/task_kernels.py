"""Grain-size-parameterized task bodies.

The paper's compute kernel executes ``iterations`` fused-multiply-adds per
element ("the time for each vertex to execute such a kernel with a grain size
of one is 2.5 ns" — paper §6.1). We reproduce that exactly: the task body is an
iterated elementwise FMA over the point's payload vector, so

    FLOPs(task) = 2 * payload * iterations        (compute_bound)

``memory_bound`` sweeps a scratch buffer instead (bytes-dominated), and
``empty`` is a no-op body used to measure pure runtime overhead.

The *reference* implementation here is pure jnp (this module). The TPU
hot-spot implementations live in ``repro.kernels`` (Pallas, VMEM-tiled):
``taskbench_compute`` (FMA body), ``bodies.memory_bound_pallas`` (scratch
sweep), and the fused-timestep megakernel ``taskbench_step`` that executes
gather + combine + body in ONE launch. Runtimes select the per-body kernels
with ``use_pallas=True`` via the ``_BODY_DISPATCH`` table below; the
``pallas_step`` backend uses the megakernel directly. Tests assert allclose
between Pallas and reference across shapes/dtypes.

Numerical design: the FMA uses a contraction map x <- a*x + b with |a| < 1 so
arbitrarily many iterations stay bounded (no inf/nan at any grain size) while
remaining un-DCE-able (result depends on every iteration and on the combined
dependency inputs).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

# The body math itself lives in repro.kernels.bodies (dependency-free) so the
# reference path, the standalone Pallas kernels, and the fused-timestep
# megakernel all trace the identical op sequence. Re-exported here for
# backward compatibility.
from repro.kernels.bodies import (  # noqa: F401
    FMA_A,
    FMA_B,
    fma_body as _fma_body,
    memory_sweep_body as _memory_sweep_body,
)

KINDS = ("compute_bound", "memory_bound", "empty")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Task body spec. ``iterations`` is the grain-size knob."""

    kind: str = "compute_bound"
    iterations: int = 16
    scratch: int = 2048  # floats; memory_bound working set per point

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown kernel kind {self.kind!r}; known {KINDS}")
        if self.iterations < 0:
            raise ValueError("iterations must be >= 0")

    def flops(self, payload: int) -> int:
        if self.kind == "compute_bound":
            return 2 * payload * self.iterations
        if self.kind == "memory_bound":
            return self.scratch * self.iterations  # 1 add per touched element
        return 0

    def bytes(self, payload: int) -> int:
        if self.kind == "compute_bound":
            return 4 * payload * 2  # read + write once; iterations live in reg
        if self.kind == "memory_bound":
            return 4 * self.scratch * 2 * self.iterations
        return 0

    def grain_duration_estimate(self, payload: int, flops_per_s: float) -> float:
        """Seconds per task at a given sustained FLOP rate (napkin math)."""
        return self.flops(payload) / max(flops_per_s, 1.0)


# --------------------------------------------------------------------------
# Reference (pure-jnp) task bodies. All operate on x: (..., payload) f32.
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnums=(1,))
def _compute_bound_jit(x: jax.Array, iterations: int) -> jax.Array:
    return compute_bound_body(x, iterations)


def compute_bound_body(x: jax.Array, iterations: int) -> jax.Array:
    """Iterated FMA: x <- A*x + B, ``iterations`` times (trace-time loop-free)."""
    return _fma_body(x, iterations)


def memory_bound_body(x: jax.Array, iterations: int, scratch: int) -> jax.Array:
    """Bytes-dominated body: stream a scratch buffer ``iterations`` times.

    Each point expands its payload into a (scratch,) working set, sweeps it
    (read-modify-write) per iteration, then reduces back to payload size.
    """
    return _memory_sweep_body(x, iterations, scratch)


def _compute_bound_pallas(x: jax.Array, spec: "KernelSpec") -> jax.Array:
    from repro.kernels import ops as _kops

    return _kops.taskbench_compute(x, spec.iterations)


def _memory_bound_pallas(x: jax.Array, spec: "KernelSpec") -> jax.Array:
    from repro.kernels import ops as _kops

    return _kops.taskbench_memory(x, spec.iterations, spec.scratch)


#: (kind, use_pallas) -> body; the single dispatch point for every runtime
#: backend (no per-callsite if-chains; pallas_step bypasses this with the
#: fused-timestep megakernel, which shares the same bodies module).
_BODY_DISPATCH = {
    ("compute_bound", False): lambda x, spec: compute_bound_body(x, spec.iterations),
    ("compute_bound", True): _compute_bound_pallas,
    ("memory_bound", False): lambda x, spec: memory_bound_body(
        x, spec.iterations, spec.scratch
    ),
    ("memory_bound", True): _memory_bound_pallas,
    ("empty", False): lambda x, spec: x,
    ("empty", True): lambda x, spec: x,
}


def apply_kernel(
    x: jax.Array, spec: KernelSpec, *, use_pallas: bool = False
) -> jax.Array:
    """Apply the task body to a batch of point states x: (..., payload)."""
    if spec.kind == "empty" or spec.iterations == 0:
        return x
    try:
        body = _BODY_DISPATCH[(spec.kind, bool(use_pallas))]
    except KeyError:
        raise ValueError(spec.kind) from None
    return body(x, spec)


def combine_dependencies(
    outputs: jax.Array, idx: jax.Array, mask: jax.Array
) -> jax.Array:
    """Gather + reduce dependency outputs into per-point kernel inputs.

    Args:
      outputs: (W, payload) previous-step point outputs.
      idx:     (W, D) int32 dependency indices (padded).
      mask:    (W, D) f32 1/0 liveness.

    Returns:
      (W, payload): mean over live deps of their outputs; points with zero
      deps (trivial pattern / masked rows) keep their own previous output.
    """
    gathered = outputs[idx]  # (W, D, payload)
    w = mask[..., None]
    denom = jnp.maximum(mask.sum(-1, keepdims=True), 1.0)[..., None]
    combined = (gathered * w).sum(axis=1) / denom[:, 0]
    has_deps = (mask.sum(-1) > 0)[:, None]
    return jnp.where(has_deps, combined, outputs)


def combine_all_to_all(outputs: jax.Array) -> jax.Array:
    """Specialized combine for the all_to_all pattern: mean over all points.

    Avoids materializing the (W, W) index array for wide graphs.
    """
    mean = outputs.mean(axis=0, keepdims=True)
    return jnp.broadcast_to(mean, outputs.shape)


def initial_state(width: int, payload: int, seed: int = 0) -> jax.Array:
    key = jax.random.PRNGKey(seed)
    return jax.random.uniform(key, (width, payload), jnp.float32, 0.1, 1.0)
