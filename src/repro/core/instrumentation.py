"""Runtime-overhead instrumentation for production loops.

This is the paper's methodology applied to the framework itself: a training
or serving loop is a task graph whose per-step "tasks" are the model steps,
and the quantity of interest is how much of the wall clock the *runtime*
(dispatch, data feed, collective schedule) adds on top of pure compute.

``OverheadProfiler`` wraps any step callable and reports:
  * per-step wall times and effective task granularity
    (wall x devices / tasks — Task Bench's granularity formula),
  * dispatch overhead (measured with an empty jitted step),
  * step-METG: the smallest per-step useful work that would keep the fleet
    >= 50% efficient given the measured overhead — the paper's METG applied
    to the production loop,
  * token throughput (``tokens_per_step``; the serving loop's currency),
  * per-category wall fractions when a span ``tracer`` is attached
    (repro.obs) — the decomposed view of the same wall the records sum.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.metg import DEFAULT_THRESHOLD


@functools.lru_cache(maxsize=None)
def measure_dispatch_overhead(reps: int = 50) -> float:
    """Seconds of host->device dispatch latency for a trivial jitted op.

    Memoized at module level (per ``reps``): the probe costs ~50 dispatches
    plus a compile, and every profiler in a process is asking the same
    question about the same device queue — examples/overhead_audit.py alone
    used to pay it three times per run. ``measure_dispatch_overhead.cache_clear()``
    re-arms it (e.g. after switching JAX platforms in a test)."""
    f = jax.jit(lambda x: x + 1.0)
    x = jnp.zeros(())
    jax.block_until_ready(f(x))
    t0 = time.perf_counter()
    for _ in range(reps):
        x = f(x)
    jax.block_until_ready(x)
    return (time.perf_counter() - t0) / reps


@dataclasses.dataclass
class StepRecord:
    step: int
    wall: float
    tokens: int = 0
    flops: float = 0.0


@dataclasses.dataclass
class OverheadReport:
    steps: int
    mean_wall: float
    p50_wall: float
    best_wall: float
    dispatch_overhead: float
    overhead_fraction: float  # dispatch / mean_wall
    granularity_us: float  # wall x devices / tasks_per_step
    step_metg_us: Optional[float]
    sustained_flops_per_s: float
    tokens_per_s: float = 0.0
    #: category -> fraction of traced wall (only when a tracer is attached)
    category_fractions: Optional[Dict[str, float]] = None
    #: steps whose wall blew a deadline (repro.resilience detector)
    flagged_steps: int = 0
    #: steps whose output failed a health check (NaN logits etc.)
    poisoned_steps: int = 0

    def lines(self) -> List[str]:
        out = [
            f"steps measured        : {self.steps}",
            f"mean / p50 / best wall: {self.mean_wall * 1e3:.3f} / "
            f"{self.p50_wall * 1e3:.3f} / {self.best_wall * 1e3:.3f} ms",
            f"dispatch overhead     : {self.dispatch_overhead * 1e6:.1f} us "
            f"({self.overhead_fraction * 100:.2f}% of step)",
            f"effective granularity : {self.granularity_us:.1f} us",
            f"sustained FLOP/s      : {self.sustained_flops_per_s / 1e9:.3f} G",
        ]
        if self.tokens_per_s > 0:
            out.append(f"tokens/s              : {self.tokens_per_s:.1f}")
        if self.step_metg_us is not None:
            out.append(f"step-METG(50%)        : {self.step_metg_us:.1f} us")
        if self.category_fractions:
            cats = "  ".join(
                f"{k}={v * 100:.1f}%"
                for k, v in sorted(self.category_fractions.items()) if v > 0)
            out.append(f"wall by category      : {cats}")
        if self.flagged_steps or self.poisoned_steps:
            out.append(f"faulted steps         : "
                       f"{self.flagged_steps} past deadline, "
                       f"{self.poisoned_steps} poisoned")
        return out


class OverheadProfiler:
    """Wraps a step function; records walls; derives overhead metrics."""

    def __init__(
        self,
        devices: int = 1,
        tasks_per_step: int = 1,
        flops_per_step: float = 0.0,
        tokens_per_step: int = 0,
        threshold: float = DEFAULT_THRESHOLD,
        tracer=None,
    ):
        self.devices = max(devices, 1)
        self.tasks_per_step = max(tasks_per_step, 1)
        self.flops_per_step = flops_per_step
        self.tokens_per_step = max(tokens_per_step, 0)
        self.threshold = threshold
        self.records: List[StepRecord] = []
        #: optional span recorder (repro.obs.Tracer); when attached, the
        #: report carries the per-category decomposition of the same wall
        self.tracer = tracer
        self._dispatch: Optional[float] = None
        #: step indices flagged by a deadline detector / health check
        #: (serve.py feeds these; the report carries the counts)
        self.flagged: List[int] = []
        self.poisoned: List[int] = []

    def wrap(self, step_fn: Callable) -> Callable:
        def timed(*args, **kwargs):
            t0 = time.perf_counter()
            out = step_fn(*args, **kwargs)
            out = jax.block_until_ready(out)
            self.record(time.perf_counter() - t0)
            return out

        return timed

    def record(self, wall: float, tokens: Optional[int] = None) -> None:
        self.records.append(
            StepRecord(
                len(self.records), wall,
                tokens=self.tokens_per_step if tokens is None else tokens,
                flops=self.flops_per_step,
            )
        )

    @property
    def dispatch_overhead(self) -> float:
        if self._dispatch is None:
            self._dispatch = measure_dispatch_overhead()
        return self._dispatch

    def _category_fractions(self) -> Optional[Dict[str, float]]:
        if self.tracer is None or not getattr(self.tracer, "spans", None):
            return None
        from repro.obs import summarize

        return summarize(self.tracer.spans)["fractions"]

    def report(self, skip_warmup: int = 1) -> OverheadReport:
        recs = self.records[skip_warmup:] or self.records
        if not recs:
            raise ValueError("no steps recorded")
        walls = sorted(r.wall for r in recs)
        mean = sum(walls) / len(walls)
        p50 = walls[len(walls) // 2]
        best = walls[0]
        disp = self.dispatch_overhead
        gran_us = mean * self.devices / self.tasks_per_step * 1e6

        # step-METG: per-step useful compute time c such that
        # c / (c + overhead) = threshold  =>  c = overhead * th / (1 - th);
        # expressed as granularity (per device) in microseconds.
        th = self.threshold
        metg_us = (disp * th / (1.0 - th)) / self.tasks_per_step * 1e6 \
            if th < 1.0 else None

        flops = self.flops_per_step / mean if mean > 0 else 0.0
        total_wall = sum(r.wall for r in recs)
        total_tokens = sum(r.tokens for r in recs)
        tps = total_tokens / total_wall if total_wall > 0 else 0.0
        return OverheadReport(
            steps=len(recs),
            mean_wall=mean,
            p50_wall=p50,
            best_wall=best,
            dispatch_overhead=disp,
            overhead_fraction=min(disp / mean, 1.0) if mean > 0 else 0.0,
            granularity_us=gran_us,
            step_metg_us=metg_us,
            sustained_flops_per_s=flops,
            tokens_per_s=tps,
            category_fractions=self._category_fractions(),
            flagged_steps=len(self.flagged),
            poisoned_steps=len(self.poisoned),
        )
