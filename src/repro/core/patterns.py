"""Dependence patterns for Task Bench task graphs.

Pattern definitions follow Task Bench (Slaughter et al., SC'20, arXiv:1908.05790)
in spirit; each is documented precisely here since the exact index arithmetic is
normative for all runtime backends (they must agree bit-for-bit).

All functions answer: which points at timestep ``t-1`` does point ``p`` at
timestep ``t`` depend on? (t >= 1.)

Patterns:
  trivial              no dependencies at all (embarrassingly parallel tasks).
  no_comm              depend only on self: {p}.
  stencil_1d           {p-1, p, p+1} clipped to [0, W).
  stencil_1d_periodic  {p-1, p, p+1} mod W.
  dom                  wavefront/dominance sweep: {p-1, p} clipped (lower-
                       triangular dataflow, models sweeps like LU/Gauss-Seidel).
  tree                 binary reduce/broadcast ladder with period 2*log2(W):
                       first log2(W) steps reduce (p pairs with p XOR 2^k for
                       k rising), next log2(W) steps broadcast back (k falling).
                       Every point stays live (Task Bench keeps width constant);
                       the pairing distance is what contracts/expands.
  fft                  butterfly: {p, p XOR 2^(t-1 mod log2(W))}.
  all_to_all           every point: {0, ..., W-1}.
  nearest              {p-radius, ..., p+radius} mod W.
  spread               ``fanout`` points spread across the width, rotating with
                       t: {(p + i*W//fanout + (t-1)) mod W : i in [0, fanout)}.
  random_nearest       deterministic random subset of the nearest window
                       (seeded per graph; same seed => same graph).
"""
from __future__ import annotations

import math
from typing import TYPE_CHECKING, Tuple

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.graph import TaskGraph

PATTERNS = (
    "trivial",
    "no_comm",
    "stencil_1d",
    "stencil_1d_periodic",
    "dom",
    "tree",
    "fft",
    "all_to_all",
    "nearest",
    "spread",
    "random_nearest",
)

#: Patterns whose cross-device traffic is carried by halo exchange (ppermute)
#: in the distributed runtimes.
HALO_PATTERNS = ("no_comm", "stencil_1d", "stencil_1d_periodic", "dom", "nearest")
#: Patterns carried by XOR block permutes.
BUTTERFLY_PATTERNS = ("fft", "tree")
#: Patterns requiring full gather.
GLOBAL_PATTERNS = ("all_to_all", "spread", "random_nearest")


def _log2(w: int) -> int:
    return int(math.log2(w))


def period(g: "TaskGraph") -> int:
    if g.pattern == "fft":
        return max(1, _log2(g.width))
    if g.pattern == "tree":
        return max(1, 2 * _log2(g.width))
    if g.pattern == "spread":
        return g.width  # rotation repeats every W steps
    return 1


def max_deps(g: "TaskGraph") -> int:
    return {
        "trivial": 1,  # keep >=1 so array shapes stay non-degenerate
        "no_comm": 1,
        "stencil_1d": 3,
        "stencil_1d_periodic": 3,
        "dom": 2,
        "tree": 2,
        "fft": 2,
        "all_to_all": g.width,
        "nearest": 2 * g.radius + 1,
        "spread": g.fanout,
        "random_nearest": 2 * g.radius + 1,
    }[g.pattern]


def _rng_for(g: "TaskGraph", p: int) -> np.random.Generator:
    # Stable per-(graph, point) stream: the random_nearest neighborhood is
    # fixed across timesteps (matches Task Bench's use of a fixed random
    # graph rather than fresh randomness each step, which would defeat
    # caching in real runtimes too). Timestep-independence is why the
    # pattern's period is 1.
    return np.random.default_rng((g.seed * 1_000_003 + p) & 0x7FFFFFFF)


def dependencies(g: "TaskGraph", t: int, p: int) -> Tuple[int, ...]:
    W = g.width
    pat = g.pattern
    if pat == "trivial":
        return ()
    if pat == "no_comm":
        return (p,)
    if pat == "stencil_1d":
        return tuple(q for q in (p - 1, p, p + 1) if 0 <= q < W)
    if pat == "stencil_1d_periodic":
        return ((p - 1) % W, p, (p + 1) % W)
    if pat == "dom":
        return tuple(q for q in (p - 1, p) if 0 <= q < W)
    if pat == "fft":
        k = (t - 1) % max(1, _log2(W))
        partner = p ^ (1 << k)
        return (p, partner) if partner < W else (p,)
    if pat == "tree":
        L = max(1, _log2(W))
        s = (t - 1) % (2 * L)
        k = s if s < L else (2 * L - 1 - s)  # rise then fall
        partner = p ^ (1 << k)
        return (p, partner) if partner < W else (p,)
    if pat == "all_to_all":
        return tuple(range(W))
    if pat == "nearest":
        return tuple((p + d) % W for d in range(-g.radius, g.radius + 1))
    if pat == "spread":
        stride = max(1, W // g.fanout)
        return tuple(sorted({(p + i * stride + (t - 1)) % W for i in range(g.fanout)}))
    if pat == "random_nearest":
        rng = _rng_for(g, p)
        window = [(p + d) % W for d in range(-g.radius, g.radius + 1)]
        keep = rng.random(len(window)) < 0.5
        keep[g.radius] = True  # always keep self so graphs stay connected
        return tuple(sorted({w for w, k in zip(window, keep) if k}))
    raise ValueError(f"unknown pattern {pat!r}")


def halo_radius(g: "TaskGraph") -> int:
    """Cross-point reach of the pattern (for halo-exchange runtimes)."""
    return {
        "trivial": 0,
        "no_comm": 0,
        "stencil_1d": 1,
        "stencil_1d_periodic": 1,
        "dom": 1,
        "nearest": g.radius,
        "random_nearest": g.radius,
    }.get(g.pattern, -1)  # -1 => not halo-expressible


def butterfly_stride(g: "TaskGraph", slot: int) -> int:
    """XOR pairing distance 2^k for period slot ``slot`` of a butterfly
    pattern: timestep t uses slot (t-1) % period. fft's exponent rises
    0..L-1 and wraps; tree rises 0..L-1 then falls back (reduce /
    broadcast ladder). Graph validation guarantees a power-of-two width,
    so partner = p XOR stride is always in [0, W) and every point has
    exactly two dependencies {p, partner}.
    """
    if g.pattern not in BUTTERFLY_PATTERNS:
        raise ValueError(f"{g.pattern} is not a butterfly pattern")
    L = max(1, _log2(g.width))
    if g.pattern == "fft":
        return 1 << (slot % L)
    k = slot % (2 * L)
    return 1 << (k if k < L else (2 * L - 1 - k))


def butterfly_slot_strides(g: "TaskGraph") -> Tuple[int, ...]:
    """Pairing distance per period slot (length ``period(g)``)."""
    return tuple(butterfly_stride(g, s) for s in range(period(g)))
